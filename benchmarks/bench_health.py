"""Health-analytics benchmark: detection quality against seeded ground
truth, the health-driven closed loop, and the detector overhead budget.

Three claims gate the PR-9 observability loop:

1. **Detection quality** — fed only the telemetry a real fleet would
   emit (per-replica round durations, per-link sync durations, the loss
   stream), the streaming detectors recover the faults a seeded
   ``FaultPlan`` injected — persistent stragglers, repeated link flaps,
   a loss spike at a known index — at >= 0.9 precision AND recall, with
   bounded detection latency.  The plan is ground truth for *scoring
   only*; the detectors never read it.
2. **Closed loop** — on a straggler-ridden fleet, the async local-SGD
   trainer driven by :class:`repro.obs.HealthMonitor` detections
   (``quorum = R`` shrunk only past *detected* stragglers) recovers
   >= 80% of the tokens/s advantage that an oracle which reads the
   fault plan (static ``quorum = R-1``) holds over the synchronous
   barrier — and the detected straggler set matches the plan exactly.
3. **Overhead** — the detector path stays inside the PR-6 telemetry
   budget: the instrumented local-SGD loop is within noise of the
   uninstrumented one, and the amortized per-round detector cost is
   <= 2% of the measured real round wall-clock.

    PYTHONPATH=src python -m benchmarks.bench_health [--smoke] [--out F]

Writes ``BENCH_health.json`` — the artifact CI uploads.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks.common import BenchResult, Claim, print_result, write_bench_json

OUT = Path(__file__).resolve().parents[1] / "BENCH_health.json"

# synthetic-stream geometry (detection-quality section)
NOMINAL_S = 0.2          # healthy round duration
BASE_LINK_S = 0.05       # healthy sync duration
NOISE = 0.05             # +-5% multiplicative noise on every duration
WARMUP_ROUNDS = 4        # flap-free prefix so link baselines exist


def _cfg():
    from repro.configs.opt import opt_config
    return opt_config("opt-125m").reduced(num_layers=4, d_model=32,
                                          vocab_size=64)


def _tc(steps):
    from repro.train.trainer import TrainerConfig
    return TrainerConfig(steps=steps, batch=2, seq_len=16, log_every=0)


def _ls(**kw):
    from repro.train.local_sgd import LocalSGDConfig
    base = dict(inner_steps=2, nominal_step_s=0.1)
    base.update(kw)
    return LocalSGDConfig(**base)


def _monitor():
    from repro.obs import HealthMonitor, MetricsRegistry
    return HealthMonitor(registry=MetricsRegistry())


# -------------------------------------------------------------------------
# 1. detection quality on synthetic telemetry with seeded ground truth


def detection_quality(smoke: bool) -> Dict:
    """Replay seeded FaultPlans as pure telemetry streams; score the
    detectors' end-state verdicts against the plan."""
    from repro.core.faultinject import FaultPlan

    seeds = [3, 5] if smoke else [3, 5, 7, 11, 13]
    R = 8
    rounds = 16 if smoke else 24
    agg = {"straggler": {"tp": 0, "fp": 0, "fn": 0},
           "link": {"tp": 0, "fp": 0, "fn": 0},
           "loss": {"tp": 0, "fp": 0, "fn": 0}}
    straggler_latencies: List[int] = []
    link_lag_rounds: List[int] = []
    per_seed = []

    for seed in seeds:
        # 0.2 keeps every realized draw below the fleet-median baseline's
        # 50% breakdown point (a majority-straggler fleet has no healthy
        # reference to be slow *relative to*)
        plan = FaultPlan(seed=seed, straggler_frac=0.2,
                         link_flap_prob=0.08)
        hm = _monitor()
        need = hm.link.degrade_after
        first_flag: Dict[str, int] = {}
        spike_rounds: Dict[int, List[int]] = {r: [] for r in range(R)}
        degrade_round: Dict[str, int] = {}
        for t in range(rounds):
            ts = t * NOMINAL_S
            for r in range(R):
                jig = np.random.default_rng([seed, r, t])
                dur = NOMINAL_S * plan.slowdown(r) \
                    * (1.0 + NOISE * (2.0 * jig.random() - 1.0))
                a = hm.observe_step(r, dur, ts_s=ts)
                if a is not None and a.kind == "straggler":
                    first_flag.setdefault(str(r), t)
                jit = plan.jitter_s(r, t) if t >= WARMUP_ROUNDS else 0.0
                if jit > 0.0:
                    spike_rounds[r].append(t)
                link = (BASE_LINK_S
                        * (1.0 + NOISE * (2.0 * jig.random() - 1.0))
                        + jit)
                a = hm.observe_link(r, link, ts_s=ts)
                # each spike alerts; the entity is *degraded* (the
                # verdict schedulers act on) once `need` spikes landed
                if a is not None and a.kind == "link_degraded" \
                        and a.detail.get("spikes", 0) >= need:
                    degrade_round.setdefault(str(r), t)

        truth_strag = {str(r) for r in range(R) if plan.is_straggler(r)}
        pred_strag = hm.stragglers()
        truth_link = {str(r) for r in range(R)
                      if len(spike_rounds[r]) >= need}
        pred_link = hm.degraded_links()
        for key, truth, pred in (("straggler", truth_strag, pred_strag),
                                 ("link", truth_link, pred_link)):
            agg[key]["tp"] += len(truth & pred)
            agg[key]["fp"] += len(pred - truth)
            agg[key]["fn"] += len(truth - pred)
        straggler_latencies.extend(first_flag[e] + 1 for e in truth_strag
                                   if e in first_flag)
        # a degraded link should be called the round its `need`-th
        # detectable spike lands, not later
        link_lag_rounds.extend(
            degrade_round[str(r)] - spike_rounds[r][need - 1]
            for r in range(R)
            if str(r) in truth_link and str(r) in degrade_round)

        # loss stream: smooth decay + noise, one spike at a known index
        hm2 = _monitor()
        inject_at = rounds * 2
        spike_alerts = []
        lrng = np.random.default_rng([seed, 999])
        for t in range(rounds * 4):
            loss = 3.0 * float(np.exp(-0.005 * t)) \
                + 0.01 * float(lrng.standard_normal())
            if t == inject_at:
                loss += 2.0
            a = hm2.observe_loss(loss, ts_s=float(t))
            if a is not None and a.kind == "loss_spike":
                spike_alerts.append(t)
        agg["loss"]["tp"] += int(inject_at in spike_alerts)
        agg["loss"]["fn"] += int(inject_at not in spike_alerts)
        agg["loss"]["fp"] += sum(t != inject_at for t in spike_alerts)

        per_seed.append({
            "seed": seed,
            "true_stragglers": sorted(truth_strag),
            "detected_stragglers": sorted(pred_strag),
            "true_degraded_links": sorted(truth_link),
            "detected_degraded_links": sorted(pred_link),
            "loss_spike_alert_rounds": spike_alerts,
            "alerts_by_kind": hm.alerts_by_kind()})

    def _pr(c):
        p = c["tp"] / max(c["tp"] + c["fp"], 1)
        r = c["tp"] / max(c["tp"] + c["fn"], 1)
        return {"precision": p, "recall": r, **c}

    return {
        "seeds": seeds, "replicas": R, "rounds": rounds,
        "noise": NOISE, "warmup_rounds": WARMUP_ROUNDS,
        "straggler": _pr(agg["straggler"]),
        "link": _pr(agg["link"]),
        "loss": _pr(agg["loss"]),
        "straggler_latency_rounds": {
            "max": max(straggler_latencies, default=0),
            "all": straggler_latencies},
        "link_lag_rounds": {"max": max(link_lag_rounds, default=0),
                            "all": link_lag_rounds},
        "per_seed": per_seed,
    }


# -------------------------------------------------------------------------
# 2. closed loop: sync vs plan-aware oracle vs health-driven async


def closed_loop(smoke: bool) -> Dict:
    """Same plan, three runs: synchronous barrier; oracle async whose
    static ``quorum = R-1`` encodes plan knowledge (someone is slow);
    health async at full ``quorum = R`` where only *detections* shrink
    the barrier.  Gate: health recovers >= 80% of the oracle's tokens/s
    advantage over sync."""
    from repro.core.faultinject import FaultPlan
    from repro.train.local_sgd import train_local_sgd

    R = 10
    rounds = 8 if smoke else 12
    tc = _tc(steps=2 * rounds)
    # seed 5 realizes exactly 1 persistent straggler (~7x) out of R=10
    plan = FaultPlan(seed=5, straggler_frac=0.12, crash_prob=0.02)
    cfg = _cfg()

    sync = train_local_sgd(cfg, tc, _ls(replicas=R), fault_plan=plan)
    oracle = train_local_sgd(
        cfg, tc, _ls(replicas=R, async_mode=True, quorum=R - 1,
                     staleness_bound=4), fault_plan=plan)
    hm = _monitor()
    health = train_local_sgd(
        cfg, tc, _ls(replicas=R, async_mode=True, quorum=R,
                     staleness_bound=4), fault_plan=plan, health=hm)

    truth = {str(r) for r in range(R) if plan.is_straggler(r)}
    detected = hm.stragglers()
    adv_oracle = (oracle.virtual_tokens_per_s
                  - sync.virtual_tokens_per_s)
    adv_health = (health.virtual_tokens_per_s
                  - sync.virtual_tokens_per_s)
    out = {
        "replicas": R, "rounds": rounds,
        "true_stragglers": sorted(truth),
        "detected_stragglers": sorted(detected),
        "detection_mismatch": len(truth ^ detected),
        "health_excluded_updates": health.health_excluded_updates,
        "health_summary": health.health_summary,
        "advantage_recovered": adv_health / max(adv_oracle, 1e-9),
    }
    for tag, r in (("sync", sync), ("oracle", oracle),
                   ("health", health)):
        out[tag] = {"tokens_per_s": r.virtual_tokens_per_s,
                    "virtual_time_s": r.virtual_time_s,
                    "final_loss": r.final_loss,
                    "contributed_steps": r.contributed_steps,
                    "fault_counts": r.fault_counts}
    return out


# -------------------------------------------------------------------------
# 3. overhead: micro cost per observe + instrumented-loop wall clock


def overhead(smoke: bool) -> Dict:
    from repro.core.faultinject import FaultPlan
    from repro.train.local_sgd import train_local_sgd

    # micro: amortized host cost of one detector observation
    hm = _monitor()
    n = 5000 if smoke else 20000
    t0 = time.perf_counter()
    for i in range(n):
        hm.observe_step(i % 8, NOMINAL_S * (1.0 + 1e-4 * (i % 7)),
                        ts_s=float(i))
    us_per_observe = (time.perf_counter() - t0) / n * 1e6

    # macro: sync local-SGD with and without a monitor attached —
    # interleaved best-of passes so shared-host noise spreads evenly
    R, steps = 4, 12
    cfg, tc = _cfg(), _tc(steps=steps)
    plan = FaultPlan(seed=7, straggler_frac=0.2)
    rounds = steps // 2

    def _timed(with_health):
        mon = _monitor() if with_health else None
        w0 = time.perf_counter()
        train_local_sgd(cfg, tc, _ls(replicas=R), fault_plan=plan,
                        health=mon)
        return time.perf_counter() - w0

    _timed(False)                                  # warmup (compile)
    best = {"off": float("inf"), "on": float("inf")}
    for _ in range(3):
        best["off"] = min(best["off"], _timed(False))
        best["on"] = min(best["on"], _timed(True))
    ratio = best["off"] / best["on"]               # >1 means on is faster

    # observations per sync round: step+link per replica, one loss
    calls_per_round = 2 * R + 1
    round_wall_us = best["off"] / rounds * 1e6
    fraction = calls_per_round * us_per_observe / round_wall_us
    return {
        "us_per_observe": us_per_observe,
        "observe_calls": n,
        "wall_s_uninstrumented": best["off"],
        "wall_s_instrumented": best["on"],
        "throughput_ratio": ratio,
        "calls_per_round": calls_per_round,
        "round_wall_us": round_wall_us,
        "detector_fraction_of_round": fraction,
    }


# -------------------------------------------------------------------------
# 4. SLO burn-rate demo: breach on a bad burst, recover on hysteresis


def slo_demo() -> Dict:
    from repro.obs import MetricsRegistry, SLOMonitor, serve_slos

    slo = SLOMonitor(serve_slos(ttft_p99_s=0.5, gco2e_budget=100.0,
                                horizon_s=3600.0),
                     registry=MetricsRegistry())
    for t in range(64):                       # healthy traffic
        slo.observe("serve_ttft", 0.1, t=float(t))
    for t in range(64, 104):                  # burst of slow TTFTs
        slo.observe("serve_ttft", 0.9, t=float(t))
    burning_during = slo.burning("serve_ttft")
    for t in range(104, 304):                 # recovery traffic
        slo.observe("serve_ttft", 0.1, t=float(t))
    # budget SLO: spend carbon at 4x the sustainable pace
    for t in range(10):
        slo.observe("serve_gco2e", 100.0 / 3600.0 * 4.0 * 60.0,
                    t=float(t * 60.0))
    events = [e["event"] for e in slo.events if e["slo"] == "serve_ttft"]
    return {
        "ttft_events": slo.events,
        "burning_during_burst": burning_during,
        "breach_recover_mismatch": int(
            events != ["slo.breach", "slo.recovered"]
            or not burning_during),
        "gco2e_burn": slo.burn_rate("serve_gco2e"),
        "verdicts": slo.verdicts(),
        "summary": slo.summary_line(),
    }


# -------------------------------------------------------------------------


def run(smoke: bool = False, out: Path = OUT) -> BenchResult:
    res = BenchResult(name="bench_health")
    record: Dict[str, Dict] = {"config": {
        "model": "opt-125m reduced (4L, d32)", "batch": 2, "seq_len": 16,
        "inner_steps": 2, "smoke": smoke}}

    det = detection_quality(smoke)
    record["detection"] = det
    for key in ("straggler", "link", "loss"):
        res.rows.append({
            "scenario": "detection", "detector": key,
            "precision": round(det[key]["precision"], 3),
            "recall": round(det[key]["recall"], 3),
            "tp": det[key]["tp"], "fp": det[key]["fp"],
            "fn": det[key]["fn"]})
    worst_p = min(det[k]["precision"] for k in ("straggler", "link",
                                                "loss"))
    worst_r = min(det[k]["recall"] for k in ("straggler", "link",
                                             "loss"))
    res.claims.append(Claim(
        "detectors recover seeded stragglers/flaps/loss-spikes from "
        "telemetry alone: precision (worst detector)", worst_p, 0.9,
        1.0))
    res.claims.append(Claim(
        "detectors recover seeded stragglers/flaps/loss-spikes from "
        "telemetry alone: recall (worst detector)", worst_r, 0.9, 1.0))
    res.claims.append(Claim(
        "straggler detection latency is bounded (max rounds of "
        "telemetry until flag)",
        float(det["straggler_latency_rounds"]["max"]), 0, 6))
    res.claims.append(Claim(
        "link degradation is called the round its qualifying spike "
        "lands (max lag, rounds)",
        float(det["link_lag_rounds"]["max"]), 0, 0))

    loop = closed_loop(smoke)
    record["closed_loop"] = loop
    for tag in ("sync", "oracle", "health"):
        res.rows.append({
            "scenario": f"closed loop R={loop['replicas']}", "mode": tag,
            "tokens_per_s": round(loop[tag]["tokens_per_s"], 1),
            "vclock_s": round(loop[tag]["virtual_time_s"], 2),
            "final_loss": round(loop[tag]["final_loss"], 4),
            "contributed": loop[tag]["contributed_steps"]})
    res.claims.append(Claim(
        "health-driven async recovers >= 80% of the plan-aware oracle's "
        "tokens/s advantage over sync (fraction)",
        loop["advantage_recovered"], 0.8, float("inf")))
    res.claims.append(Claim(
        "detected straggler set matches the plan's ground truth "
        "(symmetric difference)",
        float(loop["detection_mismatch"]), 0, 0))

    ovh = overhead(smoke)
    record["overhead"] = ovh
    res.rows.append({
        "scenario": "overhead",
        "us_per_observe": round(ovh["us_per_observe"], 2),
        "throughput_ratio": round(ovh["throughput_ratio"], 3),
        "fraction_of_round": round(
            ovh["detector_fraction_of_round"], 5)})
    # the micro-measured fraction claim below is the principled <=2%
    # gate; this macro ratio is a sanity band only — CPU-XLA step times
    # jitter several % run to run on a shared host, so the floor is
    # 0.90 (exact best-of-3 ratio is in the JSON)
    res.claims.append(Claim(
        "health-instrumented local-SGD loop stays within noise of "
        "uninstrumented (wall-clock ratio)",
        ovh["throughput_ratio"], 0.90, float("inf")))
    res.claims.append(Claim(
        "amortized detector cost per round <= 2% of the real round "
        "wall-clock (fraction)",
        ovh["detector_fraction_of_round"], 0.0, 0.02))

    slo = slo_demo()
    record["slo"] = slo
    res.rows.append({
        "scenario": "slo", "events": len(slo["ttft_events"]),
        "gco2e_burn": round(slo["gco2e_burn"], 2),
        "summary": slo["summary"]})
    res.claims.append(Claim(
        "TTFT SLO walks the breach -> recovered cycle on a slow burst "
        "(sequence mismatches)",
        float(slo["breach_recover_mismatch"]), 0, 0))
    res.claims.append(Claim(
        "budget SLO burn tracks spend pace (4x pace -> burn >= 2)",
        slo["gco2e_burn"], 2.0, float("inf")))

    res.notes.append(
        f"detection: straggler P/R "
        f"{det['straggler']['precision']:.2f}/"
        f"{det['straggler']['recall']:.2f}, link "
        f"{det['link']['precision']:.2f}/{det['link']['recall']:.2f} "
        f"across {len(det['seeds'])} seeded plans")
    res.notes.append(
        f"closed loop: sync {loop['sync']['tokens_per_s']:.0f} -> "
        f"oracle {loop['oracle']['tokens_per_s']:.0f} -> health "
        f"{loop['health']['tokens_per_s']:.0f} tok/s "
        f"({loop['advantage_recovered']:.2f}x of oracle advantage, "
        f"{loop['health_excluded_updates']} quorum exclusions, plan "
        f"never read)")
    res.notes.append(
        f"overhead: {ovh['us_per_observe']:.1f}us/observe, "
        f"{ovh['detector_fraction_of_round']*100:.3f}% of a real round")
    write_bench_json(out, {"result": record, "rows": res.rows,
                           "notes": res.notes}, claims=res.claims)
    res.notes.append(f"wrote {Path(out).name}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", type=Path, default=OUT)
    args = ap.parse_args()
    res = run(smoke=args.smoke, out=args.out)
    print_result(res)
    raise SystemExit(0 if res.ok else 1)


if __name__ == "__main__":
    main()
