"""Paper Fig. 3 — cloud vs edge training energy across OPT model sizes,
under the paper's idealized distributed-training method (footnote 1).

For each OPT size: devices = ceil(state bytes / usable memory); compute
perfectly divided; communication = model size + per-layer intermediates,
once per batch, through the controller.  Claim checked: edge training is
1.5-4x more energy-efficient than cloud across the range (paper §4.2:
"lowering training related energy consumption with edge devices by
1.5-4x compared to the cloud case across a range of model sizes").
"""

from __future__ import annotations

from repro.configs.opt import OPT_NAMES, opt_config
from repro.core.energy.devices import (CLOUD_A5000, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888)
from repro.core.planner import idealized as IDL

from benchmarks.common import BenchResult, Claim

SIZES = [n for n in OPT_NAMES if n not in ("opt-350m",)]  # paper's x-axis


def run() -> BenchResult:
    res = BenchResult("Fig. 3: idealized distributed training energy "
                      "(cloud vs edge, OPT sizes)")
    ratios_laptop, ratios_phone = [], []
    for name in SIZES:
        cfg = opt_config(name)
        cloud = IDL.fig3_energy(cfg, CLOUD_A5000)
        laptop = IDL.fig3_energy(cfg, LAPTOP_M2PRO)
        phone = IDL.fig3_energy(cfg, SMARTPHONE_SD888)
        r_l = cloud["energy_wh"] / laptop["energy_wh"]
        r_p = cloud["energy_wh"] / phone["energy_wh"]
        ratios_laptop.append(r_l)
        ratios_phone.append(r_p)
        res.rows.append({
            "model": name,
            "cloud_dev": cloud["devices"], "cloud_wh": cloud["energy_wh"],
            "laptop_dev": laptop["devices"], "laptop_wh": laptop["energy_wh"],
            "phone_dev": phone["devices"], "phone_wh": phone["energy_wh"],
            "cloud/laptop": r_l, "cloud/phone": r_p,
        })

    res.claims.append(Claim(
        "laptops >=1.5x more efficient than cloud across all sizes (min)",
        min(ratios_laptop), 1.5, 10.0))
    res.claims.append(Claim(
        "laptop advantage 'particularly pronounced' (max)",
        max(ratios_laptop), 2.0, 10.0))
    res.claims.append(Claim(
        "smartphones >= cloud efficiency across all sizes (min)",
        min(ratios_phone), 1.0, 4.0))
    res.notes.append("idealized method (paper footnote 1): perfectly "
                     "divisible compute, controller aggregation, volume = "
                     "model + Σ intermediates per batch")
    return res
