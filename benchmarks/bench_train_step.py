"""Train-step wall-clock baseline — the perf trajectory anchor.

Measures compile time, steady-state steps/s, and tokens/s for a small
config across

* ``attn_impl`` ∈ {naive, chunked, pallas}  (pallas runs the real kernel
  logic in interpret mode on CPU — correctness of the hot path, not its
  TPU speed), and
* the trainer-loop axes: buffer donation on/off × per-step host sync vs
  async device-resident metrics (prefetch rides with async),

and writes ``BENCH_train_step.json``.  The headline number is the
steps/s ratio of the zero-sync loop (donation + async metrics +
prefetch) over the seed-style loop (no donation, blocking
``float(loss)`` every step) — the regression gate every future PR's
loop change is measured against.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_train_step [--smoke] \
        [--steps N] [--out BENCH_train_step.json]
"""

from __future__ import annotations

import argparse
import platform
from typing import Any, Dict

import jax

from repro.configs.opt import opt_config
from repro.train.trainer import TrainerConfig, donation_supported, train

from benchmarks.common import BenchResult, Claim, write_bench_json

# (a) attention axis: big enough that attention is a visible fraction
ATTN_BATCH, ATTN_SEQ = 8, 128
# (b) loop axis: the per-step host sync / donation bookkeeping / transfer
# costs are FIXED per step, so the loop effect is measured where steps are
# fast (~10ms) and the fixed costs are a visible fraction of step time —
# at 100ms+ steps the loop delta drowns in shared-host wall-clock noise
LOOP_BATCH, LOOP_SEQ = 4, 64

# loop variants: name -> (donate, async_metrics+prefetch, obs)
# ``obs`` runs the identical zero-sync loop with the repro.obs tracer
# enabled and a device-resident metrics registry attached — the
# telemetry layer's own regression gate: spans + device accumulators
# must not reintroduce per-step host syncs.
LOOP_VARIANTS = {
    "seed_sync_nodonate": (False, False, False),
    "donate_only": (True, False, False),
    "async_only": (False, True, False),
    "async_donate": (True, True, False),
    "async_donate_obs": (True, True, True),
}


def _attn_cfg():
    return opt_config("opt-125m").reduced(num_layers=2, d_model=128,
                                          vocab_size=512)


def _loop_cfg():
    return opt_config("opt-125m").reduced(num_layers=1, d_model=64,
                                          vocab_size=256)


def _measure(cfg, *, batch: int, seq: int, attn_impl: str, donate: bool,
             async_metrics: bool, steps: int,
             obs: bool = False) -> Dict[str, float]:
    tc = TrainerConfig(steps=steps, batch=batch, seq_len=seq, log_every=0,
                       attn_impl=attn_impl, donate=donate,
                       async_metrics=async_metrics, prefetch=async_metrics)
    if obs:
        from repro.obs import MetricsRegistry, Tracer, set_tracer
        registry = MetricsRegistry()
        old = set_tracer(Tracer(enabled=True, registry=registry,
                                process="bench_train_step"))
        try:
            res = train(cfg, tc, metrics=registry)
        finally:
            set_tracer(old)
    else:
        res = train(cfg, tc)
    return {
        "compile_time_s": res.compile_time_s,
        "steps_per_s": res.steady_steps_per_s,
        "tokens_per_s": res.steady_steps_per_s * batch * seq,
        "final_loss": res.final_loss,
        "steps": steps,
    }


def bench(steps: int, pallas_steps: int, repeats: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {
        "config": {"attn_axis": {"model": "opt-125m reduced L2 d128 v512",
                                 "batch": ATTN_BATCH, "seq_len": ATTN_SEQ},
                   "loop_axis": {"model": "opt-125m reduced L1 d64 v256",
                                 "batch": LOOP_BATCH, "seq_len": LOOP_SEQ},
                   "backend": jax.default_backend(),
                   "device_count": jax.device_count(),
                   # on CPU the donate axis is requested-but-inert: the
                   # trainer only passes donate_argnums where XLA can
                   # actually reuse the buffers (TPU/GPU)
                   "donation_supported": donation_supported(),
                   "platform": platform.platform()},
        "attn": {}, "loop": {},
    }
    # (a) kernel axis: zero-sync loop, vary the attention implementation.
    # pallas off-TPU is interpret mode (python-level execution) — its
    # steps/s here measures CI overhead, not kernel speed; its compile
    # time and the fact that it *trains* are the signals.
    attn_cfg = _attn_cfg()
    for impl in ("naive", "chunked", "pallas"):
        n = pallas_steps if impl == "pallas" else steps
        out["attn"][impl] = _measure(attn_cfg, batch=ATTN_BATCH,
                                     seq=ATTN_SEQ, attn_impl=impl,
                                     donate=True, async_metrics=True,
                                     steps=n)
    # (b) loop axis: chunked attention, vary donation x metrics sync.
    # One untimed warmup run, then ``repeats`` round-robin passes over the
    # variants with best-of taken per variant — interleaving spreads
    # shared-host noise and in-process warmup drift (allocator/GC state
    # after the interpret-mode runs above) evenly across variants instead
    # of penalizing whichever runs first.
    loop_cfg = _loop_cfg()
    loop_steps = steps * 3      # fast steps: more of them for less noise
    _measure(loop_cfg, batch=LOOP_BATCH, seq=LOOP_SEQ, attn_impl="chunked",
             donate=False, async_metrics=False, steps=loop_steps)  # warmup
    for rep in range(repeats):
        for name, (donate, async_m, obs) in LOOP_VARIANTS.items():
            row = _measure(loop_cfg, batch=LOOP_BATCH, seq=LOOP_SEQ,
                           attn_impl="chunked", donate=donate,
                           async_metrics=async_m, steps=loop_steps,
                           obs=obs)
            row["repeats"] = repeats
            prev = out["loop"].get(name)
            if prev is None or row["steps_per_s"] > prev["steps_per_s"]:
                row["compile_time_s"] = (prev or row)["compile_time_s"]
                out["loop"][name] = row
    seed = out["loop"]["seed_sync_nodonate"]["steps_per_s"]
    best = out["loop"]["async_donate"]["steps_per_s"]
    out["speedup_async_donate_vs_seed"] = best / seed
    out["obs_over_uninstrumented"] = (
        out["loop"]["async_donate_obs"]["steps_per_s"] / best)
    return out


def run(steps: int = 40, pallas_steps: int = 4, repeats: int = 2,
        out_path: str = "BENCH_train_step.json") -> BenchResult:
    data = bench(steps, pallas_steps, repeats)

    res = BenchResult(name="bench_train_step")
    for impl, row in data["attn"].items():
        res.rows.append({"axis": "attn", "variant": impl, **row})
    for name, row in data["loop"].items():
        res.rows.append({"axis": "loop", "variant": name, **row})
    speedup = data["speedup_async_donate_vs_seed"]
    res.notes.append(f"wrote {out_path}")
    res.notes.append(
        f"zero-sync loop (donation+async+prefetch) vs seed loop: "
        f"{speedup:.3f}x steps/s on {data['config']['backend']}")
    # regression gate, not a win-proof: CI boxes are noisy, so the claim
    # band only rejects a clear slowdown of the zero-sync loop; the exact
    # delta is recorded in the JSON trajectory.
    res.claims.append(Claim(
        text="async+donation loop is not slower than the seed "
             "sync-every-step loop (steps/s ratio)",
        value=speedup, lo=0.95, hi=float("inf")))
    obs_ratio = data["obs_over_uninstrumented"]
    res.notes.append(
        f"tracer+device-metrics instrumented loop vs uninstrumented: "
        f"{obs_ratio:.3f}x steps/s (target: within 2%; band below "
        f"absorbs shared-host noise, exact ratio is in the JSON)")
    res.claims.append(Claim(
        text="instrumented (spans + device-resident metrics) zero-sync "
             "loop keeps step time within noise of uninstrumented "
             "(steps/s ratio)",
        value=obs_ratio, lo=0.95, hi=float("inf")))
    # claims are embedded in the artifact so repro.obs.validate can
    # re-check the committed verdicts without re-running the benchmark
    write_bench_json(out_path, data, claims=res.claims)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_train_step.json")
    args = ap.parse_args()
    steps = args.steps or (30 if args.smoke else 60)
    pallas_steps = 3 if args.smoke else 6
    repeats = 2 if args.smoke else 3
    res = run(steps=steps, pallas_steps=pallas_steps, repeats=repeats,
              out_path=args.out)
    from benchmarks.common import print_result
    print_result(res)
    if not res.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
