"""Paper Table 2 — distributed DT-FM training energy, OPT-1.3B.

Setting (paper §4.2): same data/hyperparameters as Table 1 (100 steps,
batch 16, seq 512); fleet sizes fixed by the paper — 4 laptops or 15
smartphones hold all parameters + training state; 10 MB/s symmetric
bandwidth, 0.5 W WiFi module [82].

Paper's measured energies: cloud GPU 152 Wh, 4 laptops 27 Wh,
15 smartphones 98 Wh -> distributed edge training is 1.5-5x more
energy-efficient than one cloud GPU *even with* communication energy.
"""

from __future__ import annotations

from repro.configs.opt import opt_config
from repro.core import flops as F
from repro.core.energy.devices import (CLOUD_A5000, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888, train_energy_wh)
from repro.core.planner import dtfm

from benchmarks.common import BenchResult, Claim

STEPS, BATCH, SEQ = 100, 16, 512
PAPER = {"cloud-a5000": 152.0, "laptop-m2pro": 27.0,
         "smartphone-sd888": 98.0}
FLEET = {"laptop-m2pro": (LAPTOP_M2PRO, 4),
         "smartphone-sd888": (SMARTPHONE_SD888, 15)}


def run() -> BenchResult:
    cfg = opt_config("opt-1.3b")
    res = BenchResult("Table 2: DT-FM distributed energy (OPT-1.3B)")

    total = F.train_flops(cfg, BATCH, SEQ, remat=False) * STEPS
    e_cloud = train_energy_wh(CLOUD_A5000, total)
    res.rows.append({"fleet": "1x cloud-a5000", "energy_wh": e_cloud,
                     "paper_wh": PAPER["cloud-a5000"],
                     "err_%": 100 * abs(e_cloud - PAPER["cloud-a5000"])
                     / PAPER["cloud-a5000"]})
    res.claims.append(Claim("cloud GPU energy ≈ paper (152 Wh)",
                            e_cloud / PAPER["cloud-a5000"], 0.9, 1.1))

    for name, (dev, n) in FLEET.items():
        out = dtfm.table2_energy(cfg, dev, n, batch=BATCH, seq_len=SEQ,
                                 steps=STEPS)
        e = out["energy_wh"]
        res.rows.append({"fleet": f"{n}x {name}", "energy_wh": e,
                         "paper_wh": PAPER[name],
                         "err_%": 100 * abs(e - PAPER[name]) / PAPER[name],
                         "bubble": out["bubble_fraction"],
                         "comm_s_per_step": out["comm_s_per_step"]})
        res.claims.append(Claim(f"{n}x {name} energy ≈ paper "
                                f"({PAPER[name]} Wh)", e / PAPER[name],
                                0.75, 1.25))
        # the paper's own numbers give 152/27 = 5.6x (laptops) and
        # 152/98 = 1.55x (phones); accept the compounded per-fleet model
        # error (each fleet is reproduced within 25% above)
        res.claims.append(Claim(
            f"{n}x {name}: 1.5-5x more efficient than cloud GPU "
            "(paper's numbers imply 1.55-5.6x)",
            e_cloud / e, 1.4, 8.0))
    res.notes.append("DT-FM plan: compute-weighted contiguous layer split, "
                     "GPipe makespan incl. bubble, stage-boundary activations"
                     " + WiFi energy at 10 MB/s / 0.5 W [82]")
    return res
