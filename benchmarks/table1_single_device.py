"""Paper Table 1 — single-device training energy, OPT-125m.

Setting (paper §4.2): MMLU dataset, 100 steps, batch 16, seq 512.
The device catalog's MFU constants were calibrated ONCE against this
table's wall-times (see ``core/energy/devices.py``); this benchmark then
checks that power × time reproduces the paper's energy column and its
headline ratios:

* edge devices are 2-10x slower than the cloud GPU,
* but consume 1.5-7.5x less energy,
* at 15-20x lower power.
"""

from __future__ import annotations

from repro.configs.opt import opt_config
from repro.core import flops as F
from repro.core.energy.devices import (CLOUD_A5000, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888, train_energy_wh,
                                       train_time_s)

from benchmarks.common import BenchResult, Claim

STEPS, BATCH, SEQ = 100, 16, 512

# the paper's measured values: (power W, time s, energy Wh)
PAPER = {
    "smartphone-sd888": (10.0, 3510.0, 9.75),
    "laptop-m2pro": (15.0, 480.0, 2.0),
    "cloud-a5000": (220.0, 250.0, 15.28),
}


def run() -> BenchResult:
    cfg = opt_config("opt-125m")
    total = F.train_flops(cfg, BATCH, SEQ, remat=False) * STEPS

    res = BenchResult("Table 1: single-device energy (OPT-125m)")
    derived = {}
    for dev in (SMARTPHONE_SD888, LAPTOP_M2PRO, CLOUD_A5000):
        t = train_time_s(dev, total)
        e = train_energy_wh(dev, total)
        derived[dev.name] = (dev.power_active_w, t, e)
        p_ref, t_ref, e_ref = PAPER[dev.name]
        res.rows.append({
            "device": dev.name, "power_w": dev.power_active_w,
            "time_s": t, "paper_time_s": t_ref,
            "energy_wh": e, "paper_energy_wh": e_ref,
            "time_err_%": 100 * abs(t - t_ref) / t_ref,
            "energy_err_%": 100 * abs(e - e_ref) / e_ref,
        })

    # per-device reproduction within 5 % (calibration closes wall-time;
    # energy = power x time must then follow)
    for name, (_, t_ref, e_ref) in PAPER.items():
        _, t, e = derived[name]
        res.claims.append(Claim(f"{name} energy ≈ paper ({e_ref} Wh)",
                                e / e_ref, 0.95, 1.05))

    e_cloud = derived["cloud-a5000"][2]
    t_cloud = derived["cloud-a5000"][1]
    for name in ("smartphone-sd888", "laptop-m2pro"):
        _, t, e = derived[name]
        res.claims.append(Claim(
            f"{name}: 1.5-7.5x lower energy than cloud GPU",
            e_cloud / e, 1.5, 7.7))
        res.claims.append(Claim(
            f"{name}: 2-10x slower than cloud GPU", t / t_cloud, 1.9, 15.0))
        res.claims.append(Claim(
            f"{name}: 15-20x lower power than cloud GPU",
            220.0 / derived[name][0], 14.0, 23.0))
    return res
