"""§5 ablation — gradient compression for communication minimization.

The paper: "Existing compression techniques reduce communication but are
typically limited to fine-tuning due to accuracy concerns" and the §5
challenge asks to "flatten communication-related energy costs".  This
ablation trains the same small model under {none, int8, topk-1%(+EF)} and
reports wire bytes per step vs final loss — quantifying the
accuracy/communication trade the paper describes.

Claims:
* int8+EF matches uncompressed loss within 5% at ~2x fewer wire bytes,
* topk-1%+EF still LEARNS (loss drops >=1.5 nats) at ~25x fewer bytes,
* WiFi energy per step scales with wire bytes (0.5 W module, 10 MB/s).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.configs.opt import opt_config
from repro.core.energy.devices import SMARTPHONE_SD888
from repro.models import params as PM
from repro.optim import adamw
from repro.optim.compress import CompressConfig, wire_bytes
from repro.train.trainer import TrainerConfig, train

from benchmarks.common import BenchResult, Claim

STEPS = 60


def _run(method: str, topk: float = 0.01):
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=128,
                                         vocab_size=512)
    comp = CompressConfig(method=method, topk_fraction=topk)
    # trainer path has no compress hook; drive train_step directly
    import jax.numpy as jnp
    from repro.data.pipeline import make_batch_fn
    from repro.train.step import make_train_step
    opt_cfg = adamw.OptConfig(learning_rate=3e-4, warmup_steps=10,
                              decay_steps=STEPS)
    params = PM.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, compress=comp))
    data = make_batch_fn(cfg, 8, 64, seed=0)
    losses = []
    for _ in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, mets = step(params, opt, batch)
        losses.append(float(mets["loss"]))
    wire = wire_bytes(params, comp)
    return np.mean(losses[:5]), np.mean(losses[-5:]), wire


def run() -> BenchResult:
    res = BenchResult("§5 ablation: gradient compression (comm energy vs "
                      "accuracy)")
    results = {}
    for method in ("none", "int8", "topk"):
        first, last, wire = _run(method)
        results[method] = (first, last, wire)
        wifi_j = wire / SMARTPHONE_SD888.net_bw_Bps \
            * SMARTPHONE_SD888.power_comm_w
        res.rows.append({"method": method, "loss_first5": first,
                         "loss_last5": last,
                         "wire_MB_per_sync": wire / 1e6,
                         "wifi_J_per_sync": wifi_j})

    base = results["none"]
    res.claims.append(Claim(
        "int8+EF final loss within 5% of uncompressed",
        results["int8"][1] / base[1], 0.9, 1.05))
    res.claims.append(Claim(
        "int8 cuts wire bytes ~2x", base[2] / results["int8"][2], 1.7, 2.3))
    res.claims.append(Claim(
        "topk-1%+EF still learns (>=1 nat drop)",
        results["topk"][0] - results["topk"][1], 1.0, 10.0))
    res.claims.append(Claim(
        "...but converges slower than uncompressed — the paper's 'limited "
        "to fine-tuning due to accuracy concerns' caveat, quantified",
        results["topk"][1] / base[1], 1.1, 3.0))
    res.claims.append(Claim(
        "topk-1% cuts wire bytes >=20x", base[2] / results["topk"][2],
        20.0, 100.0))
    return res
