"""Elastic-state benchmark: reshard parity + placement-aware recovery win.

Two claims gate the elastic checkpoint subsystem (§5's checkpointing /
replication / recomputation trade-off made placement-aware):

1. **Reshard parity** — a checkpoint written by a 3-stage placement,
   resharded onto 2 stages and back to 3, restores *bit-identically*
   (params and optimizer state) to never resharding.  Boundary math is
   shared with the pipeline executor, so the slice a stage checkpoints
   is the slice it executes.
2. **Recovery win** — on a 2-region fleet that loses a device,
   placement-aware restore (survivors keep their shards, joiners fetch
   only their layer ranges from the nearest holder) moves strictly
   fewer cross-region bytes AND strictly less recovery wall-clock than
   the naive baseline (every node pulls the full state from the durable
   store across the WAN).  The same comparison is run end-to-end
   through the orchestrator sim, whose churn trajectory is identical
   under both pricings.

    PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke] [--out F]

Writes ``BENCH_elastic.json`` — the artifact CI uploads.
"""

from __future__ import annotations

import argparse
import tempfile
from pathlib import Path
from typing import Dict, List

from benchmarks.common import BenchResult, Claim, print_result, write_bench_json

OUT = Path(__file__).resolve().parents[1] / "BENCH_elastic.json"

BATCH, SEQ, MB = 16, 512, 8


def _two_region_fleet(per_region: int = 4) -> List:
    from repro.core.energy.devices import LAPTOP_M2PRO, SMARTPHONE_SD888
    from repro.core.sched.carbon_aware import FleetDevice
    fleet = []
    for i in range(2 * per_region):
        region = ("europe", "north_america")[i % 2]
        spec = (LAPTOP_M2PRO, SMARTPHONE_SD888)[(i // 2) % 2]
        fleet.append(FleetDevice(spec=spec, region=region, device_id=i))
    return fleet


def _search(cfg, fleet, topo, dp):
    from repro.core.placement import search_placement
    return search_placement(
        cfg, [d.spec for d in fleet], topology=topo,
        nodes=[str(d.device_id) for d in fleet], data_parallel=dp,
        batch=BATCH, seq_len=SEQ, microbatches=MB,
        collective="hierarchical")


def reshard_parity_mismatches() -> Dict[str, float]:
    """3-stage -> 2-stage -> 3-stage file round trip vs never resharding;
    returns mismatching-leaf counts (0 = bit-identical)."""
    import jax
    import numpy as np
    from repro.checkpoint import CheckpointSpec, ckpt
    from repro.configs.opt import opt_config
    from repro.models import params as P
    from repro.optim import adamw

    cfg = opt_config("opt-125m").reduced(num_layers=6, d_model=64,
                                         vocab_size=64)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, adamw.OptConfig())
    tree = {"params": params, "opt": opt}
    spec3 = CheckpointSpec(6, (0, 2, 4, 6), replication=1)
    spec2 = CheckpointSpec(6, (0, 3, 6))
    bad = 0
    dtype_bad = 0
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as d3:
        ckpt.save_for_placement(d1, 11, tree, spec3)
        ckpt.reshard(d1, spec2, tree, out_directory=d2)
        ckpt.reshard(d2, spec3, tree, out_directory=d3)
        back = ckpt.restore(d3, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            if not np.array_equal(np.asarray(x), np.asarray(y)):
                bad += 1
            if x.dtype != y.dtype:
                dtype_bad += 1
        n = len(jax.tree.leaves(tree))
    return {"leaves": n, "value_mismatches": bad,
            "dtype_mismatches": dtype_bad}


def churn_recovery(replication: int = 1) -> Dict[str, Dict[str, float]]:
    """Analytic 2-region churn: placement A loses a device, search finds
    placement B; price aware vs naive recovery onto B."""
    from repro.checkpoint import (CheckpointSpec, recovery_cost,
                                  state_layer_bytes, write_cost)
    from repro.configs import get_config
    from repro.core.net import NetParams, Topology

    cfg = get_config("opt-125m")
    fleet = _two_region_fleet()
    net = NetParams(wan_bw_Bps=5e6)
    topo = Topology.from_fleet(fleet, params=net)
    A = _search(cfg, fleet, topo, dp=2)
    layer_b, global_b = state_layer_bytes(cfg)
    spec = CheckpointSpec.from_placement(A, replication)
    wc = write_cost(topo, A, spec, layer_b, global_b)

    survivors = fleet[1:]                    # a europe laptop departs
    topo2 = Topology.from_fleet(survivors, params=net)
    B = _search(cfg, survivors, topo2, dp=2)
    kw = dict(old_spec=spec, layer_bytes=layer_b, global_bytes=global_b)
    aware = recovery_cost(topo2, B, **kw)
    naive = recovery_cost(topo2, B, naive=True, **kw)
    out = {}
    for tag, c in (("write", wc), ("aware", aware), ("naive", naive)):
        out[tag] = {"time_s": c.time_s, "bytes": c.bytes_moved,
                    "wan_bytes": c.wan_bytes, "energy_wh": c.energy_wh,
                    "transfers": c.transfers,
                    "per_region_bytes": dict(c.per_region_bytes)}
    out["meta"] = {"old": A.strategy, "old_boundaries": A.boundaries,
                   "new": B.strategy, "new_boundaries": B.boundaries,
                   "replication": replication,
                   "state_GB": (layer_b * cfg.num_layers + global_b) / 1e9}
    return out


def sim_recovery() -> Dict[str, Dict[str, float]]:
    """End-to-end orchestrator sim, aware vs naive restore pricing on the
    identical churn trajectory (pricing consumes no randomness)."""
    from repro.configs.opt import opt_config
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    cfg = opt_config("opt-125m")
    out = {}
    for tag, naive in (("aware", False), ("naive", True)):
        fleet = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6},
                           regions=("europe", "north_america"), seed=2)
        r = Orchestrator(cfg, fleet, SimConfig(
            total_steps=120, seed=5, checkpoint_interval=20,
            naive_restore=naive)).run()
        out[tag] = {
            "wall_s": r.wall_time_s, "restores": r.restores,
            "restore_s": r.restore_s_total,
            "restore_bytes": r.restore_bytes_moved,
            "restore_wan_bytes": r.restore_wan_bytes,
            "restore_bytes_by_region": dict(r.restore_bytes_by_region),
            "ckpt_writes": r.ckpt_writes,
            "ckpt_bytes_by_region": dict(r.ckpt_bytes_by_region),
            "recovery_energy_wh": r.recovery_energy_wh,
            "membership_changes": r.membership_changes}
    return out


def run(smoke: bool = False, out: Path = OUT) -> BenchResult:
    res = BenchResult(name="bench_elastic")

    parity = reshard_parity_mismatches()
    res.rows.append(dict({"scenario": "reshard 3->2->3"}, **parity))
    res.claims.append(Claim(
        "reshard round trip (3-stage -> 2-stage -> 3-stage) is "
        "bit-identical to never resharding (mismatching leaves)",
        float(parity["value_mismatches"] + parity["dtype_mismatches"]),
        0, 0))

    record: Dict[str, Dict] = {"config": {
        "model": "opt-125m", "batch": BATCH, "seq_len": SEQ,
        "microbatches": MB, "fleet": "2 regions x (2 laptops + 2 phones)",
        "wan_bw_Bps": 5e6}, "reshard_parity": parity}

    reps = [1] if smoke else [0, 1, 2]
    head = None
    for rep in reps:
        c = churn_recovery(replication=rep)
        record[f"churn r={rep}"] = c
        if rep == 1 or head is None:
            head = c
        for tag in ("aware", "naive"):
            res.rows.append({
                "scenario": f"churn r={rep}", "restore": tag,
                "time_s": c[tag]["time_s"],
                "GB_moved": c[tag]["bytes"] / 1e9,
                "wan_GB": c[tag]["wan_bytes"] / 1e9,
                "transfers": c[tag]["transfers"]})
    aware, naive = head["aware"], head["naive"]
    res.claims.append(Claim(
        "placement-aware restore moves strictly fewer cross-region bytes "
        "than naive full restore (2-region churn, x)",
        aware["wan_bytes"] / naive["wan_bytes"], 0.0, 0.999))
    res.claims.append(Claim(
        "placement-aware restore takes strictly less recovery wall-clock "
        "than naive full restore (x)",
        aware["time_s"] / naive["time_s"], 0.0, 0.999))

    if not smoke:
        sim = sim_recovery()
        record["sim"] = sim
        for tag in ("aware", "naive"):
            s = sim[tag]
            res.rows.append({
                "scenario": "orchestrator sim", "restore": tag,
                "time_s": s["restore_s"],
                "GB_moved": s["restore_bytes"] / 1e9,
                "wan_GB": s["restore_wan_bytes"] / 1e9,
                "transfers": s["restores"]})
        res.claims.append(Claim(
            "orchestrator sim: aware restore beats naive on wall-clock "
            "over the identical churn trajectory (x)",
            sim["aware"]["restore_s"] / max(sim["naive"]["restore_s"],
                                            1e-9), 0.0, 0.999))
        res.notes.append(
            f"sim moved {sim['aware']['restore_bytes']/1e9:.2f} GB aware "
            f"vs {sim['naive']['restore_bytes']/1e9:.2f} GB naive across "
            f"{sim['aware']['restores']} restores")

    res.notes.append(
        f"churn r=1: old {head['meta']['old_boundaries']} -> new "
        f"{head['meta']['new_boundaries']}; state "
        f"{head['meta']['state_GB']:.2f} GB; survivors keep shards local, "
        f"joiners fetch layer ranges from the nearest holder")

    write_bench_json(str(out), {"record": record}, claims=res.claims)
    res.notes.append(f"wrote {out.name}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer scenarios (CI)")
    ap.add_argument("--out", default=str(OUT),
                    help="where to write the JSON artifact")
    args = ap.parse_args()
    r = run(smoke=args.smoke, out=Path(args.out))
    print_result(r)
    if not r.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
