"""Fleet-scale simulation benchmark: the 10⁵-device control plane.

The claims under test (the massive-fleet perf rewrite's payoff):

1. **Parity** — the vectorized paths are *bit-identical* to the scalar
   references they replace: batched collective kernels vs the dict-
   topology cost models (all five algorithms), batched keyed fault
   draws vs per-entity ``default_rng`` construction, ``price_fleet_grid``
   vs ``dtfm.plan_placement``, and the FleetSim vectorized engine vs its
   per-entity scalar engine (whole trajectories).  0 mismatches.
2. **Speedup** — the churn/fault sweep at 10⁴ devices is ≥50× faster
   than the scalar per-entity path (the PR-7 draw contract, unchanged).
3. **Scale** — a 10⁵-device topology-aware placement search plus a
   200-round churn simulation completes under a fixed wall-clock
   budget (search cost scales with regions, not devices).
4. **Conclusions hold at 10⁵** — topology-aware placement beats
   round-robin on a shuffled-arrival fleet over a slow WAN, and
   async-quorum rounds beat fully-synchronous rounds under stragglers.

    PYTHONPATH=src python -m benchmarks.bench_fleet_scale [--smoke] [--out F]

Writes ``BENCH_fleet_scale.json`` — validated by ``repro.obs.validate``
alongside the other committed artifacts.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks.common import (BenchResult, Claim, print_result,
                               write_bench_json)
from repro.configs import get_config
from repro.core.faultinject.plan import FaultPlan
from repro.core.net import NetParams, batched_collective_cost
from repro.core.net.collectives import collective_cost
from repro.core.net.fleet_arrays import synthetic_fleet
from repro.core.placement import price_fleet_grid, search_placement_fleet
from repro.core.planner import dtfm
from repro.core.sched.fleet_sim import FleetSim, FleetSimConfig

OUT = Path(__file__).resolve().parents[1] / "BENCH_fleet_scale.json"

SEQ, MB = 128, 4
ALGORITHMS = ("ring", "tree", "hierarchical", "gossip", "allgather")

PLAN = FaultPlan(seed=7, straggler_frac=0.1,
                 straggler_slowdown=(4.0, 8.0), crash_prob=0.005,
                 rejoin_delay=(2, 5), link_flap_prob=0.05,
                 link_jitter_s=(0.5, 2.0))


def _feq(a: float, b: float) -> bool:
    return float(a) == float(b)


# ------------------------------------------------------------------ parity

def _parity_collectives(res: BenchResult) -> int:
    """Batched kernels vs scalar cost models on overlapping groups."""
    mism = 0
    rng = np.random.default_rng(0)
    checked = 0
    for seed in (0, 1):
        fleet = synthetic_fleet(40, region_mix="shuffled", seed=seed,
                                params=NetParams(wan_bw_Bps=2e7))
        topo = fleet.to_topology()
        # overlapping random groups, caller order random (gossip keeps it)
        member_dev: List[int] = []
        member_grp: List[int] = []
        groups: List[List[int]] = []
        for g in range(6):
            size = int(rng.integers(1, 13))
            rows = rng.choice(fleet.num_devices, size=size, replace=False)
            groups.append([int(r) for r in rows])
            member_dev.extend(int(r) for r in rows)
            member_grp.extend([g] * size)
        nbytes = 5e7
        for algo in ALGORITHMS:
            b = batched_collective_cost(
                fleet, np.asarray(member_dev), np.asarray(member_grp),
                nbytes, algorithm=algo)
            for g, rows in enumerate(groups):
                nodes = [str(fleet.node_names[r]) for r in rows]
                s = collective_cost(topo, nodes, nbytes, algorithm=algo)
                i = b.group(g)
                checked += 1
                if not (_feq(b.time_s[i], s.time_s)
                        and _feq(b.wire_bytes[i], s.wire_bytes)
                        and _feq(b.wan_bytes[i], s.wan_bytes)):
                    mism += 1
                sel = b.member_group == g
                for d, busy, byts in zip(b.member_device[sel],
                                         b.busy_s[sel], b.bytes_dev[sel]):
                    name = str(fleet.node_names[int(d)])
                    if not (_feq(busy, s.per_device_busy_s[name])
                            and _feq(byts, s.per_device_bytes[name])):
                        mism += 1
    res.rows.append({"check": "collectives", "compared": checked,
                     "mismatches": mism})
    return mism


def _parity_faults(res: BenchResult) -> int:
    """Batched keyed draws vs per-entity default_rng draws."""
    mism = 0
    ents = list(range(150)) + ["node:a", "node:b", 2 ** 33]
    for t in (0, 3):
        pairs = [
            (PLAN.slowdown_batch(ents),
             [PLAN.slowdown(e) for e in ents]),
            (PLAN.crashes_batch(ents, t),
             [PLAN.crashes(e, t) for e in ents]),
            (PLAN.flaps_batch(ents, t),
             [PLAN.flaps(e, t) for e in ents]),
            (PLAN.jitter_batch(ents, t),
             [PLAN.jitter_s(e, t) for e in ents]),
            (PLAN.rejoin_after_batch(ents, t),
             [PLAN.rejoin_after(e, t) for e in ents]),
        ]
        plan_c = FaultPlan(seed=7, corrupt_prob=0.2)
        shards = list(range(40))
        holders = [f"h{i % 5}" for i in range(40)]
        pairs.append((plan_c.corrupts_batch(t, shards, holders),
                      [plan_c.corrupts(t, s, h)
                       for s, h in zip(shards, holders)]))
        for got, want in pairs:
            mism += int(np.sum(np.asarray(got) != np.asarray(want)))
    res.rows.append({"check": "fault draws",
                     "compared": 6 * 2 * len(ents), "mismatches": mism})
    return mism


def _parity_pricing(res: BenchResult, cfg) -> int:
    """price_fleet_grid vs dtfm.plan_placement on the same placement."""
    mism = 0
    rng = np.random.default_rng(3)
    checked = 0
    for seed in (0, 1, 2):
        fleet = synthetic_fleet(24, region_mix="shuffled", seed=seed,
                                params=NetParams(wan_bw_Bps=1e7))
        dp, S = 2, 4
        rows = rng.choice(24, size=dp * S, replace=False)
        grid = rows.reshape(dp, S)
        for algo in ("ring", "hierarchical"):
            fp = price_fleet_grid(fleet, cfg, grid, batch=16, seq_len=SEQ,
                                  microbatches=MB, collective=algo)
            spec = fp.to_spec(cfg)
            p = dtfm.plan_placement(cfg, spec, batch=16, seq_len=SEQ,
                                    microbatches=MB, collective=algo)
            checked += 1
            if not (_feq(fp.step_time_s, p.step_time_s)
                    and _feq(fp.wan_bytes_per_step, p.wan_bytes_per_step)
                    and _feq(fp.wire_bytes_per_step,
                             p.wire_bytes_per_step)
                    and fp.cross_region_edges
                    == spec.cross_region_edges()):
                mism += 1
    res.rows.append({"check": "grid pricing", "compared": checked,
                     "mismatches": mism})
    return mism


def _parity_sim(res: BenchResult) -> int:
    """FleetSim vectorized engine ≡ per-entity scalar engine."""
    mism = 0
    fleet = synthetic_fleet(400, region_mix="shuffled", seed=5)
    for mode in ("sync", "async"):
        sim = FleetSim(fleet, FleetSimConfig(
            rounds=12, seed=11, leave_prob=0.02, join_prob=0.3,
            mode=mode, quorum=0.8, fault_plan=PLAN))
        rv = sim.run("vectorized")
        rs = sim.run("scalar")
        if not rv.trajectory_equal(rs):
            mism += 1
        if rv.region_busy_s != rs.region_busy_s:
            mism += 1
    res.rows.append({"check": "fleet sim trajectories", "compared": 4,
                     "mismatches": mism})
    return mism


# ----------------------------------------------------------------- speedup

def _measure_sim(n: int, rounds: int, engine: str,
                 mode: str = "sync", quorum: float = 0.9):
    fleet = synthetic_fleet(n, region_mix="shuffled", seed=0)
    cfg = FleetSimConfig(rounds=rounds, seed=2, leave_prob=0.01,
                         join_prob=0.2, mode=mode, quorum=quorum,
                         fault_plan=PLAN)
    return FleetSim(fleet, cfg).run(engine)


def _speedup(res: BenchResult, smoke: bool) -> float:
    rounds = 5 if smoke else 20
    sizes = [1_000, 10_000] if smoke else [1_000, 10_000, 100_000]
    at_1e4 = {}
    for n in sizes:
        rv = _measure_sim(n, rounds, "vectorized")
        res.rows.append({"fleet": n, "engine": "vectorized",
                         "rounds": rounds, "sim_s": round(rv.elapsed_s, 3),
                         "ms_per_round":
                         round(rv.elapsed_s / rounds * 1e3, 2)})
        if n <= 10_000:       # the scalar path is the point: it can't scale
            rs = _measure_sim(n, rounds, "scalar")
            res.rows.append({"fleet": n, "engine": "scalar",
                             "rounds": rounds,
                             "sim_s": round(rs.elapsed_s, 3),
                             "ms_per_round":
                             round(rs.elapsed_s / rounds * 1e3, 2)})
            if n == 10_000:
                at_1e4 = {"vec": rv.elapsed_s, "scalar": rs.elapsed_s}
    return at_1e4["scalar"] / at_1e4["vec"]


# ------------------------------------------------------------------- scale

def _scale(res: BenchResult, cfg, smoke: bool) -> Dict[str, float]:
    n = 20_000 if smoke else 100_000
    rounds = 50 if smoke else 200
    dp = n // 8
    fleet = synthetic_fleet(n, region_mix="shuffled", seed=0,
                            params=NetParams(wan_bw_Bps=5e6))

    t0 = time.perf_counter()
    best = search_placement_fleet(fleet, cfg, data_parallel=dp,
                                  batch=2 * dp, seq_len=SEQ,
                                  microbatches=MB)
    search_s = time.perf_counter() - t0
    rr_step = best.search_stats["round_robin_step_time_s"]
    res.rows.append({
        "fleet": n, "check": "placement search",
        "search_s": round(search_s, 2),
        "pruned": int(best.search_stats["candidates_pruned"]),
        "ta_step_s": round(best.step_time_s, 2),
        "rr_step_s": round(rr_step, 2),
        "ta_wan_GB": round(best.wan_bytes_per_step / 1e9, 2),
        "rr_wan_GB":
        round(best.search_stats["round_robin_wan_bytes"] / 1e9, 2)})

    sim_cfg = dict(rounds=rounds, seed=2, leave_prob=0.01, join_prob=0.2,
                   fault_plan=PLAN)
    t0 = time.perf_counter()
    sync = FleetSim(fleet, FleetSimConfig(mode="sync",
                                          **sim_cfg)).run("vectorized")
    churn_s = time.perf_counter() - t0
    asyn = FleetSim(fleet, FleetSimConfig(mode="async", quorum=0.9,
                                          **sim_cfg)).run("vectorized")
    for tag, r in (("sync", sync), ("async q=0.9", asyn)):
        res.rows.append({
            "fleet": n, "check": f"churn sim ({tag})", "rounds": rounds,
            "sim_s": round(r.elapsed_s, 2),
            "modeled_wall_s": round(r.wall_time_s, 1),
            "mean_active": int(r.mean_active), "crashes": r.crashes})
    return {"n": n, "search_s": search_s, "churn_s": churn_s,
            "ta_rr_ratio": best.step_time_s / rr_step,
            "sync_async_ratio": sync.wall_time_s / asyn.wall_time_s,
            "strategy": best.strategy,
            "pruned": int(best.search_stats["candidates_pruned"])}


# --------------------------------------------------------------------- run

def run(smoke: bool = False, out: Path = OUT) -> BenchResult:
    res = BenchResult(name="bench_fleet_scale")
    cfg = get_config("opt-125m")

    mism = (_parity_collectives(res) + _parity_faults(res)
            + _parity_pricing(res, cfg) + _parity_sim(res))
    speedup = _speedup(res, smoke)
    sc = _scale(res, cfg, smoke)

    res.claims.append(Claim(
        "vectorized fleet paths (collective kernels, keyed fault draws, "
        "grid pricing, sim trajectories) are bit-identical to the "
        "scalar references: 0 mismatches", mism, 0, 0))
    res.claims.append(Claim(
        "churn/fault sweep at 1e4 devices is >=50x faster than the "
        "per-entity scalar path" if not smoke else
        "churn/fault sweep at 1e4 devices beats the per-entity scalar "
        "path (smoke: >=10x; full gate >=50x)",
        speedup, 50.0 if not smoke else 10.0, float("inf")))
    budget = 60.0 if smoke else 120.0
    res.claims.append(Claim(
        f"{sc['n']:.0e}-device topology-aware search + {200 if not smoke else 50}"
        f"-round churn sim completes in under {budget:.0f}s wall-clock",
        sc["search_s"] + sc["churn_s"], 0.0, budget))
    res.claims.append(Claim(
        "topology-aware placement beats round-robin on modeled step "
        "time at fleet scale (shuffled arrivals, slow WAN)",
        sc["ta_rr_ratio"], 0.0, 0.999))
    res.claims.append(Claim(
        "async quorum (q=0.9) beats fully-sync rounds under stragglers "
        "at fleet scale (modeled wall ratio sync/async)",
        sc["sync_async_ratio"], 1.5, float("inf")))

    res.notes.append(
        f"winner at {sc['n']:.0e} devices: {sc['strategy']} "
        f"(search {sc['search_s']:.2f}s, {sc['pruned']} candidate "
        f"orderings pruned by the O(regions) proxy ranking)")
    res.notes.append(
        f"speedup at 1e4 devices: {speedup:.1f}x (per-entity RNG "
        f"construction is the scalar bottleneck the batched keyed "
        f"streams remove)")

    write_bench_json(str(out), {"scale": sc, "speedup_1e4": speedup},
                     claims=res.claims)
    res.notes.append(f"wrote {out.name}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller fleets / fewer rounds (CI)")
    ap.add_argument("--out", default=str(OUT),
                    help="where to write the JSON artifact")
    args = ap.parse_args()
    r = run(smoke=args.smoke, out=Path(args.out))
    print_result(r)
    if not r.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
