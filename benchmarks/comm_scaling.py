"""Wide-area communication scaling — the net-subsystem benchmark.

Sweeps fleet size x collective algorithm x gradient compression x
local-update sync interval on a two-region edge fleet training OPT-1.3B
data-parallel, and prices every cell through the
:mod:`repro.core.net` topology/collective cost models.

Baseline: the seed planner's flat ``min(net_bw_Bps)`` pricing applied
to the sync this stack replaces — the fp32 pseudo-gradients/gradients
the trainer actually all-reduces (what ``optim.compress.wire_bytes``
charges for uncompressed fp32 grads), every step, no topology, no
compression.  The seed planner's own table used a bf16 wire
convention (``param_bytes(cfg, 2)``); the ratio against that stricter
baseline is reported as a note.

Headline claim: hierarchical allreduce + int8 compression + local SGD
(K=16) reduces modelled per-step wire time by >= 10x on a 16-device
two-region fleet.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.opt import opt_config
from repro.core import flops as F
from repro.core.energy.devices import LAPTOP_M2PRO
from repro.core.net import NetParams, Topology, sync_cost
from repro.core.sched.carbon_aware import FleetDevice
from repro.optim.compress import CompressConfig, wire_bytes_count

from benchmarks.common import BenchResult, Claim

BATCH, SEQ = 16, 512
REGIONS = ("europe", "north_america")
FLEET_SIZES = (4, 8, 16, 32)
COLLECTIVES = ("ring", "tree", "hierarchical")
COMPRESSORS = {"fp32": None, "int8": CompressConfig(method="int8"),
               "top1%": CompressConfig(method="topk", topk_fraction=0.01)}
SYNC_INTERVALS = (1, 16)
# transcontinental per-flow share of the region uplink: slower than the
# 10 MB/s LAN access links — the regime the paper's edge fleets live in
WAN = NetParams(wan_bw_Bps=4e6, wan_latency_s=0.05, wan_jitter_s=0.01)


def two_region_fleet(n: int) -> List[FleetDevice]:
    return [FleetDevice(spec=LAPTOP_M2PRO, region=REGIONS[i % 2],
                        device_id=i) for i in range(n)]


def two_region_topology(n: int) -> Topology:
    return Topology.from_fleet(two_region_fleet(n), params=WAN)


def run() -> BenchResult:
    cfg = opt_config("opt-1.3b")
    res = BenchResult("Comm scaling: collectives x compression x local SGD")
    n_elems = int(F.param_bytes(cfg, 1))

    # baseline: the seed's flat min-bandwidth pricing on the fp32
    # gradients an uncompressed every-step sync transmits
    seed_bw = LAPTOP_M2PRO.net_bw_Bps
    seed_wire_s = wire_bytes_count(n_elems, None, dtype_bytes=4) / seed_bw
    # the seed planner's own (stricter) bf16 wire convention
    seed_bf16_s = wire_bytes_count(n_elems, None, dtype_bytes=2) / seed_bw

    best: Dict[int, float] = {}
    for n in FLEET_SIZES:
        topo = two_region_topology(n)
        for alg in COLLECTIVES:
            for cname, ccfg in COMPRESSORS.items():
                for k in SYNC_INTERVALS:
                    c = sync_cost(topo, topo.devices, n_elems,
                                  algorithm=alg, compress=ccfg,
                                  dtype_bytes=4, sync_interval=k)
                    if n == 16 or (alg == "hierarchical"
                                   and cname == "int8"):
                        res.rows.append({
                            "devices": n, "collective": alg,
                            "compress": cname, "K": k,
                            "step_wire_s": c.time_s,
                            "wire_MB": c.wire_bytes / 1e6,
                            "wan_MB": c.wan_bytes / 1e6,
                            "vs_seed": seed_wire_s / c.time_s})
                    if alg == "hierarchical" and cname == "int8" \
                            and k == 16:
                        best[n] = c.time_s

    res.notes.append(
        f"flat-min-bw baseline: {seed_wire_s:.1f} s/step "
        f"({n_elems * 4 / 1e6:.0f} MB fp32 grads at "
        f"{seed_bw / 1e6:.0f} MB/s); under the seed planner's bf16 "
        f"wire convention {seed_bf16_s:.1f} s/step -> best stack is "
        f"{seed_bf16_s / best[16]:.1f}x against that")
    res.notes.append(
        "int8 wire bytes: "
        f"{wire_bytes_count(n_elems, COMPRESSORS['int8']) / 1e6:.0f} MB; "
        "hierarchical crosses the WAN O(regions) not O(devices) times; "
        "K=16 local SGD syncs once per 16 steps")

    res.claims.append(Claim(
        "hierarchical+int8+K=16 cuts per-step wire time >=10x vs "
        "every-step fp32 sync under the seed's flat min-bw pricing "
        "(16 devices, two regions)",
        seed_wire_s / best[16], 10.0, float("inf")))

    # sanity orderings the paper's systems argument rests on
    topo16 = two_region_topology(16)
    flat = sync_cost(topo16, topo16.devices, n_elems, algorithm="ring",
                     compress=None, dtype_bytes=4)
    hier = sync_cost(topo16, topo16.devices, n_elems,
                     algorithm="hierarchical", compress=None,
                     dtype_bytes=4)
    res.claims.append(Claim(
        "hierarchical <= flat ring on a two-region fleet",
        flat.time_s / hier.time_s, 1.0, float("inf")))
    res.claims.append(Claim(
        "hierarchical WAN bytes < ring WAN bytes (two regions, N=16)",
        flat.wan_bytes / hier.wan_bytes, 1.0 + 1e-9, float("inf")))
    return res


if __name__ == "__main__":
    from benchmarks.common import print_result
    result = run()
    print_result(result)
    raise SystemExit(0 if result.ok else 1)
