"""Fault-tolerance benchmark: deterministic replay, the async-vs-sync
straggler win, and self-healing checkpoint recovery.

Four claims gate the robustness subsystem:

1. **Deterministic replay** — the same seeded ``FaultPlan`` replays the
   async trainer and the orchestrator sim bit-identically (losses,
   virtual clock, fault counts): fault experiments are reproducible.
2. **Straggler win** — under injected stragglers (~10% of replicas 4-8x
   slower) plus crash/rejoin churn, bounded-staleness async local SGD
   sustains >= 1.5x the contributed tokens/s of the synchronous barrier
   on the modelled fleet clock, at matched final loss.
3. **Sync reduction** — with ``quorum = replicas`` and
   ``staleness_bound = 0`` the async engine's trajectory is
   bit-identical to the synchronous loop.
4. **Self-healing restore** — a checkpoint with corrupted + missing
   shard files restores bit-exactly by re-fetching from a neighbour
   holder, and the fetched bytes price through the WAN topology.

    PYTHONPATH=src python -m benchmarks.bench_faults [--smoke] [--out F]

Writes ``BENCH_faults.json`` — the artifact CI uploads.
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
from pathlib import Path
from typing import Dict

from benchmarks.common import BenchResult, Claim, print_result, write_bench_json

OUT = Path(__file__).resolve().parents[1] / "BENCH_faults.json"


def _cfg():
    from repro.configs.opt import opt_config
    return opt_config("opt-125m").reduced(num_layers=4, d_model=32,
                                          vocab_size=64)


def _tc(steps):
    from repro.train.trainer import TrainerConfig
    return TrainerConfig(steps=steps, batch=2, seq_len=16, log_every=0)


def _ls(**kw):
    from repro.train.local_sgd import LocalSGDConfig
    base = dict(inner_steps=2, nominal_step_s=0.1)
    base.update(kw)
    return LocalSGDConfig(**base)


def _train(tc, ls, plan=None):
    from repro.train.local_sgd import train_local_sgd
    return train_local_sgd(_cfg(), tc, ls, fault_plan=plan)


def straggler_win(smoke: bool) -> Dict:
    """Sync barrier vs bounded-staleness async under the same plan:
    ~10% stragglers (4-8x slower) + crash/rejoin churn."""
    from repro.core.faultinject import FaultPlan
    R = 4 if smoke else 10
    rounds = 4 if smoke else 8
    tc = _tc(steps=2 * rounds)
    # straggler_frac is a per-replica probability; seed 5 realizes
    # exactly 1 straggler (7x slower) out of R for both fleet sizes
    plan = FaultPlan(seed=5, straggler_frac=0.12, crash_prob=0.02)
    sync = _train(tc, _ls(replicas=R), plan)
    asyn = _train(tc, _ls(replicas=R, async_mode=True, quorum=R - 1,
                          staleness_bound=2), plan)
    return {
        "replicas": R, "rounds": rounds,
        "stragglers": sum(plan.is_straggler(r) for r in range(R)),
        "sync": {"tokens_per_s": sync.virtual_tokens_per_s,
                 "virtual_time_s": sync.virtual_time_s,
                 "final_loss": sync.final_loss,
                 "contributed_steps": sync.contributed_steps,
                 "fault_counts": sync.fault_counts},
        "async": {"tokens_per_s": asyn.virtual_tokens_per_s,
                  "virtual_time_s": asyn.virtual_time_s,
                  "final_loss": asyn.final_loss,
                  "contributed_steps": asyn.contributed_steps,
                  "outer_updates": asyn.outer_updates,
                  "dropped_stale": asyn.dropped_stale,
                  "late_merged": asyn.late_merged,
                  "resyncs": asyn.resyncs,
                  "fault_counts": asyn.fault_counts},
        "speedup": (asyn.virtual_tokens_per_s
                    / max(sync.virtual_tokens_per_s, 1e-12)),
        "loss_ratio": asyn.final_loss / sync.final_loss,
    }


def replay_fidelity(smoke: bool) -> Dict:
    """Run the async trainer and the orchestrator sim twice under one
    plan; count anything that differs (0 = bit-identical)."""
    from repro.configs.opt import opt_config
    from repro.core.faultinject import FaultPlan
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    plan = FaultPlan(seed=16, straggler_frac=0.5, crash_prob=0.4,
                     link_flap_prob=0.3)
    tc = _tc(steps=8)
    ls = _ls(replicas=3, async_mode=True, quorum=2, staleness_bound=1)
    a, b = _train(tc, ls, plan), _train(tc, ls, plan)
    mismatches = sum([a.losses != b.losses,
                      a.round_losses != b.round_losses,
                      a.virtual_time_s != b.virtual_time_s,
                      a.fault_counts != b.fault_counts])
    sim_mismatches = 0
    steps = 40 if smoke else 80
    splan = FaultPlan(seed=0, straggler_frac=0.3, crash_prob=0.02,
                      link_flap_prob=0.1, corrupt_prob=0.3)
    sim = SimConfig(total_steps=steps, seed=5, checkpoint_interval=20,
                    fault_plan=splan)
    cfg = opt_config("opt-125m")
    fl = lambda: make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6},
                            seed=2)
    ra = Orchestrator(cfg, fl(), sim).run()
    rb = Orchestrator(cfg, fl(), sim).run()
    sim_mismatches = sum([ra.wall_time_s != rb.wall_time_s,
                          ra.energy_wh != rb.energy_wh,
                          ra.membership_changes != rb.membership_changes,
                          ra.fault_counts != rb.fault_counts])
    return {"trainer_mismatches": mismatches,
            "trainer_fault_counts": a.fault_counts,
            "sim_mismatches": sim_mismatches,
            "sim_fault_counts": ra.fault_counts,
            "sim_crashes": ra.crashes,
            "sim_corrupted_shard_copies": ra.corrupted_shard_copies}


def sync_reduction() -> Dict:
    """quorum=all + staleness_bound=0 must reproduce the sync loop."""
    tc = _tc(steps=6)
    sync = _train(tc, _ls(replicas=3))
    asyn = _train(tc, _ls(replicas=3, async_mode=True, quorum=3,
                          staleness_bound=0))
    return {"loss_mismatches": sum(x != y for x, y in
                                   zip(sync.losses, asyn.losses))
            + abs(len(sync.losses) - len(asyn.losses)),
            "round_loss_mismatches": sum(
                x != y for x, y in zip(sync.round_losses,
                                       asyn.round_losses))
            + abs(len(sync.round_losses) - len(asyn.round_losses)),
            "rounds": sync.rounds}


def heal_roundtrip() -> Dict:
    """Corrupt 2 shard files + delete 1, heal from a neighbour holder,
    restore bit-exactly, price the fetched bytes over a 2-region WAN."""
    import jax
    import numpy as np
    from repro.checkpoint import (CheckpointSpec, HealReport, ckpt,
                                  heal_cost)
    from repro.core.energy.devices import LAPTOP_M2PRO
    from repro.core.faultinject import corrupt_file
    from repro.core.net import NetParams, Topology
    from repro.models import params as P
    from repro.optim import adamw

    cfg = _cfg()
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    tree = {"params": params,
            "opt": adamw.init_opt_state(params, adamw.OptConfig())}
    with tempfile.TemporaryDirectory() as td:
        primary, holder = Path(td) / "primary", Path(td) / "holder"
        ckpt.save_for_placement(str(primary), 9, tree,
                                CheckpointSpec(4, (0, 1, 2, 4),
                                               replication=1))
        shutil.copytree(primary, holder)
        files = sorted(p for p in (primary / "step_00000009").iterdir()
                       if p.suffix == ".npy")
        corrupt_file(files[0], seed=2)
        corrupt_file(files[1], seed=2)
        files[2].unlink()
        damaged = len(ckpt.damaged_files(str(primary), 9))
        rep = HealReport()
        back = ckpt.restore(str(primary), tree, step=9,
                            sources=[("n1", str(holder))],
                            heal_report=rep)
        mismatches = sum(
            not np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)))
    topo = Topology(params=NetParams(wan_bw_Bps=5e6))
    topo.add_device("n0", "europe", LAPTOP_M2PRO)
    topo.add_device("n1", "north_america", LAPTOP_M2PRO)
    cost = heal_cost(topo, [("n1", "n0", h["bytes"])
                            for h in rep.healed])
    return {"damaged_files": damaged, "healed": len(rep.healed),
            "unrecovered": len(rep.unrecovered),
            "restore_mismatches": mismatches,
            "bytes_fetched": rep.bytes_fetched,
            "heal_time_s": cost.time_s, "heal_wan_bytes": cost.wan_bytes,
            "heal_energy_wh": cost.energy_wh}


def run(smoke: bool = False, out: Path = OUT) -> BenchResult:
    res = BenchResult(name="bench_faults")
    record: Dict[str, Dict] = {"config": {
        "model": "opt-125m reduced (4L, d32)", "batch": 2, "seq_len": 16,
        "inner_steps": 2, "smoke": smoke}}

    rep = replay_fidelity(smoke)
    record["replay"] = rep
    res.rows.append({"scenario": "replay", "surface": "async trainer",
                     "mismatches": rep["trainer_mismatches"],
                     "faults": sum(rep["trainer_fault_counts"].values())})
    res.rows.append({"scenario": "replay", "surface": "orchestrator sim",
                     "mismatches": rep["sim_mismatches"],
                     "faults": sum(rep["sim_fault_counts"].values())})
    res.claims.append(Claim(
        "seeded FaultPlan replays bit-identically across the async "
        "trainer and the orchestrator sim (mismatching fields)",
        float(rep["trainer_mismatches"] + rep["sim_mismatches"]), 0, 0))

    sw = straggler_win(smoke)
    record["straggler_win"] = sw
    for tag in ("sync", "async"):
        res.rows.append({
            "scenario": f"stragglers R={sw['replicas']}", "mode": tag,
            "tokens_per_s": round(sw[tag]["tokens_per_s"], 1),
            "vclock_s": round(sw[tag]["virtual_time_s"], 2),
            "final_loss": round(sw[tag]["final_loss"], 4),
            "contributed": sw[tag]["contributed_steps"]})
    res.claims.append(Claim(
        "bounded-staleness async sustains >= 1.5x sync tokens/s under "
        "injected stragglers + churn (x)", sw["speedup"], 1.5,
        float("inf")))
    res.claims.append(Claim(
        "async final loss matches sync under faults (ratio)",
        sw["loss_ratio"], 0.9, 1.1))

    red = sync_reduction()
    record["sync_reduction"] = red
    res.rows.append({"scenario": "Q=all S=0 reduction",
                     "mismatches": red["loss_mismatches"]
                     + red["round_loss_mismatches"],
                     "rounds": red["rounds"]})
    res.claims.append(Claim(
        "quorum=all + staleness_bound=0 reduces the async engine "
        "exactly to the sync trajectory (mismatching losses)",
        float(red["loss_mismatches"] + red["round_loss_mismatches"]),
        0, 0))

    heal = heal_roundtrip()
    record["heal"] = heal
    res.rows.append({"scenario": "heal 2 corrupt + 1 missing",
                     "healed": heal["healed"],
                     "mismatches": heal["restore_mismatches"],
                     "MB_fetched": round(heal["bytes_fetched"] / 1e6, 3),
                     "heal_s": round(heal["heal_time_s"], 4)})
    res.claims.append(Claim(
        "corrupted/missing shards restore bit-exactly via neighbour "
        "re-fetch (unhealed + mismatching leaves)",
        float(heal["damaged_files"] - heal["healed"]
              + heal["unrecovered"] + heal["restore_mismatches"]), 0, 0))
    res.claims.append(Claim(
        "healed bytes price through the WAN topology (fetch seconds)",
        heal["heal_time_s"], 1e-9, float("inf")))

    res.notes.append(
        f"straggler win: {sw['stragglers']}/{sw['replicas']} replicas "
        f"4-8x slower; async {sw['speedup']:.2f}x sync tokens/s, "
        f"{sw['async']['dropped_stale']} stale deltas dropped, "
        f"{sw['async']['late_merged']} folded late")
    res.notes.append(
        f"sim under faults: {rep['sim_crashes']} forced crashes, "
        f"{rep['sim_corrupted_shard_copies']} corrupted shard copies "
        f"degraded recovery to surviving holders")
    write_bench_json(out, {"result": record, "rows": res.rows,
                           "notes": res.notes}, claims=res.claims)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", type=Path, default=OUT)
    args = ap.parse_args()
    res = run(smoke=args.smoke, out=args.out)
    print_result(res)
    raise SystemExit(0 if res.ok else 1)


if __name__ == "__main__":
    main()
