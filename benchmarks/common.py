"""Shared helpers for the paper-reproduction benchmarks.

Every benchmark module exposes ``run() -> BenchResult``; ``benchmarks.run``
orchestrates them and fails the process if any paper claim is violated.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence


@dataclass
class Claim:
    """A quantitative claim made by the paper, checked by a benchmark."""
    text: str                   # the claim, quoting the paper
    value: float                # what the framework derives
    lo: float                   # acceptance band
    hi: float

    @property
    def ok(self) -> bool:
        return self.lo <= self.value <= self.hi

    def __str__(self) -> str:
        mark = "PASS" if self.ok else "FAIL"
        return (f"  [{mark}] {self.text}: derived {self.value:.3g} "
                f"(accept [{self.lo:.3g}, {self.hi:.3g}])")


@dataclass
class BenchResult:
    name: str
    rows: List[Dict[str, Any]] = field(default_factory=list)
    claims: List[Claim] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.claims)


def fmt_table(rows: Sequence[Dict[str, Any]],
              cols: Optional[Sequence[str]] = None) -> str:
    if not rows:
        return "  (no rows)"
    if cols is None:
        seen = {}
        for r in rows:
            for k in r:
                seen.setdefault(k, None)
        cols = list(seen)
    else:
        cols = list(cols)
    def cell(v: Any) -> str:
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)
    data = [[cell(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(d[i]) for d in data))
              for i, c in enumerate(cols)]
    out = ["  " + "  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    out.append("  " + "  ".join("-" * w for w in widths))
    for d in data:
        out.append("  " + "  ".join(x.ljust(w) for x, w in zip(d, widths)))
    return "\n".join(out)


def print_result(res: BenchResult, cols: Optional[Sequence[str]] = None
                 ) -> None:
    print(f"\n=== {res.name} ===")
    print(fmt_table(res.rows, cols))
    for n in res.notes:
        print(f"  note: {n}")
    for c in res.claims:
        print(c)
