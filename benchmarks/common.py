"""Shared helpers for the paper-reproduction benchmarks.

Every benchmark module exposes ``run() -> BenchResult``; ``benchmarks.run``
orchestrates them and fails the process if any paper claim is violated.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence


def bench_meta() -> Dict[str, Any]:
    """Provenance stamp for every BENCH_*.json: git commit, UTC
    timestamp, jax version, backend, platform — what makes the bench
    trajectory comparable across PRs."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        commit = "unknown"
    import jax
    return {
        "commit": commit,
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                       time.gmtime()),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
    }


def write_bench_json(path: str, data: Dict[str, Any],
                     registry=None, claims=None) -> None:
    """Write a bench artifact with the uniform schema: the module's own
    payload + ``meta`` (provenance, see :func:`bench_meta`) + optional
    ``metrics`` (a ``repro.obs`` MetricsRegistry snapshot — histogram
    summaries with p50/p95/p99) + optional embedded ``claims`` verdicts
    (a list of :class:`Claim`) — the block ``repro.obs.validate``
    re-checks on every committed artifact, so a BENCH_*.json whose gates
    no longer hold fails CI without re-running the benchmark."""
    payload = dict(data)
    payload["meta"] = bench_meta()
    if registry is not None:
        payload["metrics"] = registry.snapshot()
    if claims is not None:
        payload["claims"] = [
            {"text": c.text, "value": c.value, "lo": c.lo, "hi": c.hi,
             "ok": c.ok} for c in claims]
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


@dataclass
class Claim:
    """A quantitative claim made by the paper, checked by a benchmark."""
    text: str                   # the claim, quoting the paper
    value: float                # what the framework derives
    lo: float                   # acceptance band
    hi: float

    @property
    def ok(self) -> bool:
        return self.lo <= self.value <= self.hi

    def __str__(self) -> str:
        mark = "PASS" if self.ok else "FAIL"
        return (f"  [{mark}] {self.text}: derived {self.value:.3g} "
                f"(accept [{self.lo:.3g}, {self.hi:.3g}])")


@dataclass
class BenchResult:
    name: str
    rows: List[Dict[str, Any]] = field(default_factory=list)
    claims: List[Claim] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.claims)


def fmt_table(rows: Sequence[Dict[str, Any]],
              cols: Optional[Sequence[str]] = None) -> str:
    if not rows:
        return "  (no rows)"
    if cols is None:
        seen = {}
        for r in rows:
            for k in r:
                seen.setdefault(k, None)
        cols = list(seen)
    else:
        cols = list(cols)
    def cell(v: Any) -> str:
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)
    data = [[cell(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(d[i]) for d in data))
              for i, c in enumerate(cols)]
    out = ["  " + "  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    out.append("  " + "  ".join("-" * w for w in widths))
    for d in data:
        out.append("  " + "  ".join(x.ljust(w) for x, w in zip(d, widths)))
    return "\n".join(out)


def print_result(res: BenchResult, cols: Optional[Sequence[str]] = None
                 ) -> None:
    print(f"\n=== {res.name} ===")
    print(fmt_table(res.rows, cols))
    for n in res.notes:
        print(f"  note: {n}")
    for c in res.claims:
        print(c)
