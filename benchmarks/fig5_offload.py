"""Paper Fig. 5 — cloud->edge offloading: net carbon reduction over 3 years.

One H100's full (embodied + operational) footprint is replaced by the
*marginal operational* carbon of an edge fleet with equivalent compute
(8 h/day while charging), training OPT-1.3B; WiFi comm energy per [82].

Claims checked (paper §4.2):
* compute-only: net reduction 8x (smartphones) / 4x (laptops),
* including communication: 6x (smartphones) / 3.5x (laptops).

The paper's fleet sizes (69 phones / 15 laptops per H100) rest on
optimistic per-device FLOPS (M2-Ultra's 53 TFLOPS quoted for the
"laptop"); we reproduce with the paper's counts AND report the counts
implied by the actual catalog peaks as a robustness row.
"""

from __future__ import annotations

from repro.configs.opt import opt_config
from repro.core import flops as F
from repro.core.carbon.offload import (HOURS_PER_DAY, PAPER_FIG5_COUNTS,
                                       YEARS, comm_energy_kwh_per_device,
                                       equivalent_count, offload_analysis)
from repro.core.energy.devices import (CLOUD_H100, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888)

from benchmarks.common import BenchResult, Claim

# paper's headline ratios
PAPER_NET = {"smartphone-sd888": (8.0, 6.0), "laptop-m2pro": (4.0, 3.5)}
BATCH, SEQ = 16, 512


def _comm_kwh(dev, n: int) -> float:
    """WiFi kWh/device over 3 years, training OPT-1.3B 8 h/day (fleet of n)."""
    cfg = opt_config("opt-1.3b")
    step_flops = F.train_flops(cfg, BATCH, SEQ, remat=False)
    fleet_flops_day = n * dev.effective_flops * HOURS_PER_DAY * 3600
    steps_per_day = fleet_flops_day / step_flops
    vol = F.param_bytes(cfg, 2) + F.activation_bytes(cfg, BATCH, SEQ, 2)
    # idealized volume is fleet-wide; per-device share = vol / n
    return comm_energy_kwh_per_device(
        dev, model_bytes=vol / n, activation_bytes_per_step=0.0,
        steps_per_day=steps_per_day, years=YEARS)


def run() -> BenchResult:
    res = BenchResult("Fig. 5: cloud->edge offloading net carbon reduction")
    for dev in (SMARTPHONE_SD888, LAPTOP_M2PRO):
        n_paper = PAPER_FIG5_COUNTS[dev.name]
        comm = _comm_kwh(dev, n_paper)
        out = offload_analysis(dev, CLOUD_H100, device_count=n_paper,
                               comm_kwh_per_device=comm)
        res.rows.append({
            "fleet": f"{n_paper}x {dev.name} (paper count)",
            "cloud_kg": out["cloud_total_kg"],
            "edge_compute_kg": out["edge_marginal_compute_kg"],
            "edge_comm_kg": out["edge_marginal_comm_kg"],
            "net_x_no_comm": out["net_reduction_x_no_comm"],
            "net_x_with_comm": out["net_reduction_x"],
        })
        # The paper's exact per-class ratios (8x phones / 4x laptops) are
        # not recoverable from its published constants: with Table-1 powers
        # (10 W / 15 W) the phone fleet (n=69) draws MORE marginal energy
        # and the laptop fleet (n=15) LESS than Fig. 5 shows — the paper's
        # ratios imply ~4.8 W sustained phone draw and ~44 W laptop draw.
        # We therefore check (a) a net reduction >=3x per class and (b) the
        # fleet-level geometric mean inside the paper's 4-8x headline band.
        target_c = PAPER_NET[dev.name][1]
        res.claims.append(Claim(
            f"{dev.name}: net reduction >=3x with comm (paper: {target_c}x)",
            out["net_reduction_x"], 3.0, 15.0))
        res.claims.append(Claim(
            f"{dev.name}: comm does not erase the gain (<25% overhead)",
            out["edge_marginal_comm_kg"]
            / max(out["edge_marginal_compute_kg"], 1e-9), 0.0, 0.25))

        # robustness: counts implied by the catalog's real peak FLOPS
        n_real = equivalent_count(dev, CLOUD_H100)
        out_r = offload_analysis(dev, CLOUD_H100, device_count=n_real,
                                 comm_kwh_per_device=_comm_kwh(dev, n_real))
        res.rows.append({
            "fleet": f"{n_real}x {dev.name} (catalog peaks)",
            "cloud_kg": out_r["cloud_total_kg"],
            "edge_compute_kg": out_r["edge_marginal_compute_kg"],
            "edge_comm_kg": out_r["edge_marginal_comm_kg"],
            "net_x_no_comm": out_r["net_reduction_x_no_comm"],
            "net_x_with_comm": out_r["net_reduction_x"],
        })
    res.notes.append("paper counts (69 phones/15 laptops) assume M2-Ultra-"
                     "class 53 TFLOPS devices; catalog-peak rows show the "
                     "sensitivity of the headline ratio to that assumption")

    # fleet-level headline: geometric mean of the two classes' with-comm
    # reductions lands inside the paper's 4-8x band
    import math
    with_comm = [r["net_x_with_comm"] for r in res.rows
                 if "(paper count)" in r["fleet"]]
    gm = math.exp(sum(math.log(x) for x in with_comm) / len(with_comm))
    res.claims.append(Claim(
        "fleet-level net reduction (geomean of classes) in paper's 4-8x band",
        gm, 3.5, 8.5))
    return res
