"""Serving benchmark: the fast path (prefix sharing + chunked prefill +
int8 KV) against its baselines, plus the original paged-vs-dense claims.

Seven claims gate the serving subsystem (writes ``BENCH_serve.json``,
including the claim verdicts, so CI can re-validate the artifact with
``repro.obs.validate``):

1. **throughput** — the continuous-batching engine beats sequential
   ``greedy_generate`` (one dense-cache generation per request) on
   aggregate tokens/s over the mixed-prefix trace.
2. **memory** — the paged cache's peak KV bytes stay strictly below the
   dense fixed-length cache at equal batch.
3. **numerics** — the Pallas flash-decode kernel (interpret mode on CPU)
   matches the ``chunked.py`` flash twin's last causal row within fp32
   tolerance on causal / GQA / sliding-window cases.
4. **fast path** — prefix sharing + chunked prefill reach >= 1.3x the
   engine tokens/s of the round-1 engine (token-by-token teacher
   forcing, no sharing) on a mixed-prefix trace — with **identical**
   greedy outputs at matched dtypes.
5. **int8 KV memory** — quantized pages + per-vector fp32 scales hold
   peak KV bytes <= 0.55x the bf16 pool at batch 4 on the same trace.
6. **int8 KV numerics** — flash-decode logits from the int8 cache stay
   within 5e-2 of the fp cache.
7. **tail latency** — under long-prompt arrival with pool pressure
   (preemption + re-prefill), chunked prefill + prefix hits cut p99
   inter-token latency vs token-by-token re-prefill.

All engine pairs are warmed up (both compiled step shapes) and reset
before the window, so the numbers measure steady-state serving, not
compilation.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] \
        [--out BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import platform
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, Claim, write_bench_json

FP32_TOL = 5e-5
INT8_LOGITS_TOL = 5e-2


def _mixed_prefix_trace(cfg, n: int, *, n_sys: int = 3, sys_len: int = 16,
                        tail_lo: int = 4, tail_hi: int = 12,
                        max_new: int = 12, seed: int = 42):
    """Shared-system-prompt workload: ``n_sys`` system prompts, each
    request one of them (round-robin, so arrivals interleave across
    prefixes) plus a unique tail — the serving pattern prefix caching is
    built for."""
    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    sys_prompts = [list(map(int, rng.randint(0, cfg.vocab_size, sys_len)))
                   for _ in range(n_sys)]
    reqs = []
    for i in range(n):
        tail_len = tail_lo + (5 * i) % max(tail_hi - tail_lo, 1)
        tail = list(map(int, rng.randint(0, cfg.vocab_size, tail_len)))
        reqs.append(Request(uid=f"r{i}", prompt=sys_prompts[i % n_sys] + tail,
                            max_new=max_new))
    return reqs


def _sequential_greedy(params, cfg, reqs, cache_len: int) -> Dict[str, float]:
    """One dense greedy_generate per request, batch 1 — the seed serving
    path.  ``cache_len`` is pinned so every request reuses one compile."""
    from repro.serve.step import greedy_generate
    greedy_generate(params, cfg, jnp.asarray([reqs[0].prompt], jnp.int32),
                    2, cache_len=cache_len).block_until_ready()   # warmup
    t0 = time.perf_counter()
    tokens = 0
    for r in reqs:
        out = greedy_generate(params, cfg,
                              jnp.asarray([r.prompt], jnp.int32),
                              r.max_new, cache_len=cache_len)
        out.block_until_ready()
        tokens += r.max_new
    wall = time.perf_counter() - t0
    return {"tokens": tokens, "wall_s": wall, "tokens_per_s": tokens / wall}


def _make_engine(params, cfg, *, slots: int, block: int, cache_len: int,
                 num_blocks: int = 0, **ecfg_kw):
    from repro.serve.engine import EngineConfig, ServeEngine
    from repro.serve.paged_cache import blocks_for
    per_seq = blocks_for(cache_len, block)
    ecfg = EngineConfig(max_slots=slots, block_size=block,
                        num_blocks=num_blocks or per_seq * slots + 2,
                        max_blocks_per_seq=per_seq, **ecfg_kw)
    eng = ServeEngine(params, cfg, ecfg)
    eng.warmup()             # both compiled step shapes + sampler
    eng.reset_stats()        # compile time/energy stays out of the window
    return eng


def _engine_run(eng, reqs) -> Tuple[Dict[str, float], Dict[str, List[int]]]:
    out = eng.run(list(reqs))
    s = eng.stats()
    assert len(out) == len(reqs), "engine dropped requests"
    row = {"tokens": int(s["tokens_generated"]), "wall_s": eng.wall_s,
           "tokens_per_s": s["tokens_per_s"], "steps": int(s["steps"]),
           "peak_cache_bytes": s["peak_cache_bytes"],
           "pool_bytes": s["pool_bytes"],
           "frag_tokens_peak": s["frag_tokens_peak"],
           "utilization_peak": s["utilization_peak"],
           "prefix_hit_rate": s["prefix_hit_rate"],
           "prefix_hit_tokens": s["prefix_hit_tokens"],
           "cow_forks": s["cow_forks_total"],
           "kv_bytes_saved": s["kv_bytes_saved"],
           "energy_j": s["energy_j"], "j_per_token": s["j_per_token"],
           "carbon_g": s["carbon_g"]}
    return row, {uid: c.tokens for uid, c in out.items()}


def _dense_cache_bytes(cfg, batch: int, cache_len: int) -> int:
    from repro.models import model as M
    shapes = M.abstract_cache(cfg, batch, cache_len)
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(shapes)))


def _int8_logits_error(params, cfg) -> float:
    """Teacher-force through fp32 and int8 paged caches with the Pallas
    flash-decode kernel (interpret off-TPU); max abs logits gap."""
    from repro.models import model as M
    from repro.serve.paged_cache import PagedKVCache
    B, S, bs = 2, 9, 4
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    pools = {"fp": M.init_paged_cache(cfg, 12, bs, jnp.float32),
             "q": M.init_paged_cache(cfg, 12, bs, jnp.int8)}
    kv = PagedKVCache(num_blocks=12, block_size=bs, max_slots=B,
                      max_blocks_per_seq=4)
    for s in range(B):
        kv.open_slot(s)
    last = {}
    for i in range(S):
        for s in range(B):
            assert kv.ensure_capacity(s)
        bt = jnp.asarray(kv.device_tables())
        sl = jnp.asarray(kv.seq_lens())
        for name in pools:
            last[name], pools[name] = M.decode_step_paged(
                params, cfg, pools[name], prompt[:, i:i + 1], bt, sl,
                attn_impl="pallas")
        for s in range(B):
            kv.commit_token(s)
    return float(jnp.max(jnp.abs(last["q"] - last["fp"])))


def _latency_scenario(params, cfg, *, chunk: int, sharing: bool
                      ) -> Dict[str, float]:
    """Long-prompt arrival under pool pressure: the pool holds ~2 of the
    3 slots' worth, so decoding sequences get preempted and must
    re-prefill their whole history.  Token-by-token re-prefill stalls
    the stream for O(prompt) steps (the p99 inter-token blowup); chunked
    re-prefill — usually a prefix-cache hit on top — compresses it."""
    from repro.serve.engine import Request
    rng = np.random.RandomState(5)
    reqs = [Request(uid=f"L{i}",
                    prompt=list(map(int, rng.randint(0, cfg.vocab_size,
                                                     28 + 4 * (i % 3)))),
                    max_new=10)
            for i in range(6)]
    cache_len = max(len(r.prompt) + r.max_new for r in reqs)
    eng = _make_engine(params, cfg, slots=3, block=4, cache_len=cache_len,
                       num_blocks=27, cache_dtype="float32",
                       prefill_chunk=chunk, prefix_sharing=sharing)
    _, _outs = _engine_run(eng, reqs)
    s = eng.stats()
    preempts = float(eng.metrics.counter("serve/preemptions").value)
    assert preempts > 0, "latency scenario must force preemption"
    return {"inter_token_p99_s": s.get("inter_token_p99_s", 0.0),
            "inter_token_p50_s": s.get("inter_token_p50_s", 0.0),
            "preemptions": preempts, "steps": s["steps"]}


def _kernel_numerics() -> List[Dict[str, Any]]:
    """flash-decode vs chunked.py last causal row, fp32."""
    from repro.kernels.flash_attention.chunked import chunked_attention
    from repro.kernels.flash_attention.decode import flash_decode_paged
    rows = []
    cases = [("causal", 4, 4, 32, 8, 37, 0),
             ("gqa", 8, 2, 64, 8, 29, 0),
             ("sliding_window", 4, 2, 64, 8, 41, 12)]
    for name, H, K, D, bs, L, window in cases:
        nb = -(-L // bs)
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (1, H, D))
        k_pages = jax.random.normal(ks[1], (nb + 1, bs, K, D))
        v_pages = jax.random.normal(ks[2], (nb + 1, bs, K, D))
        bt = jnp.asarray(1 + np.arange(nb, dtype=np.int32))[None]
        out = flash_decode_paged(q, k_pages, v_pages, bt,
                                 jnp.asarray([L], jnp.int32),
                                 window=window, pages_per_split=2,
                                 interpret=True)
        kd = k_pages[bt[0]].reshape(-1, K, D)[None, :L]
        vd = v_pages[bt[0]].reshape(-1, K, D)[None, :L]
        qd = jnp.zeros((1, L, H, D)).at[:, L - 1].set(q[0])
        ref = chunked_attention(qd, kd, vd, causal=True, window=window,
                                chunk=8)[0, L - 1]
        err = float(jnp.max(jnp.abs(out[0] - ref)))
        rows.append({"case": name, "H": H, "K": K, "D": D, "seq_len": L,
                     "window": window, "max_abs_err": err})
    return rows


def bench(n_requests: int, max_new: int, slots: int,
          prefill_chunk: int) -> Dict[str, Any]:
    from repro.configs import get_config
    from repro.models import params as P

    cfg = get_config("qwen2-7b-smoke")
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _mixed_prefix_trace(cfg, n_requests, max_new=max_new)
    cache_len = max(len(r.prompt) + r.max_new for r in reqs)

    out: Dict[str, Any] = {
        "config": {"model": cfg.name, "n_requests": n_requests,
                   "max_new": max_new, "slots": slots,
                   "prefill_chunk": prefill_chunk, "cache_len": cache_len,
                   "trace": "mixed_prefix (3 system prompts, round-robin)",
                   "backend": jax.default_backend(),
                   "platform": platform.platform()},
    }
    out["sequential_greedy"] = _sequential_greedy(params, cfg, reqs,
                                                  cache_len)

    # fast path vs the round-1 engine: same code, matched fp32 KV, greedy
    # -> outputs must be IDENTICAL; only the schedule and block mapping
    # differ.  (The round-1 engine is prefill_chunk=1 + sharing off.)
    fast = _make_engine(params, cfg, slots=slots, block=8,
                        cache_len=cache_len, cache_dtype="float32",
                        prefill_chunk=prefill_chunk, prefix_sharing=True)
    out["engine_fast"], toks_fast = _engine_run(fast, reqs)
    base = _make_engine(params, cfg, slots=slots, block=8,
                        cache_len=cache_len, cache_dtype="float32",
                        prefill_chunk=1, prefix_sharing=False)
    out["engine_round1"], toks_base = _engine_run(base, reqs)
    mismatched = [u for u in toks_base if toks_base[u] != toks_fast[u]]
    assert not mismatched, f"fast path changed greedy outputs: {mismatched}"
    out["outputs_identical"] = True
    out["speedup_fast_vs_round1"] = (
        out["engine_fast"]["tokens_per_s"]
        / out["engine_round1"]["tokens_per_s"])
    out["speedup_engine_vs_sequential"] = (
        out["engine_fast"]["tokens_per_s"]
        / out["sequential_greedy"]["tokens_per_s"])

    # int8 KV at batch 4: peak bytes vs the bf16 pool, same trace
    q8 = _make_engine(params, cfg, slots=4, block=8, cache_len=cache_len,
                      cache_dtype="int8", prefill_chunk=prefill_chunk)
    out["engine_int8_b4"], _ = _engine_run(q8, reqs)
    bf = _make_engine(params, cfg, slots=4, block=8, cache_len=cache_len,
                      cache_dtype="bfloat16", prefill_chunk=prefill_chunk)
    out["engine_bf16_b4"], _ = _engine_run(bf, reqs)
    out["int8_peak_kv_ratio"] = (out["engine_int8_b4"]["peak_cache_bytes"]
                                 / out["engine_bf16_b4"]["peak_cache_bytes"])
    out["int8_flash_decode_max_logits_err"] = _int8_logits_error(params, cfg)

    # tail latency under preemption + re-prefill
    out["latency_chunked"] = _latency_scenario(params, cfg,
                                               chunk=prefill_chunk,
                                               sharing=True)
    out["latency_token_by_token"] = _latency_scenario(params, cfg, chunk=1,
                                                      sharing=False)
    out["p99_inter_token_ratio"] = (
        out["latency_chunked"]["inter_token_p99_s"]
        / max(out["latency_token_by_token"]["inter_token_p99_s"], 1e-12))

    # memory claim at matched dtype: the bf16 engine vs the dense bf16
    # cache (the fast/round-1 pair runs fp32 KV for exact output parity)
    out["dense_cache_bytes_equal_batch"] = _dense_cache_bytes(
        cfg, 4, cache_len)
    out["paged_over_dense_bytes"] = (
        out["engine_bf16_b4"]["peak_cache_bytes"]
        / out["dense_cache_bytes_equal_batch"])
    out["kernel_numerics"] = _kernel_numerics()
    return out


def run(n_requests: int = 12, max_new: int = 16, slots: int = 4,
        prefill_chunk: int = 8,
        out_path: str = "BENCH_serve.json") -> BenchResult:
    data = bench(n_requests, max_new, slots, prefill_chunk)

    res = BenchResult(name="bench_serve")
    for variant, key in (("sequential_greedy", "sequential_greedy"),
                         ("engine_fast", "engine_fast"),
                         ("engine_round1", "engine_round1"),
                         ("engine_int8_b4", "engine_int8_b4")):
        res.rows.append({"variant": variant,
                         **{k: v for k, v in data[key].items()
                            if k not in ("pool_bytes",)}})
    for r in data["kernel_numerics"]:
        res.rows.append({"variant": f"flash_decode/{r['case']}",
                         "max_abs_err": r["max_abs_err"]})
    res.notes.append(
        f"fast path vs round-1 engine: "
        f"{data['speedup_fast_vs_round1']:.2f}x tokens/s "
        f"({data['engine_fast']['steps']} vs "
        f"{data['engine_round1']['steps']} steps, prefix hit rate "
        f"{100 * data['engine_fast']['prefix_hit_rate']:.0f}%, identical "
        f"greedy outputs)")
    res.notes.append(
        f"int8 KV: {data['int8_peak_kv_ratio']:.3f}x peak bytes at batch "
        f"4, flash-decode logits err "
        f"{data['int8_flash_decode_max_logits_err']:.3g}")
    res.notes.append(
        f"p99 inter-token under preemption: "
        f"{data['latency_chunked']['inter_token_p99_s'] * 1e3:.1f} ms "
        f"chunked vs "
        f"{data['latency_token_by_token']['inter_token_p99_s'] * 1e3:.1f} "
        f"ms token-by-token")
    res.claims.append(Claim(
        text="continuous-batching engine beats sequential greedy_generate "
             "on aggregate tokens/s (mixed-prefix trace)",
        value=data["speedup_engine_vs_sequential"], lo=1.05,
        hi=float("inf")))
    res.claims.append(Claim(
        text="paged KV peak bytes strictly below dense fixed-length cache "
             "at equal batch (ratio)",
        value=data["paged_over_dense_bytes"], lo=0.0, hi=0.999))
    worst = max(r["max_abs_err"] for r in data["kernel_numerics"])
    res.claims.append(Claim(
        text="flash-decode kernel matches chunked reference "
             "(fp32 max abs err, causal/GQA/sliding-window)",
        value=worst, lo=0.0, hi=FP32_TOL))
    res.claims.append(Claim(
        text="prefix sharing + chunked prefill >= 1.3x engine tokens/s vs "
             "round-1 engine on the mixed-prefix trace (identical greedy "
             "outputs, matched dtypes)",
        value=data["speedup_fast_vs_round1"], lo=1.3, hi=float("inf")))
    res.claims.append(Claim(
        text="int8 KV blocks hold peak KV bytes <= 0.55x the bf16 pool at "
             "batch 4",
        value=data["int8_peak_kv_ratio"], lo=0.0, hi=0.55))
    res.claims.append(Claim(
        text="flash-decode logits from the int8 cache within 5e-2 of the "
             "fp cache",
        value=data["int8_flash_decode_max_logits_err"], lo=0.0,
        hi=INT8_LOGITS_TOL))
    res.claims.append(Claim(
        text="chunked prefill (+prefix hits) cuts p99 inter-token latency "
             "vs token-by-token under long-prompt arrival with preemption "
             "(ratio)",
        value=data["p99_inter_token_ratio"], lo=0.0, hi=0.9))

    # embed the verdicts so repro.obs.validate can re-check the artifact
    write_bench_json(out_path, data, claims=res.claims)
    res.notes.append(f"wrote {out_path}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer, shorter requests)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        res = run(n_requests=8, max_new=12, slots=4, out_path=args.out)
    else:
        res = run(out_path=args.out)
    from benchmarks.common import print_result
    print_result(res)
    if not res.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
