"""Serving benchmark: continuous batching + paged KV vs the dense path.

Three claims gate the serving subsystem (writes ``BENCH_serve.json``):

1. **throughput** — the continuous-batching engine beats sequential
   ``greedy_generate`` (one dense-cache generation per request) on
   aggregate tokens/s over a mixed-length request set.  Both paths are
   warmed up first, so the window measures steady-state serving, not
   compilation.
2. **memory** — the paged cache's peak KV bytes stay strictly below the
   dense fixed-length cache at equal batch (the dense layout must size
   every slot to the worst-case sequence; pages only exist once written).
3. **numerics** — the Pallas flash-decode kernel (interpret mode on CPU)
   matches the ``chunked.py`` flash twin's last causal row within fp32
   tolerance on causal / GQA / sliding-window cases.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] \
        [--out BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import platform
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, Claim, write_bench_json

FP32_TOL = 5e-5


def _requests(cfg, n: int, max_prompt: int, max_new_hi: int):
    from repro.serve.engine import Request
    reqs = []
    for i in range(n):
        L = 4 + (5 * i) % max(max_prompt - 3, 1)
        m = 8 + (7 * i) % max(max_new_hi - 7, 1)
        toks = np.random.RandomState(1000 + i).randint(0, cfg.vocab_size, L)
        reqs.append(Request(uid=f"r{i}", prompt=list(map(int, toks)),
                            max_new=m))
    return reqs


def _sequential_greedy(params, cfg, reqs, cache_len: int) -> Dict[str, float]:
    """One dense greedy_generate per request, batch 1 — the seed serving
    path.  ``cache_len`` is pinned so every request reuses one compile."""
    from repro.serve.step import greedy_generate
    greedy_generate(params, cfg, jnp.asarray([reqs[0].prompt], jnp.int32),
                    2, cache_len=cache_len).block_until_ready()   # warmup
    t0 = time.perf_counter()
    tokens = 0
    for r in reqs:
        out = greedy_generate(params, cfg,
                              jnp.asarray([r.prompt], jnp.int32),
                              r.max_new, cache_len=cache_len)
        out.block_until_ready()
        tokens += r.max_new
    wall = time.perf_counter() - t0
    return {"tokens": tokens, "wall_s": wall, "tokens_per_s": tokens / wall}


def _engine_run(params, cfg, reqs, *, slots: int, block: int,
                cache_len: int) -> Dict[str, float]:
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    from repro.serve.paged_cache import blocks_for
    per_seq = blocks_for(cache_len, block)
    ecfg = EngineConfig(max_slots=slots, block_size=block,
                        num_blocks=per_seq * slots + 2,
                        max_blocks_per_seq=per_seq)
    eng = ServeEngine(params, cfg, ecfg)
    eng.run([Request(uid="_warm", prompt=[1, 2, 3], max_new=2)])   # warmup
    eng.reset_stats()        # compile time/energy stays out of the window

    eng.run(reqs)
    s = eng.stats()
    assert len(eng.completions) == len(reqs), "engine dropped requests"
    return {"tokens": int(s["tokens_generated"]), "wall_s": eng.wall_s,
            "tokens_per_s": s["tokens_per_s"], "steps": int(s["steps"]),
            "peak_cache_bytes": s["peak_cache_bytes"],
            "pool_bytes": s["pool_bytes"],
            "frag_tokens_peak": s["frag_tokens_peak"],
            "utilization_peak": s["utilization_peak"],
            "energy_j": s["energy_j"], "j_per_token": s["j_per_token"],
            "carbon_g": s["carbon_g"]}


def _dense_cache_bytes(cfg, batch: int, cache_len: int) -> int:
    from repro.models import model as M
    shapes = M.abstract_cache(cfg, batch, cache_len)
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(shapes)))


def _kernel_numerics() -> List[Dict[str, Any]]:
    """flash-decode vs chunked.py last causal row, fp32."""
    from repro.kernels.flash_attention.chunked import chunked_attention
    from repro.kernels.flash_attention.decode import flash_decode_paged
    rows = []
    cases = [("causal", 4, 4, 32, 8, 37, 0),
             ("gqa", 8, 2, 64, 8, 29, 0),
             ("sliding_window", 4, 2, 64, 8, 41, 12)]
    for name, H, K, D, bs, L, window in cases:
        nb = -(-L // bs)
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (1, H, D))
        k_pages = jax.random.normal(ks[1], (nb + 1, bs, K, D))
        v_pages = jax.random.normal(ks[2], (nb + 1, bs, K, D))
        bt = jnp.asarray(1 + np.arange(nb, dtype=np.int32))[None]
        out = flash_decode_paged(q, k_pages, v_pages, bt,
                                 jnp.asarray([L], jnp.int32),
                                 window=window, pages_per_split=2,
                                 interpret=True)
        kd = k_pages[bt[0]].reshape(-1, K, D)[None, :L]
        vd = v_pages[bt[0]].reshape(-1, K, D)[None, :L]
        qd = jnp.zeros((1, L, H, D)).at[:, L - 1].set(q[0])
        ref = chunked_attention(qd, kd, vd, causal=True, window=window,
                                chunk=8)[0, L - 1]
        err = float(jnp.max(jnp.abs(out[0] - ref)))
        rows.append({"case": name, "H": H, "K": K, "D": D, "seq_len": L,
                     "window": window, "max_abs_err": err})
    return rows


def bench(n_requests: int, max_prompt: int, max_new: int, slots: int
          ) -> Dict[str, Any]:
    from repro.configs import get_config
    from repro.models import params as P

    cfg = get_config("qwen2-7b-smoke")
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, n_requests, max_prompt, max_new)
    cache_len = max(len(r.prompt) + r.max_new for r in reqs)

    out: Dict[str, Any] = {
        "config": {"model": cfg.name, "n_requests": n_requests,
                   "max_prompt": max_prompt, "max_new": max_new,
                   "slots": slots, "cache_len": cache_len,
                   "backend": jax.default_backend(),
                   "platform": platform.platform()},
    }
    out["sequential_greedy"] = _sequential_greedy(params, cfg, reqs,
                                                  cache_len)
    out["engine"] = _engine_run(params, cfg, reqs, slots=slots, block=8,
                                cache_len=cache_len)
    out["dense_cache_bytes_equal_batch"] = _dense_cache_bytes(
        cfg, slots, cache_len)
    out["speedup_engine_vs_sequential"] = (
        out["engine"]["tokens_per_s"]
        / out["sequential_greedy"]["tokens_per_s"])
    out["paged_over_dense_bytes"] = (
        out["engine"]["peak_cache_bytes"]
        / out["dense_cache_bytes_equal_batch"])
    out["kernel_numerics"] = _kernel_numerics()
    return out


def run(n_requests: int = 12, max_prompt: int = 20, max_new: int = 24,
        slots: int = 4, out_path: str = "BENCH_serve.json") -> BenchResult:
    data = bench(n_requests, max_prompt, max_new, slots)
    write_bench_json(out_path, data)

    res = BenchResult(name="bench_serve")
    res.rows.append({"variant": "sequential_greedy",
                     **data["sequential_greedy"]})
    res.rows.append({"variant": "engine",
                     **{k: v for k, v in data["engine"].items()
                        if k not in ("pool_bytes",)}})
    for r in data["kernel_numerics"]:
        res.rows.append({"variant": f"flash_decode/{r['case']}",
                         "max_abs_err": r["max_abs_err"]})
    res.notes.append(f"wrote {out_path}")
    res.notes.append(
        f"engine vs sequential greedy: "
        f"{data['speedup_engine_vs_sequential']:.2f}x tokens/s; paged peak "
        f"{data['engine']['peak_cache_bytes']/1e6:.2f} MB vs dense "
        f"{data['dense_cache_bytes_equal_batch']/1e6:.2f} MB at batch "
        f"{slots}")
    res.claims.append(Claim(
        text="continuous-batching engine beats sequential greedy_generate "
             "on aggregate tokens/s (mixed-length requests)",
        value=data["speedup_engine_vs_sequential"], lo=1.05,
        hi=float("inf")))
    res.claims.append(Claim(
        text="paged KV peak bytes strictly below dense fixed-length cache "
             "at equal batch (ratio)",
        value=data["paged_over_dense_bytes"], lo=0.0, hi=0.999))
    worst = max(r["max_abs_err"] for r in data["kernel_numerics"])
    res.claims.append(Claim(
        text="flash-decode kernel matches chunked reference "
             "(fp32 max abs err, causal/GQA/sliding-window)",
        value=worst, lo=0.0, hi=FP32_TOL))
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer, shorter requests)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        res = run(n_requests=8, max_prompt=12, max_new=16, slots=4,
                  out_path=args.out)
    else:
        res = run(out_path=args.out)
    from benchmarks.common import print_result
    print_result(res)
    if not res.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
