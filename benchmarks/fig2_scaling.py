"""Paper Fig. 2 — compute demand and carbon footprint vs model accuracy.

(a) PFLOP/s-day (training compute to finish in one day) vs MMLU accuracy,
(b) tCO2e per model — reported numbers where the model's paper gives one
    [18, 22, 69, 84], LLMCarbon-style estimate otherwise [30].

The paper's qualitative claims, made quantitative here:
* accuracy advancement costs exponential compute: compute grows by orders
  of magnitude across the model range while MMLU gains are linear,
* carbon footprint grows along the same exponential trend.
"""

from __future__ import annotations

import math

from repro.core.carbon import llmcarbon as LC

from benchmarks.common import BenchResult, Claim


def run() -> BenchResult:
    res = BenchResult("Fig. 2: compute & carbon vs accuracy scaling")
    table = LC.fig2_table()
    for name, row in table.items():
        res.rows.append({"model": name, **row})

    models = [m for m in LC.FIG2_MODELS if m.mmlu]
    models.sort(key=lambda m: m.mmlu)
    lo, hi = models[0], models[-1]

    compute_ratio = LC.pflops_day(hi) / LC.pflops_day(lo)
    acc_gain = hi.mmlu / lo.mmlu
    res.claims.append(Claim(
        "linear accuracy gain needs exponential compute "
        f"(compute x{compute_ratio:.0f} for accuracy x{acc_gain:.2f})",
        math.log10(compute_ratio), 3.0, 8.0))

    carbon_ratio = LC.footprint(hi) / LC.footprint(lo)
    res.claims.append(Claim(
        "carbon footprint grows exponentially with accuracy "
        f"(x{carbon_ratio:.0f} across the range)",
        math.log10(carbon_ratio), 2.0, 8.0))

    # estimator sanity: where official tCO2e exists, our LLMCarbon-style
    # estimate lands within 3x (methodology differences: grid CI, PUE, MFU)
    for m in LC.FIG2_MODELS:
        if m.reported_tco2e:
            est = LC.estimated_tco2e(m)
            res.rows.append({"model": f"{m.name} (est. check)",
                             "params_B": m.params / 1e9,
                             "tco2e": est,
                             "reported": m.reported_tco2e})
            res.claims.append(Claim(
                f"{m.name}: LLMCarbon estimate within 3x of reported",
                est / m.reported_tco2e, 1 / 3.0, 3.0))
    return res
