"""Benchmark harness — one module per paper table/figure (deliverable d).

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME]

Each module derives the paper's numbers from the framework's analytic
substrate and checks the paper's quantitative claims; the process exits
non-zero if any claim fails.  The roofline module additionally consumes
the multi-pod dry-run artifacts (deliverable g).
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_elastic, bench_faults, bench_fleet_scale,
                        bench_health, bench_placement,
                        bench_serve, bench_train_step, comm_scaling,
                        compress_ablation, fig2_scaling, fig3_idealized,
                        fig4_breakdown, fig5_offload, roofline,
                        sched_carbon, table1_single_device, table2_dtfm)
from benchmarks.common import print_result

MODULES = {
    "table1": table1_single_device,
    "table2": table2_dtfm,
    "fig2": fig2_scaling,
    "fig3": fig3_idealized,
    "fig4": fig4_breakdown,
    "fig5": fig5_offload,
    "sched": sched_carbon,
    "compress": compress_ablation,
    "roofline": roofline,
    "comm": comm_scaling,
    "train_step": bench_train_step,
    "placement": bench_placement,
    "serve": bench_serve,
    "elastic": bench_elastic,
    "faults": bench_faults,
    "health": bench_health,
    "fleet_scale": bench_fleet_scale,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()

    names = [args.only] if args.only else list(MODULES)
    failures = []
    for name in names:
        t0 = time.time()
        res = MODULES[name].run()
        dt = time.time() - t0
        print_result(res)
        print(f"  ({dt:.1f}s)")
        if not res.ok:
            failures.append(name)

    print("\n==== SUMMARY ====")
    for name in names:
        print(f"  {name:10s} {'FAIL' if name in failures else 'PASS'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
