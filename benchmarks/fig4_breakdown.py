"""Paper Fig. 4 — single-device 3-year carbon footprint: embodied vs
operational breakdown and absolute totals.

Claims checked (paper §4.2):
* edge-device footprint is dominated by embodied carbon (>80 % for the
  mobile device),
* operational carbon is significant for the data-center GPU,
* the data-center GPU has at least an order of magnitude higher absolute
  footprint than the laptop (for ~5x the compute capability).
"""

from __future__ import annotations

from repro.core.carbon.offload import fig4_table

from benchmarks.common import BenchResult, Claim


def run() -> BenchResult:
    res = BenchResult("Fig. 4: 3-year embodied/operational breakdown")
    fps = fig4_table()
    for name, fp in fps.items():
        res.rows.append({
            "device": name,
            "embodied_kg": fp.embodied_kg,
            "operational_kg": fp.operational_kg,
            "total_kg": fp.total_kg,
            "embodied_%": fp.embodied_pct,
        })

    phone = fps["smartphone-sd888"]
    laptop = fps["laptop-m2pro"]
    h100 = fps["cloud-h100"]

    res.claims.append(Claim("mobile footprint >80% embodied",
                            phone.embodied_pct, 80.0, 100.0))
    res.claims.append(Claim("laptop footprint mostly embodied",
                            laptop.embodied_pct, 60.0, 100.0))
    res.claims.append(Claim("DC GPU operational share significant (>40%)",
                            100.0 - h100.embodied_pct, 40.0, 100.0))
    res.claims.append(Claim(
        "DC GPU total >= 10x laptop total (order of magnitude)",
        h100.total_kg / laptop.total_kg, 10.0, 100.0))
    res.claims.append(Claim(
        "H100/M2 compute ratio ~5x (267 vs 53 TFLOPS, paper's basis)",
        267.0 / 53.0, 4.5, 5.5))
    return res
