"""§5 benchmark — carbon-aware orchestration vs carbon-blind baselines.

The paper's §5 argues (without a system) that carbon-blind scheduling
"can end up using devices in regions powered by high-carbon grids" and
that fault-tolerance strategies trade carbon against recovery latency.
This benchmark exercises the framework's orchestration layer to make both
arguments quantitative:

1. fleet selection: carbon-aware greedy vs throughput-greedy on a mixed
   fleet spanning clean (nordics) and dirty (india/east_asia) grids —
   report gCO2e/GFLOP at equal throughput targets,
2. end-to-end orchestration sim: 200 steps of OPT-125m over a churning
   fleet, carbon-aware admission vs admit-everyone,
3. fault-tolerance Pareto frontier (checkpoint/replicate/recompute).
"""

from __future__ import annotations

import numpy as np

from repro.configs.opt import opt_config
from repro.core.sched.carbon_aware import (FleetDevice, fleet_carbon_rate,
                                           select_fleet)
from repro.core.sched.faults import FaultModel, pareto_frontier
from repro.core.sched.orchestrator import Orchestrator, SimConfig, make_fleet
from repro.core.energy.devices import LAPTOP_M2PRO, SMARTPHONE_SD888

from benchmarks.common import BenchResult, Claim


def _mixed_fleet(n_per_region: int = 20) -> list:
    """Identical laptops spread across clean and dirty grids — isolates the
    grid-intensity knob, the thing carbon-blind scheduling cannot see."""
    regions = ("nordics", "europe", "north_america", "east_asia", "india")
    fleet = []
    for i in range(n_per_region * len(regions)):
        fleet.append(FleetDevice(spec=LAPTOP_M2PRO,
                                 region=regions[i % len(regions)],
                                 charging=True, device_id=i))
    return fleet


def run() -> BenchResult:
    res = BenchResult("§5: carbon-aware orchestration vs carbon-blind")

    # 1. selection quality at equal throughput (identical hardware, mixed
    #    grids: nordics 0.03 ... india 0.70 kgCO2e/kWh)
    fleet = _mixed_fleet()
    target = 20 * LAPTOP_M2PRO.effective_flops
    aware = select_fleet(fleet, target_flops=target, hour_utc=12.0)
    rate_aware = fleet_carbon_rate(aware)
    # carbon-blind: equal hardware -> any subset of the right size; take
    # a round-robin over regions (what a throughput-only scheduler does)
    priced = select_fleet(fleet, target_flops=float("inf"), hour_utc=12.0)
    by_id = {s.device_id: s for s in priced}
    acc, blind_sel = 0.0, []
    for d in fleet:                     # fleet order = round-robin regions
        if acc >= target:
            break
        s = by_id[d.device_id]
        blind_sel.append(s)
        acc += s.effective_flops
    rate_blind = fleet_carbon_rate(blind_sel)
    res.rows.append({"policy": "carbon-aware", "devices": len(aware),
                     "g_per_gflop": rate_aware})
    res.rows.append({"policy": "carbon-blind", "devices": len(blind_sel),
                     "g_per_gflop": rate_blind})
    res.claims.append(Claim(
        "carbon-aware selection cuts gCO2e/GFLOP vs carbon-blind (x)",
        rate_blind / rate_aware, 1.5, 50.0))

    # 2. end-to-end sim with churn: admission threshold set at the fleet's
    #    median carbon rate (keeps clean-grid members, rejects dirty-grid)
    from repro.core.sched.carbon_aware import carbon_rate
    cfg = opt_config("opt-125m")
    sim_fleet = make_fleet({"laptop-m2pro": 6, "smartphone-sd888": 12},
                           regions=("nordics", "india"), seed=1)
    rates = sorted(carbon_rate(d, 12.0, {})[0] for d in sim_fleet)
    threshold = rates[len(rates) // 2]
    base = SimConfig(total_steps=200, seed=1)
    aware_cfg = SimConfig(total_steps=200, seed=1,
                          carbon_threshold_g_per_gflop=threshold)
    r_blind = Orchestrator(cfg, sim_fleet, base).run()
    r_aware = Orchestrator(cfg, sim_fleet, aware_cfg).run()
    for name, r in (("admit-all", r_blind), ("carbon-aware", r_aware)):
        res.rows.append({"policy": f"sim/{name}",
                         "steps_h": r.throughput_steps_per_hour,
                         "carbon_g": r.carbon_kg * 1000,
                         "energy_wh": r.energy_wh,
                         "rework": r.rework_steps,
                         "churn": r.membership_changes})
        # recovery carbon attributed from bytes moved, no longer lumped
        # into step time: the sim reports per-region checkpoint/restore
        # traffic and its radio energy separately
        res.rows.append({
            "policy": f"sim/{name}/recovery",
            "energy_wh": r.recovery_energy_wh,
            "ckpt_GB": r.ckpt_bytes_written / 1e9,
            "restore_GB": r.restore_bytes_moved / 1e9,
            "restore_GB_by_region": "|".join(
                f"{k}:{v/1e9:.2f}"
                for k, v in sorted(r.restore_bytes_by_region.items()))})
    res.claims.append(Claim(
        "carbon-aware sim emits less CO2e for the same 200 steps (x)",
        r_blind.carbon_kg / max(r_aware.carbon_kg, 1e-12), 1.05, 500.0))
    res.claims.append(Claim(
        "sim attributes recovery traffic (checkpoint + restore bytes "
        "priced via core.net, GB > 0)",
        (r_blind.ckpt_bytes_written + r_blind.restore_bytes_moved) / 1e9,
        1e-6, 1e6))

    # 3. fault-tolerance Pareto, checkpoint terms priced from a real
    #    2-region placement over the wide-area model (no constants)
    from repro.core.net import Topology
    from repro.core.placement import search_placement
    from repro.core.sched.faults import priced_fault_model
    ft_fleet = sim_fleet[:8]
    topo = Topology.from_fleet(ft_fleet)
    placement = search_placement(
        cfg, [d.spec for d in ft_fleet], topology=topo,
        nodes=[str(d.device_id) for d in ft_fleet], data_parallel=2,
        batch=16, seq_len=512, microbatches=32, collective="hierarchical")
    fm = priced_fault_model(cfg, placement, lambda_per_device_hour=0.2,
                            step_time_s=30.0, stage_recompute_s=120.0,
                            replication=1)
    res.rows.append({"policy": "ft/priced-model",
                     "write_s": fm.ckpt_write_s,
                     "restore_naive_s": fm.ckpt_restore_s,
                     "restore_elastic_s": fm.elastic_restore_s})
    res.claims.append(Claim(
        "placement-aware restore is strictly cheaper than naive "
        "full restore in the priced fault model (x)",
        fm.elastic_restore_s / fm.ckpt_restore_s, 0.0, 0.999))
    frontier = pareto_frontier(fm)
    for s in frontier:
        res.rows.append({"policy": f"ft/{s.name}", "slowdown": s.slowdown,
                         "energy_overhead": s.energy_overhead})
    res.claims.append(Claim(
        "fault-tolerance frontier is a real trade-off (>=2 strategies)",
        float(len(frontier)), 2, 6))
    names = " ".join(s.name for s in frontier)
    res.notes.append(f"frontier: {names}")
    res.claims.append(Claim(
        "replication never carbon-optimal at edge churn rates "
        "(its energy overhead is max on the frontier)",
        max(frontier, key=lambda s: s.energy_overhead).energy_overhead,
        min(0.99, max(s.energy_overhead for s in frontier)), 10.0))
    return res
