"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads every ``experiments/dryrun/*_pod.json`` record and derives the three
roofline terms per (arch × shape) on the single-pod 16x16 v5e mesh:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s        [s]
    memory     = HLO_bytes_per_chip / HBM_bw             [s]
    collective = collective_bytes_per_chip / link_bw     [s]

Conventions:
* cost_analysis() and the HLO text are PER-DEVICE under SPMD, so the
  per-chip terms divide by per-chip peaks only (no further /chips).
* train records multiply by the microbatch trip count (recorded by the
  dry-run as *_corrected) — XLA's cost analysis counts while bodies once.
* MODEL_FLOPS = 6·N(_active)·D for train, 2·N·D prefill, 2·N_active·B
  decode (+ attention/SSD terms), from ``repro.core.flops``; the ratio
  MODEL/HLO exposes remat & redundancy waste.

Writes ``experiments/roofline.json`` consumed by EXPERIMENTS.md.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.configs.registry import get_config, input_shape
from repro.core import flops as F
from repro.core.energy.devices import TPU_V5E

from benchmarks.common import BenchResult, Claim

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT = Path(__file__).resolve().parents[1] / "experiments" / "roofline.json"

PEAK = TPU_V5E.peak_flops          # 197e12 bf16
HBM = TPU_V5E.hbm_bw_Bps           # 819e9
LINK = TPU_V5E.link_bw_Bps         # 50e9


def model_flops(arch: str, shape_name: str) -> float:
    """Global useful FLOPs for one step of (arch, shape).

    whisper-medium lowers with its TRUE geometry (1500-frame encoder,
    448-token decoder; see DESIGN.md §4) — the analytic side must match:
    the decoder sees seq 448 and cross-attends 1500 encoder positions.
    """
    cfg = get_config(arch)
    s = input_shape(shape_name)
    seq = s.seq_len
    if cfg.is_encoder_decoder:
        seq = cfg.max_target_positions                       # 448
        # cross-attention + encoder self-attention extra flops
        enc_tokens = s.global_batch * cfg.encoder_seq_len
        xattn = (2.0 * seq * cfg.encoder_seq_len * cfg.d_model * 2
                 * cfg.num_layers * s.global_batch)
    else:
        xattn = 0.0
    if s.kind == "train":
        base = F.train_flops(cfg, s.global_batch, seq, remat=False)
        return base + 3.0 * xattn
    if s.kind == "prefill":
        return F.fwd_flops(cfg, s.global_batch, seq) + xattn
    cache = seq if cfg.is_encoder_decoder else s.seq_len
    dec = F.decode_flops(cfg, s.global_batch, cache)
    if cfg.is_encoder_decoder:
        # per-token cross-attention reads the full encoder KV
        dec += (2.0 * cfg.encoder_seq_len * cfg.d_model * 2
                * cfg.num_layers * s.global_batch)
    return dec


def mitigation(dom: str, kind: str) -> str:
    return {
        "compute": "compute-bound is the roofline goal; raise MFU via larger "
                   "per-chip tiles / fewer remat recomputes",
        "memory": "cut bytes: fuse attention (chunked/flash), bf16 optimizer "
                  "moments, avoid materialized S x S scores",
        "collective": "reshard: move FSDP all-gathers off the critical path, "
                      "overlap with compute, or trade TP degree for DP",
    }[dom]


def analyse(rec: Dict[str, Any]) -> Dict[str, Any]:
    arch, shape_name = rec["arch"], rec["shape"]
    # prefer the trip-count-aware HLO walk; fall back to cost_analysis
    flops_dev = rec.get("hlo_flops_per_device",
                        rec.get("flops_per_device_corrected",
                                rec["flops_per_device"]))
    bytes_dev = rec.get("hlo_bytes_per_device",
                        rec.get("bytes_accessed_corrected",
                                rec["bytes_accessed_per_device"]))
    coll_dev = rec["collectives"]["total_bytes"]

    t_c = flops_dev / PEAK
    t_m = bytes_dev / HBM
    t_x = coll_dev / LINK
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]

    mf = model_flops(arch, shape_name)
    hlo_global = flops_dev * rec["chips"]
    return {
        "arch": arch, "shape": shape_name, "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "bound_step_s": max(t_c, t_m, t_x),
        "mfu_upper_bound": mf / (rec["chips"] * PEAK * max(t_c, t_m, t_x))
        if max(t_c, t_m, t_x) > 0 else 0.0,
        "mitigation": mitigation(dom, rec["kind"]),
    }


def load_records(suffix: str = "_pod.json") -> List[Dict[str, Any]]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob(f"*{suffix}")):
        stem = p.name[: -len(suffix)]
        # skip variant records (extra underscore-tagged runs)
        if any(stem.endswith(x) for x in ("_full_float32_default",)):
            continue
        recs.append(json.loads(p.read_text()))
    return recs


def run() -> BenchResult:
    res = BenchResult("Roofline: per (arch x shape) terms, 16x16 v5e pod")
    rows = []
    for rec in load_records():
        a = analyse(rec)
        rows.append(a)
        res.rows.append({
            "arch": a["arch"], "shape": a["shape"],
            "compute_s": a["compute_s"], "memory_s": a["memory_s"],
            "collective_s": a["collective_s"], "dominant": a["dominant"],
            "useful": a["useful_ratio"], "mfu_ub": a["mfu_upper_bound"],
        })
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(rows, indent=1))

    res.claims.append(Claim("all 33 applicable (arch x shape) pairs lowered "
                            "and analysed", float(len(rows)), 33, 33))
    n_train = sum(1 for r in rows if r["kind"] == "train")
    res.claims.append(Claim("every arch has a train_4k baseline",
                            float(n_train), 10, 10))
    res.notes.append(f"terms written to {OUT}")
    return res
