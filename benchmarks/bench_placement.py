"""Placement benchmark: topology-aware vs round-robin on a 2-region fleet.

The claim under test (the plan→place→execute refactor's payoff): on a
heterogeneous fleet spread over two regions joined by a slow WAN,
searching placements topology-aware — each pipeline's regions contiguous,
DP replicas carved region-first, non-uniform layer boundaries balancing
laptop/smartphone compute — strictly reduces BOTH modeled cross-region
bytes per step and modeled step time versus the naive round-robin
carve-up of the same fleet.  Energy and the local-SGD sync pricing ride
along as reported rows.

    PYTHONPATH=src python -m benchmarks.bench_placement [--smoke] [--out F]

Writes ``BENCH_placement.json`` next to ``BENCH_train_step.json`` — the
artifact CI uploads.
"""

from __future__ import annotations

import argparse
from pathlib import Path
from typing import Dict, List

from benchmarks.common import BenchResult, Claim, print_result, write_bench_json
from repro.configs import get_config
from repro.core.energy.devices import LAPTOP_M2PRO, SMARTPHONE_SD888
from repro.core.net import NetParams, Topology
from repro.core.placement import round_robin_placement, search_placement
from repro.core.planner import dtfm
from repro.core.sched.carbon_aware import FleetDevice

OUT = Path(__file__).resolve().parents[1] / "BENCH_placement.json"

BATCH, SEQ, MB = 16, 512, 8


def two_region_fleet(per_region: int = 4) -> List[FleetDevice]:
    """Heterogeneous 2-region fleet, caller order interleaving regions —
    the arrival order a naive (round-robin) assignment would consume."""
    fleet = []
    for i in range(2 * per_region):
        region = ("europe", "north_america")[i % 2]
        spec = (LAPTOP_M2PRO, SMARTPHONE_SD888)[(i // 2) % 2]
        fleet.append(FleetDevice(spec=spec, region=region, device_id=i))
    return fleet


def _measure(cfg, fleet, data_parallel: int, sync_interval: int
             ) -> Dict[str, Dict]:
    topo = Topology.from_fleet(fleet, params=NetParams(wan_bw_Bps=5e6))
    devices = [d.spec for d in fleet]
    nodes = [str(d.device_id) for d in fleet]
    kw = dict(batch=BATCH, seq_len=SEQ, microbatches=MB,
              collective="hierarchical", sync_interval=sync_interval)

    rr = round_robin_placement(cfg, devices, topology=topo, nodes=nodes,
                               data_parallel=data_parallel)
    ta = search_placement(cfg, devices, topology=topo, nodes=nodes,
                          data_parallel=data_parallel, **kw)
    out = {}
    for tag, spec in (("round_robin", rr), ("topology_aware", ta)):
        p = dtfm.plan_placement(cfg, spec, **kw)
        out[tag] = {
            "strategy": spec.strategy,
            "boundaries": spec.boundaries,
            "cross_region_edges": spec.cross_region_edges(),
            "step_time_s": p.step_time_s,
            "wan_bytes_per_step": p.wan_bytes_per_step,
            "wire_bytes_per_step": p.wire_bytes_per_step,
            "energy_wh_per_step": p.total_energy_wh_per_step,
            "comm_s_per_step": p.comm_s_per_step,
            "bubble_fraction": p.bubble_fraction,
        }
    return out


def run(smoke: bool = False, out: Path = OUT) -> BenchResult:
    res = BenchResult(name="bench_placement")
    cfg = get_config("opt-125m")

    scenarios = [("dp2xS4, K=1", 2, 1), ("dp2xS4, K=16", 2, 16)]
    if not smoke:
        scenarios += [("dp4xS2, K=1", 4, 1), ("dp1xS8, K=1", 1, 1)]

    record: Dict[str, Dict] = {"config": {
        "model": cfg.name, "batch": BATCH, "seq_len": SEQ,
        "microbatches": MB, "fleet": "2 regions x (2 laptops + 2 phones)",
        "wan_bw_Bps": 5e6}}
    head = None
    for tag, dp, k in scenarios:
        m = _measure(cfg, two_region_fleet(), dp, k)
        record[tag] = m
        if head is None:
            head = m
        for strat in ("round_robin", "topology_aware"):
            r = m[strat]
            res.rows.append({
                "scenario": tag, "placement": strat,
                "step_s": r["step_time_s"],
                "wan_MB_per_step": r["wan_bytes_per_step"] / 1e6,
                "xregion_edges": r["cross_region_edges"],
                "energy_wh": r["energy_wh_per_step"],
                "boundaries": "|".join(map(str, r["boundaries"])),
            })

    rr, ta = head["round_robin"], head["topology_aware"]
    res.claims.append(Claim(
        "topology-aware placement strictly reduces modeled cross-region "
        "bytes/step vs round-robin (2-region heterogeneous fleet)",
        ta["wan_bytes_per_step"] / rr["wan_bytes_per_step"],
        0.0, 0.999))
    res.claims.append(Claim(
        "topology-aware placement strictly reduces modeled step time "
        "vs round-robin (2-region heterogeneous fleet)",
        ta["step_time_s"] / rr["step_time_s"], 0.0, 0.9999))
    k16 = record["dp2xS4, K=16"]["topology_aware"]
    res.claims.append(Claim(
        "once local update (K=16) amortizes grad sync, the search "
        "recovers region-contiguous pipelines (0 cross-region stage "
        "boundaries)", k16["cross_region_edges"], 0, 0))
    res.notes.append(
        f"winning K=1 layout: {ta['strategy']}, boundaries "
        f"{ta['boundaries']} (non-uniform: laptops carry more layers "
        f"than phones); K=1 keeps DP sync intra-region and pays "
        f"activation WAN, K=16 flips to region-contiguous pipelines — "
        f"the cost model, not a heuristic, picks the crossing to pay")

    write_bench_json(str(out), {"record": record}, claims=res.claims)
    res.notes.append(f"wrote {out.name}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer scenarios (CI)")
    ap.add_argument("--out", default=str(OUT),
                    help="where to write the JSON artifact")
    args = ap.parse_args()
    r = run(smoke=args.smoke, out=Path(args.out))
    print_result(r)
    if not r.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
