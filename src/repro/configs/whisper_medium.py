"""Whisper-medium [arXiv:2212.04356] — encoder-decoder, conv frontend stub.

24+24L d_model=1024 16H (MHA) d_ff=4096 vocab=51865; encoder consumes 1500
mel-frame embeddings (conv frontend is a STUB per assignment); decoder max
448 positions with learned embeddings; GELU MLP, LayerNorm.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_activation="gelu",
    norm_type="layernorm",
    pos_embedding="learned",
    encoder_seq_len=1500,
    max_target_positions=448,
    frontend="audio_stub",
    qkv_bias=True,
    source="arXiv:2212.04356 (Whisper)",
)
