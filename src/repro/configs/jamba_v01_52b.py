"""Jamba-v0.1 (52B total) [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE.

32L d_model=4096; attention layer every 8 (offset 4) with GQA kv=8;
MoE 16 experts top-2 every other layer (offset 1); d_ff=14336; vocab=65536.

Adaptation note (see DESIGN.md): Jamba's mixer is Mamba-1; this framework
implements the SSD (Mamba2) dual form for all SSM layers — state-space
duality makes the two families computationally interchangeable at this
granularity, and SSD is the TPU-native (MXU-friendly) formulation.
"""

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_layer_period=8,
    attn_layer_offset=4,
    # layout measured per-arch (EXPERIMENTS.md §Perf B2/B6): jamba's MoE
    # dispatch lowers 4x cheaper when GSPMD propagates the buffer layout
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_ff_expert=14336,
                  layout="unconstrained"),
    moe_layer_period=2,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk_size=128),
    pos_embedding="none",    # Jamba uses no positional encoding
    source="arXiv:2403.19887 (Jamba)",
)
