"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 — M-RoPE with
(t,h,w) sections (16,24,24) over head_dim 128; dynamic-resolution vision
encoder is a STUB (``input_specs`` supplies patch embeddings).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    pos_embedding="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    tie_embeddings=True,
    source="arXiv:2409.12191 (Qwen2-VL)",
)
