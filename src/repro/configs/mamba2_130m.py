"""Mamba2-130m [arXiv:2405.21060] — attention-free SSD (state-space duality).

24L d_model=768, d_inner=1536 (expand 2), 24 SSD heads of dim 64,
ssm_state=128, vocab=50280.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,            # unused (attention-free); kept for bookkeeping
    num_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    pos_embedding="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk_size=128),
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba2 / SSD)",
)
