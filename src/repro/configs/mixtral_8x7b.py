"""Mixtral-8x7B [arXiv:2401.04088] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, SWA 4096.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=14336),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088 (Mixtral of Experts)",
)
