"""Architecture registry: the 10 assigned archs + the paper's OPT series."""

from repro.configs.registry import (ARCHS, INPUT_SHAPES, get_config,
                                    input_shape, list_archs)

__all__ = ["ARCHS", "INPUT_SHAPES", "get_config", "input_shape", "list_archs"]
