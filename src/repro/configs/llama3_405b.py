"""Llama-3.1-405B [arXiv:2407.21783] — dense GQA flagship.

126L d_model=16384 128H (GQA kv=8, head_dim 128) d_ff=53248 vocab=128256.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783 (Llama 3)",
)
