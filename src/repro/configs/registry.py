"""Registry: ``--arch <id>`` lookup plus the four assigned input shapes."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.models.config import ModelConfig

from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl_2b
from repro.configs.mamba2_130m import CONFIG as _mamba2_130m
from repro.configs.jamba_v01_52b import CONFIG as _jamba
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.qwen2_7b import CONFIG as _qwen2_7b
from repro.configs.qwen15_32b import CONFIG as _qwen15_32b
from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.opt import OPT_NAMES, opt_config

ARCHS: Dict[str, ModelConfig] = {
    "qwen2-vl-2b": _qwen2_vl_2b,
    "mamba2-130m": _mamba2_130m,
    "jamba-v0.1-52b": _jamba,
    "deepseek-v3-671b": _dsv3,
    "whisper-medium": _whisper,
    "llama3-405b": _llama3,
    "qwen2-7b": _qwen2_7b,
    "qwen1.5-32b": _qwen15_32b,
    "granite-3-2b": _granite,
    "mixtral-8x7b": _mixtral,
}
ASSIGNED = tuple(ARCHS)

for _n in OPT_NAMES:
    ARCHS[_n] = opt_config(_n)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: SSM / hybrid / sliding-window only.
LONG_CONTEXT_OK = ("mamba2-130m", "jamba-v0.1-52b", "mixtral-8x7b")


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    cfg.validate()
    return cfg


def list_archs(assigned_only: bool = False):
    return list(ASSIGNED) if assigned_only else sorted(ARCHS)


def shape_applicable(arch: str, shape: str) -> bool:
    """Whether (arch, shape) is part of the dry-run matrix (see DESIGN.md)."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True


def input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
