"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437].

61L d_model=7168, 128 heads MLA (q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128); first 3 layers dense (d_ff=18432); remaining 58 layers
MoE with 1 shared + 256 routed experts top-8 (expert d_ff=2048);
vocab=129280; multi-token prediction depth 1.
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,              # dense-layer FFN width
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    # layout="unconstrained": at 256 experts the sort-based dispatch
    # scatter shards best when GSPMD propagates from the (data-sharded)
    # token stream; hand-pinned buffer layouts cost 7-10x collective bytes
    # (EXPERIMENTS.md §Perf B2 — measured, both directions refuted).
    moe=MoEConfig(num_experts=256, experts_per_token=8,
                  num_shared_experts=1, d_ff_expert=2048,
                  layout="unconstrained"),
    first_dense_layers=3,
    mtp_depth=1,
    source="arXiv:2412.19437 (DeepSeek-V3)",
)
