"""OPT series [arXiv:2205.01068] — the paper's experimental models (§4.2).

Tables 1-2 and Fig. 3 of the paper train OPT-125m..OPT-30B on edge devices;
the analytic substrate (``repro.core``) re-derives those results from these
exact configs.  Geometry from the OPT paper, Table 1.
"""

from repro.models.config import ModelConfig

_OPT_GEOMETRY = {
    # name: (layers, d_model, heads, d_ff)
    "opt-125m": (12, 768, 12, 3072),
    "opt-350m": (24, 1024, 16, 4096),
    "opt-1.3b": (24, 2048, 32, 8192),
    "opt-2.7b": (32, 2560, 32, 10240),
    "opt-6.7b": (32, 4096, 32, 16384),
    "opt-13b": (40, 5120, 40, 20480),
    "opt-30b": (48, 7168, 56, 28672),
}


def opt_config(name: str) -> ModelConfig:
    layers, d, heads, ff = _OPT_GEOMETRY[name]
    return ModelConfig(
        name=name,
        arch_type="dense",
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=ff,
        vocab_size=50272,
        mlp_activation="gelu",
        norm_type="layernorm",
        pos_embedding="learned",
        max_target_positions=2048,
        tie_embeddings=True,
        qkv_bias=True,
        source="arXiv:2205.01068 (OPT)",
    )


OPT_NAMES = tuple(_OPT_GEOMETRY)
CONFIG = opt_config("opt-125m")
