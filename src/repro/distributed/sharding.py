"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Every parameter carries logical axis names (see ``repro.models.params``);
a *rule table* maps logical axes to mesh axes.  The engine enforces the two
GSPMD constraints that otherwise bite at scale:

* a mesh axis may appear at most once per PartitionSpec (first dim wins),
* a dim is only sharded if its size divides the mesh-axis extent —
  otherwise it silently falls back to replication (recorded for roofline
  honesty via :func:`sharding_report`).

Default strategy = FSDP over ``data`` (embed dim of every weight) combined
with Megatron tensor parallelism over ``model`` (heads / mlp / vocab /
experts).  The ``pod`` axis is pure data parallelism: params are replicated
across pods and gradients all-reduce over DCN, the standard multi-pod
pattern.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any

# logical axis -> candidate mesh axes (first that fits wins)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),          # FSDP / ZeRO-3 weight sharding
    "heads": ("model",),
    "kv_heads": ("model",),
    "head": (),
    "mlp": ("model",),
    "experts": ("model", "data"),   # expert parallelism; full EP when the
                                    # expert count covers model x data
                                    # (deepseek 256e on 256 chips)
    "mamba_inner": ("model",),
    "mamba_heads": ("model",),
    "state": (),
    "q_rank": ("model",),
    "kv_rank": ("model",),
    "layers": (),
    "batch": ("pod", "data"),
    "seq": (),
}

# Tensor-parallel-only variant (no FSDP): small models / serving
TP_ONLY_RULES = dict(DEFAULT_RULES, embed=())


def spec_for_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  mesh: Mesh, rules: Dict[str, Tuple[str, ...]]) -> P:
    used: set = set()
    parts: List[Optional[Tuple[str, ...]]] = []
    for dim, name in zip(shape, axes):
        if name is None or name not in rules:
            parts.append(None)
            continue
        chosen: List[str] = []
        extent = 1
        for mx in rules[name]:
            if mx in used or mx not in mesh.shape:
                continue
            if dim % (extent * mesh.shape[mx]) == 0:
                chosen.append(mx)
                extent *= mesh.shape[mx]
        for mx in chosen:
            used.add(mx)
        parts.append(tuple(chosen) if chosen else None)
    return P(*parts)


def param_shardings(cfg, mesh: Mesh,
                    rules: Optional[Dict[str, Tuple[str, ...]]] = None
                    ) -> PyTree:
    """NamedSharding tree matching ``models.params`` structure."""
    from repro.models import params as PM
    rules = dict(rules or DEFAULT_RULES)
    # expert weights must match the dispatch layout (models.moe):
    # full EP shards experts over model x data; otherwise experts shard
    # over model only and keep FSDP (embed over data) on the hidden dims.
    if getattr(cfg, "moe", None) and cfg.moe.enabled \
            and cfg.moe.layout != "ep_full":
        rules["experts"] = ("model",)
    spec_tree = PM.model_spec(cfg)

    def leaf(s: PM.ParamSpec):
        return NamedSharding(mesh, spec_for_axes(s.axes, s.shape, mesh, rules))
    return jax.tree.map(leaf, spec_tree,
                        is_leaf=lambda x: isinstance(x, PM.ParamSpec))


def sharding_report(cfg, mesh: Mesh,
                    rules: Optional[Dict[str, Tuple[str, ...]]] = None
                    ) -> Dict[str, Any]:
    """Bytes/device + which params fell back to replication (honesty check)."""
    from repro.models import params as PM
    rules = rules or DEFAULT_RULES
    spec_tree = PM.model_spec(cfg)
    total = 0
    replicated = 0
    fallbacks: List[str] = []
    for path, s in jax.tree_util.tree_flatten_with_path(
            spec_tree, is_leaf=lambda x: isinstance(x, PM.ParamSpec))[0]:
        spec = spec_for_axes(s.axes, s.shape, mesh, rules)
        shard_factor = 1
        for p_ in spec:
            if p_ is None:
                continue
            names = (p_,) if isinstance(p_, str) else p_
            for nm in names:
                shard_factor *= mesh.shape[nm]
        bytes_ = s.size() * (4 if s.init in ("ssm_a", "dt_bias") else 2)
        total += bytes_ // shard_factor
        if shard_factor == 1 and s.size() > 1_000_000:
            replicated += bytes_
            fallbacks.append(jax.tree_util.keystr(path))
    return {"param_bytes_per_device": total,
            "replicated_large_param_bytes": replicated,
            "replicated_params": fallbacks}


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Shard the global batch over (pod, data) as divisibility allows."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen: List[str] = []
    extent = 1
    for a in axes:
        if batch_size % (extent * mesh.shape[a]) == 0:
            chosen.append(a)
            extent *= mesh.shape[a]
    return P(tuple(chosen) if chosen else None)


def batch_shardings(mesh: Mesh, batch: Dict[str, jax.Array | jax.ShapeDtypeStruct]
                    ) -> Dict[str, NamedSharding]:
    """Input shardings for a train/prefill batch dict."""
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim == 3:          # (3, B, S) M-RoPE
            bs = v.shape[1]
            spec = batch_spec(mesh, bs)
            out[k] = NamedSharding(mesh, P(None, *spec))
        else:
            bs = v.shape[0]
            spec = batch_spec(mesh, bs)
            rest = (None,) * (v.ndim - 1)
            out[k] = NamedSharding(mesh, P(*spec, *rest))
    return out


def cache_shardings(cfg, mesh: Mesh, cache: PyTree, batch_size: int) -> PyTree:
    """KV/SSM cache shardings for decode.

    batch >= data extent: shard batch dim.  batch == 1 (long-context):
    shard the *sequence* dim of attention caches over ``data`` —
    context-parallel decode; SSM states shard over heads via ``model``.
    """
    from repro.models import params as PM
    daxes = [a for a in ("pod", "data") if a in mesh.shape]
    dsize = math.prod(mesh.shape[a] for a in daxes)
    shard_batch = batch_size % dsize == 0
    depths = {f"g{gi}": g.depth
              for gi, g in enumerate(PM.decoder_groups(cfg))}

    msize = mesh.shape.get("model", 1)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        key = jax.tree_util.keystr(path)
        gkey = key.split("'")[1] if "'" in key else "g0"
        stacked = depths.get(gkey, 1) > 1                      # leading layers axis
        off = 1 if stacked else 0
        parts: List[Any] = [None] * len(shape)
        if shard_batch:
            parts[off] = tuple(daxes)
        is_seq_cache = ("latent" in key or "k_rope" in key
                        or (len(shape) - off == 4
                            and ("'k'" in key or "'v'" in key)))
        if is_seq_cache:
            # sequence-shard the cache: over model always (flash-decoding
            # partial-softmax merge), and over data too when batch can't
            T = shape[off + 1]
            seq_axes: List[str] = []
            extent = 1
            if "model" in mesh.shape and T % msize == 0:
                seq_axes.append("model")
                extent = msize
            if not shard_batch:
                dext = math.prod(mesh.shape[a] for a in daxes)
                if T % (extent * dext) == 0:
                    seq_axes += daxes
            if seq_axes:
                parts[off + 1] = tuple(seq_axes)
        elif "state" in key and len(shape) - off == 4:         # SSM state
            nh = shape[off + 1]
            if "model" in mesh.shape and nh % msize == 0:
                parts[off + 1] = "model"
        elif "conv" in key and len(shape) - off == 3:
            ch = shape[off + 2]
            if "model" in mesh.shape and ch % msize == 0:
                parts[off + 2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
