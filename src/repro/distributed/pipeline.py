"""DT-FM execution path: GPipe pipeline parallelism via shard_map+ppermute.

The paper's Table 2 method [98] combines data parallelism with pipeline
parallelism across edge devices.  This module runs it for real on a JAX
mesh with a ``stage`` axis:

* the decoder layer stack (uniform ``(attn, mlp)`` groups — the OPT family
  the paper trains) is split into S contiguous stages, parameters sharded
  over ``stage`` on the stacked layer axis,
* inside ``shard_map`` each tick runs the local stage and rotates
  activations with ``jax.lax.ppermute`` (the GPipe systolic schedule:
  mb + S - 1 ticks, bubble (S-1)/(mb+S-1)),
* embedding / lm-head / loss run outside the pipelined region (replicated),
* autodiff goes straight through ``ppermute`` — the backward pipeline is
  derived, not hand-scheduled.

Combined with the ``data`` mesh axis this is exactly DT-FM's hybrid
data+pipeline layout, executable on any device count (CPU tests use
``--xla_force_host_platform_device_count``).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as PM
from repro.models.blocks import _sublayer_train
from repro.models.config import ModelConfig
from repro.models.model import cross_entropy, embed_tokens, lm_logits
from repro.models.layers import norm

PyTree = Any


def _stage_forward(cfg: ModelConfig, stage_params: PyTree, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """Run this device's layer slice.  stage_params leaves: (L/S, ...)."""
    ctx = {"positions": positions, "causal": True, "attn_impl": "chunked"}

    def body(h, p_unit):
        for j, kind in enumerate(("attn", "mlp")):
            h, _ = _sublayer_train(kind, p_unit[f"s{j}_{kind}"], h,
                                   jnp.zeros((), jnp.float32), cfg, ctx)
        return h, None

    h, _ = jax.lax.scan(body, x, stage_params)
    return h


def stack_for_stages(cfg: ModelConfig, params: PyTree, num_stages: int
                     ) -> PyTree:
    """Reshape decoder stack leaves (L, ...) -> (S, L/S, ...)."""
    groups = PM.decoder_groups(cfg)
    assert len(groups) == 1 and groups[0].sublayers == ("attn", "mlp"), \
        "pipeline path supports uniform dense decoders (OPT family)"
    L = cfg.num_layers
    assert L % num_stages == 0, (L, num_stages)

    def reshape(leaf):
        return leaf.reshape((num_stages, L // num_stages) + leaf.shape[1:])
    return jax.tree.map(reshape, params["decoder"]["g0"])


def unstack_stages(cfg: ModelConfig, staged: PyTree) -> PyTree:
    L = cfg.num_layers

    def reshape(leaf):
        return leaf.reshape((L,) + leaf.shape[2:])
    return jax.tree.map(reshape, staged)


def make_pipeline_loss(cfg: ModelConfig, mesh: Mesh, *,
                       num_microbatches: int) -> Callable:
    """loss(params, staged_layers, batch) with the stage axis pipelined."""
    S = mesh.shape["stage"]
    MB = num_microbatches
    perm = [(i, (i + 1) % S) for i in range(S)]

    def pipelined(staged, mb_embeds, positions):
        """Inside shard_map: staged (1, L/S, ...) local; mb_embeds
        (MB, mbsz, T, d) replicated; returns (MB, mbsz, T, d) outputs."""
        local = jax.tree.map(lambda l: l[0], staged)
        stage_id = jax.lax.axis_index("stage")
        mbsz, T, d = mb_embeds.shape[1:]

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t (while t < MB)
            inject = mb_embeds[jnp.minimum(t, MB - 1)]
            x = jnp.where(stage_id == 0, inject, state)
            y = _stage_forward(cfg, local, x, positions)
            # last stage emits finished microbatch t-(S-1)
            done_idx = t - (S - 1)
            is_done = jnp.logical_and(stage_id == S - 1, done_idx >= 0)
            outs = jax.lax.cond(
                is_done,
                lambda o: o.at[jnp.maximum(done_idx, 0)].set(y),
                lambda o: o, outs)
            state = jax.lax.ppermute(y, "stage", perm)
            return (state, outs), None

        state0 = jnp.zeros((mbsz, T, d), mb_embeds.dtype)
        outs0 = jnp.zeros_like(mb_embeds)
        (_, outs), _ = jax.lax.scan(tick, (state0, outs0),
                                    jnp.arange(MB + S - 1))
        return outs[None]           # stacked over stage; stage S-1 is real

    from jax.experimental.shard_map import shard_map
    sm = shard_map(pipelined, mesh=mesh,
                   in_specs=(P("stage"), P(), P()),
                   out_specs=P("stage"), check_rep=False)

    def loss_fn(params, staged, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, T = tokens.shape
        assert B % MB == 0
        x = embed_tokens(params, cfg, tokens)
        if "pos" in params["embed"]:
            x = x + params["embed"]["pos"][:T].astype(x.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                     (B // MB, T))
        mb_embeds = x.reshape(MB, B // MB, T, -1)
        outs = sm(staged, mb_embeds, positions)        # (S, MB, mbsz, T, d)
        h = outs[S - 1].reshape(B, T, -1)              # last stage's output
        h = norm(params["final_norm"], h, cfg)
        logits = lm_logits(params, cfg, h)
        loss, _ = cross_entropy(logits, labels)
        return loss

    return loss_fn


def pipeline_train_step(cfg: ModelConfig, mesh: Mesh, opt_cfg, *,
                        num_microbatches: int = 4) -> Tuple[Callable, Callable]:
    """Returns (init_fn, step_fn) for pipelined training on ``mesh``."""
    from repro.optim import adamw
    loss_fn = make_pipeline_loss(cfg, mesh, num_microbatches=num_microbatches)
    S = mesh.shape["stage"]

    def init_fn(rng):
        params = PM.init_params(cfg, rng)
        staged = stack_for_stages(cfg, params, S)
        staged = jax.device_put(
            staged, jax.tree.map(
                lambda _: NamedSharding(
                    mesh, P("stage")), staged))
        rest = dict(params)
        del rest["decoder"]
        opt = adamw.init_opt_state({"rest": rest, "staged": staged}, opt_cfg)
        return rest, staged, opt

    from repro.optim import adamw as A

    @jax.jit
    def step_fn(rest, staged, opt, batch):
        # one jitted program per step: eager dispatch of the shard_map
        # collectives deadlocks the XLA CPU rendezvous (threads reach
        # different collectives in different orders)

        def wrapped(ps):
            full = dict(ps["rest"])
            return loss_fn(full, ps["staged"], batch)

        loss, grads = jax.value_and_grad(wrapped)(
            {"rest": rest, "staged": staged})
        merged = {"rest": rest, "staged": staged}
        new_p, new_opt, mets = A.apply_updates(merged, grads, opt, opt_cfg)
        return new_p["rest"], new_p["staged"], new_opt, \
            dict(mets, loss=loss)

    return init_fn, step_fn
