"""DT-FM execution path: GPipe pipeline parallelism via shard_map+ppermute.

The paper's Table 2 method [98] combines data parallelism with pipeline
parallelism across edge devices.  This module runs it for real on a JAX
mesh with a ``stage`` axis:

* the decoder layer stack (uniform ``(attn, mlp)`` groups — the OPT family
  the paper trains) is split into S contiguous stages, parameters sharded
  over ``stage`` on the stacked layer axis,
* stage boundaries may be **non-uniform** (a
  :class:`~repro.core.placement.PlacementSpec` balancing heterogeneous
  devices): every stage is padded to the longest stage's layer count and
  the phantom scan steps are masked out, so a 3-stage split of an
  8-layer model runs as (3, 3, 2) real layers on a (3, 3, 3) scan,
* inside ``shard_map`` each tick runs the local stage and rotates
  activations with ``jax.lax.ppermute`` (the GPipe systolic schedule:
  mb + S - 1 ticks, bubble (S-1)/(mb+S-1)),
* embedding / lm-head / loss run outside the pipelined region (replicated),
* autodiff goes straight through ``ppermute`` — the backward pipeline is
  derived, not hand-scheduled.

Combined with the ``data`` mesh axis this is exactly DT-FM's hybrid
data+pipeline layout, executable on any device count (CPU tests use
``--xla_force_host_platform_device_count``).
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as PM
from repro.models.blocks import _sublayer_train
from repro.models.config import ModelConfig
from repro.models.model import cross_entropy, embed_tokens, lm_logits
from repro.models.layers import norm

PyTree = Any

# stage boundaries: an int S (uniform split), an explicit [0,...,L] boundary
# list, or anything with a ``.boundaries`` attribute (a PlacementSpec)
Boundaries = Union[int, Sequence[int]]


def resolve_boundaries(cfg: ModelConfig, stages: Boundaries) -> List[int]:
    """Normalize to an explicit boundary list [0, ..., num_layers]."""
    if hasattr(stages, "boundaries"):            # PlacementSpec duck-type
        stages = stages.boundaries
    L = cfg.num_layers
    if isinstance(stages, int):
        if L % stages != 0:
            raise ValueError(
                f"{L} layers do not split uniformly into {stages} stages; "
                "pass explicit boundaries (e.g. a PlacementSpec's)")
        step = L // stages
        return list(range(0, L + 1, step))
    bounds = list(stages)
    if bounds[0] != 0 or bounds[-1] != L or bounds != sorted(bounds) \
            or len(set(bounds)) != len(bounds):
        raise ValueError(
            f"boundaries {bounds} must strictly ascend from 0 to {L}")
    return bounds


def stage_slices(bounds: Sequence[int]) -> List[Tuple[int, int]]:
    """Boundary list -> per-stage [start, stop) layer spans.

    This is the ONE place stage boundary math lives: the pipeline executor
    pads/masks from it and :mod:`repro.checkpoint` shards/reshards
    checkpoints with it, so a checkpoint written under one placement
    re-slices exactly onto the stages another placement executes.
    """
    return list(zip(bounds[:-1], bounds[1:]))


def stage_counts(bounds: Sequence[int]) -> List[int]:
    """Per-stage layer counts for a boundary list."""
    return [b - a for a, b in zip(bounds[:-1], bounds[1:])]


_stage_counts = stage_counts          # internal alias (pre-export name)


def stage_layer_mask(cfg: ModelConfig, stages: Boundaries) -> jax.Array:
    """(S, Lmax) bool: True where a padded scan slot holds a real layer."""
    counts = _stage_counts(resolve_boundaries(cfg, stages))
    lmax = max(counts)
    return jnp.arange(lmax)[None, :] < jnp.asarray(counts)[:, None]


def _stage_forward(cfg: ModelConfig, stage_params: PyTree, x: jax.Array,
                   positions: jax.Array,
                   mask: Optional[jax.Array] = None) -> jax.Array:
    """Run this device's layer slice.  stage_params leaves: (Lmax, ...);
    ``mask`` (Lmax,) skips the zero-padded slots of short stages."""
    ctx = {"positions": positions, "causal": True, "attn_impl": "chunked"}

    def run(h, p_unit):
        for j, kind in enumerate(("attn", "mlp")):
            h, _ = _sublayer_train(kind, p_unit[f"s{j}_{kind}"], h,
                                   jnp.zeros((), jnp.float32), cfg, ctx)
        return h

    if mask is None:
        def body(h, p_unit):
            return run(h, p_unit), None
        h, _ = jax.lax.scan(body, x, stage_params)
    else:
        def body(h, xs):
            p_unit, m = xs
            return jnp.where(m, run(h, p_unit), h), None
        h, _ = jax.lax.scan(body, x, (stage_params, mask))
    return h


def stack_for_stages(cfg: ModelConfig, params: PyTree, stages: Boundaries
                     ) -> PyTree:
    """Reshape decoder stack leaves (L, ...) -> (S, Lmax, ...).

    Uniform splits are a pure reshape; non-uniform boundaries slice each
    stage's layers and zero-pad to the longest stage (the executor masks
    the padding, and zero params receive zero grads, so padded slots stay
    exactly zero through training).
    """
    groups = PM.decoder_groups(cfg)
    assert len(groups) == 1 and groups[0].sublayers == ("attn", "mlp"), \
        "pipeline path supports uniform dense decoders (OPT family)"
    bounds = resolve_boundaries(cfg, stages)
    counts = _stage_counts(bounds)
    S, lmax = len(counts), max(counts)
    L = cfg.num_layers

    if L == S * lmax:                 # uniform: pure reshape
        def reshape(leaf):
            return leaf.reshape((S, lmax) + leaf.shape[1:])
        return jax.tree.map(reshape, params["decoder"]["g0"])

    def slice_pad(leaf):
        parts = []
        for a, b in zip(bounds[:-1], bounds[1:]):
            pad = [(0, lmax - (b - a))] + [(0, 0)] * (leaf.ndim - 1)
            parts.append(jnp.pad(leaf[a:b], pad))
        return jnp.stack(parts)
    return jax.tree.map(slice_pad, params["decoder"]["g0"])


def unstack_stages(cfg: ModelConfig, staged: PyTree,
                   stages: Optional[Boundaries] = None) -> PyTree:
    """Invert :func:`stack_for_stages` (drops non-uniform padding)."""
    L = cfg.num_layers

    if stages is None:                # legacy uniform round-trip
        def reshape(leaf):
            return leaf.reshape((L,) + leaf.shape[2:])
        return jax.tree.map(reshape, staged)

    counts = _stage_counts(resolve_boundaries(cfg, stages))

    def gather(leaf):
        return jnp.concatenate(
            [leaf[i, :c] for i, c in enumerate(counts)], axis=0)
    return jax.tree.map(gather, staged)


def make_pipeline_loss(cfg: ModelConfig, mesh: Mesh, *,
                       num_microbatches: int,
                       boundaries: Optional[Boundaries] = None) -> Callable:
    """loss(params, staged_layers, batch) with the stage axis pipelined.

    ``boundaries`` (a boundary list or PlacementSpec) enables non-uniform
    stage splits; ``None`` keeps the uniform L/S split.
    """
    S = mesh.shape["stage"]
    MB = num_microbatches
    perm = [(i, (i + 1) % S) for i in range(S)]
    bounds = resolve_boundaries(cfg, boundaries if boundaries is not None
                                else S)
    if len(bounds) - 1 != S:
        raise ValueError(
            f"boundaries {bounds} define {len(bounds) - 1} stages but the "
            f"mesh 'stage' axis has {S}")
    uniform = cfg.num_layers == S * max(_stage_counts(bounds))
    mask_all = None if uniform else stage_layer_mask(cfg, bounds)

    def pipelined(staged, mb_embeds, positions, mask):
        """Inside shard_map: staged (1, Lmax, ...) local; mb_embeds
        (MB, mbsz, T, d) replicated; returns (MB, mbsz, T, d) outputs."""
        local = jax.tree.map(lambda l: l[0], staged)
        local_mask = None if mask is None else mask[0]
        stage_id = jax.lax.axis_index("stage")
        mbsz, T, d = mb_embeds.shape[1:]

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t (while t < MB)
            inject = mb_embeds[jnp.minimum(t, MB - 1)]
            x = jnp.where(stage_id == 0, inject, state)
            y = _stage_forward(cfg, local, x, positions, local_mask)
            # last stage emits finished microbatch t-(S-1)
            done_idx = t - (S - 1)
            is_done = jnp.logical_and(stage_id == S - 1, done_idx >= 0)
            outs = jax.lax.cond(
                is_done,
                lambda o: o.at[jnp.maximum(done_idx, 0)].set(y),
                lambda o: o, outs)
            state = jax.lax.ppermute(y, "stage", perm)
            return (state, outs), None

        state0 = jnp.zeros((mbsz, T, d), mb_embeds.dtype)
        outs0 = jnp.zeros_like(mb_embeds)
        (_, outs), _ = jax.lax.scan(tick, (state0, outs0),
                                    jnp.arange(MB + S - 1))
        return outs[None]           # stacked over stage; stage S-1 is real

    from jax.experimental.shard_map import shard_map
    if mask_all is None:
        sm3 = shard_map(lambda s, e, p: pipelined(s, e, p, None), mesh=mesh,
                        in_specs=(P("stage"), P(), P()),
                        out_specs=P("stage"), check_rep=False)
        sm = lambda s, e, p, _m: sm3(s, e, p)          # noqa: E731
    else:
        sm = shard_map(pipelined, mesh=mesh,
                       in_specs=(P("stage"), P(), P(), P("stage")),
                       out_specs=P("stage"), check_rep=False)

    def loss_fn(params, staged, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, T = tokens.shape
        assert B % MB == 0
        x = embed_tokens(params, cfg, tokens)
        if "pos" in params["embed"]:
            x = x + params["embed"]["pos"][:T].astype(x.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                     (B // MB, T))
        mb_embeds = x.reshape(MB, B // MB, T, -1)
        outs = sm(staged, mb_embeds, positions, mask_all)  # (S, MB, mbsz, T, d)
        h = outs[S - 1].reshape(B, T, -1)              # last stage's output
        h = norm(params["final_norm"], h, cfg)
        logits = lm_logits(params, cfg, h)
        loss, _ = cross_entropy(logits, labels)
        return loss

    return loss_fn


def pipeline_train_step(cfg: ModelConfig, mesh: Mesh, opt_cfg, *,
                        num_microbatches: int = 4,
                        boundaries: Optional[Boundaries] = None
                        ) -> Tuple[Callable, Callable]:
    """Returns (init_fn, step_fn) for pipelined training on ``mesh``."""
    from repro.optim import adamw
    loss_fn = make_pipeline_loss(cfg, mesh, num_microbatches=num_microbatches,
                                 boundaries=boundaries)
    S = mesh.shape["stage"]
    stages: Boundaries = boundaries if boundaries is not None else S

    def init_fn(rng):
        params = PM.init_params(cfg, rng)
        staged = stack_for_stages(cfg, params, stages)
        staged = jax.device_put(
            staged, jax.tree.map(
                lambda _: NamedSharding(
                    mesh, P("stage")), staged))
        rest = dict(params)
        del rest["decoder"]
        opt = adamw.init_opt_state({"rest": rest, "staged": staged}, opt_cfg)
        return rest, staged, opt

    from repro.optim import adamw as A

    @jax.jit
    def step_fn(rest, staged, opt, batch):
        # one jitted program per step: eager dispatch of the shard_map
        # collectives deadlocks the XLA CPU rendezvous (threads reach
        # different collectives in different orders)

        def wrapped(ps):
            full = dict(ps["rest"])
            return loss_fn(full, ps["staged"], batch)

        loss, grads = jax.value_and_grad(wrapped)(
            {"rest": rest, "staged": staged})
        merged = {"rest": rest, "staged": staged}
        new_p, new_opt, mets = A.apply_updates(merged, grads, opt, opt_cfg)
        return new_p["rest"], new_p["staged"], new_opt, \
            dict(mets, loss=loss)

    return init_fn, step_fn
