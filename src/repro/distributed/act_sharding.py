"""Ambient-mesh activation sharding constraints.

``constrain(x, part0, part1, ...)`` applies ``with_sharding_constraint``
using whatever mesh axes exist in the ambient (jit-time) mesh; axes that
don't exist or don't divide the dim are silently dropped, so model code can
annotate once and run unchanged on a laptop (1 device), the edge mesh, or
the 512-chip production mesh.

Mesh introspection goes through :mod:`repro.compat`, so the same code is
live on jax ≥ 0.5 (abstract mesh) and jax 0.4.x (physical-mesh context).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

Part = Union[None, str, Tuple[str, ...]]

# canonical activation partitions
BATCH = ("pod", "data")
MODEL = ("model",)
# sentinel: force replication on this dim (plain None leaves it to GSPMD)
REPLICATED = "~replicated~"


def axis_extent(name: str) -> int:
    """Extent of a mesh axis in the ambient abstract mesh (1 if absent or
    not Auto) — lets model code pick sharding-dependent layouts at trace
    time without carrying the mesh around."""
    try:
        am = compat.get_abstract_mesh()
    except Exception:
        return 1
    if am is None or not am.axis_names:
        return 1
    for n, s, t in zip(am.axis_names, am.axis_sizes, am.axis_types):
        if n == name and t == compat.AxisType.Auto:
            return s
    return 1


def constrain(x: jax.Array, *parts: Part) -> jax.Array:
    """Pin listed dims to mesh axes; unlisted/None dims stay UNCONSTRAINED
    so GSPMD remains free to shard them (crucial: a hard None would force
    replication and insert all-gathers against XLA's chosen layout)."""
    try:
        am = compat.get_abstract_mesh()
    except Exception:
        return x
    if am is None or not am.axis_names:
        return x
    # only Auto axes can carry constraints; inside shard_map (Manual) no-op
    # (compare enum values, NOT str(): str(AxisType.Auto)=="AxisType.Auto")
    auto = {n for n, t in zip(am.axis_names, am.axis_types)
            if t == compat.AxisType.Auto}
    if not auto:
        return x
    sizes = {n: s for n, s in zip(am.axis_names, am.axis_sizes) if n in auto}
    used = set()
    clean = []
    pinned = False
    for i, part in enumerate(parts):
        dim = x.shape[i] if i < x.ndim else 1
        if part is None:
            clean.append(P.UNCONSTRAINED)
            continue
        if part == REPLICATED:
            clean.append(None)
            pinned = True
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        keep = []
        extent = 1
        for n in names:
            if n not in sizes or n in used:
                continue
            if dim % (extent * sizes[n]) == 0:
                keep.append(n)
                extent *= sizes[n]
        used.update(keep)
        if keep:
            pinned = True
            clean.append(tuple(keep))
        else:
            clean.append(P.UNCONSTRAINED)
    clean += [P.UNCONSTRAINED] * (x.ndim - len(clean))
    if not pinned:
        return x
    return jax.lax.with_sharding_constraint(x, P(*clean))
