"""Token sampling for the serving engine: greedy + temperature / top-k.

One vectorized, jit-once sampler covers every request in a step: per-slot
``temperature`` and ``top_k`` arrive as arrays, so mixed sampling configs
share the compiled function.  ``temperature <= 0`` means greedy (argmax);
``top_k <= 0`` disables the top-k filter.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # <= 0 -> greedy
    top_k: int = 0                    # <= 0 -> no filter


@jax.jit
def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperature: jax.Array, top_k: jax.Array) -> jax.Array:
    """logits: (B, V) f32; temperature/top_k: (B,).  Returns (B,) int32."""
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    # per-slot top-k: keep logits >= the k-th largest; k <= 0 keeps all
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        sorted_desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=1)
    keep = (scaled >= kth) | (top_k[:, None] <= 0)
    masked = jnp.where(keep, scaled, NEG_INF)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)
