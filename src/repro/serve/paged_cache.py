"""Paged KV-cache bookkeeping: block allocator + per-sequence block tables.

The dense ``model.init_cache`` layout sizes every sequence's cache to the
worst-case length, so a batch of mixed-length requests pays
``batch x max_len`` KV bytes even though most of that is never written.
The serving engine instead carves each layer's KV storage into fixed-size
**blocks** (``block_size`` tokens each, vLLM-style paging) and maps logical
token positions to physical blocks through a per-sequence **block table**:

* device side — per-attention-layer pools ``k_pages``/``v_pages`` of shape
  ``(num_blocks, block_size, kv_heads, head_dim)`` (see
  ``model.init_paged_cache``); block ids are shared across layers, so one
  table drives every layer's gather,
* host side — this module: a free-list :class:`BlockAllocator` plus
  :class:`BlockTable` slot state (alloc on admission, append on decode,
  free on eviction) with fragmentation / high-water statistics.

Block id 0 is reserved as the **null block**: padded batch slots and
unused block-table entries point at it, so the device-side scatter/gather
is always in-bounds and inactive slots can never corrupt live pages.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

NULL_BLOCK = 0


def blocks_for(num_tokens: int, block_size: int) -> int:
    """Physical blocks needed to hold ``num_tokens`` cache positions."""
    return -(-num_tokens // block_size)


class BlockAllocator:
    """LIFO free-list over block ids ``1..num_blocks-1`` (0 = null block)."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO: recently-freed blocks are re-used first (warm pages)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.peak_blocks_in_use = 0

    @property
    def num_usable(self) -> int:
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_usable - self.num_free

    def can_alloc(self, n: int) -> bool:
        return self.num_free >= n

    def alloc(self, n: int = 1) -> List[int]:
        """Pop ``n`` blocks; raises MemoryError when the pool is exhausted
        (callers check :meth:`can_alloc` / admission first)."""
        if not self.can_alloc(n):
            raise MemoryError(
                f"paged KV pool OOM: want {n} blocks, {self.num_free} free")
        out = [self._free.pop() for _ in range(n)]
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the null block")
            if b in self._free or not (0 < b < self.num_blocks):
                raise ValueError(f"double/invalid free of block {b}")
        self._free.extend(blocks)


@dataclass
class BlockTable:
    """One sequence's logical->physical block mapping + its length."""

    blocks: List[int] = field(default_factory=list)
    num_tokens: int = 0                  # cache positions written so far

    def allocated_tokens(self, block_size: int) -> int:
        return len(self.blocks) * block_size


class PagedKVCache:
    """Host-side paging state for ``max_slots`` concurrent sequences.

    Owns the allocator and one :class:`BlockTable` per slot, and renders
    them into the dense ``(max_slots, max_blocks_per_seq)`` int32 table +
    ``(max_slots,)`` length vector the device kernels consume.  The device
    pools themselves live in the model pytree (``model.init_paged_cache``).
    """

    def __init__(self, *, num_blocks: int, block_size: int,
                 max_slots: int, max_blocks_per_seq: int):
        self.allocator = BlockAllocator(num_blocks, block_size)
        self.block_size = block_size
        self.max_slots = max_slots
        self.max_blocks_per_seq = max_blocks_per_seq
        self._tables: List[Optional[BlockTable]] = [None] * max_slots

    # ------------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, t in enumerate(self._tables) if t is None]

    def table(self, slot: int) -> BlockTable:
        t = self._tables[slot]
        assert t is not None, f"slot {slot} not allocated"
        return t

    def can_admit(self, num_tokens: int) -> bool:
        """Admission check: enough free blocks for ``num_tokens`` cache
        positions (prompt + 1 lookahead so the first decode step cannot
        OOM the moment a request is admitted)."""
        need = blocks_for(num_tokens + 1, self.block_size)
        return (need <= self.max_blocks_per_seq
                and self.allocator.can_alloc(need))

    def open_slot(self, slot: int) -> None:
        assert self._tables[slot] is None, f"slot {slot} busy"
        self._tables[slot] = BlockTable()

    def ensure_capacity(self, slot: int) -> bool:
        """Make sure the next token position for ``slot`` has a physical
        block; returns False on pool OOM (caller preempts a sequence)."""
        t = self.table(slot)
        if t.num_tokens < t.allocated_tokens(self.block_size):
            return True
        if len(t.blocks) >= self.max_blocks_per_seq:
            return False                     # sequence hit its table limit
        if not self.allocator.can_alloc(1):
            return False
        t.blocks.extend(self.allocator.alloc(1))
        return True

    def commit_token(self, slot: int) -> None:
        """Account one cache position written at ``num_tokens`` (call after
        the device step that performed the write)."""
        t = self.table(slot)
        assert t.num_tokens < t.allocated_tokens(self.block_size), \
            "commit_token without ensure_capacity"
        t.num_tokens += 1

    def close_slot(self, slot: int) -> None:
        t = self.table(slot)
        if t.blocks:
            self.allocator.free(t.blocks)
        self._tables[slot] = None

    # ------------------------------------------------------------ device view
    def device_tables(self) -> np.ndarray:
        """(max_slots, max_blocks_per_seq) int32; unused entries -> null."""
        out = np.full((self.max_slots, self.max_blocks_per_seq), NULL_BLOCK,
                      np.int32)
        for i, t in enumerate(self._tables):
            if t is not None and t.blocks:
                out[i, :len(t.blocks)] = t.blocks
        return out

    def seq_lens(self) -> np.ndarray:
        """(max_slots,) int32 — cache positions already written per slot."""
        return np.asarray(
            [0 if t is None else t.num_tokens for t in self._tables],
            np.int32)

    # ------------------------------------------------------------ statistics
    def stats(self) -> Dict[str, float]:
        a = self.allocator
        live = [t for t in self._tables if t is not None]
        alloc_tok = sum(t.allocated_tokens(self.block_size) for t in live)
        used_tok = sum(t.num_tokens for t in live)
        return {
            "blocks_total": float(a.num_usable),
            "blocks_in_use": float(a.blocks_in_use),
            "blocks_peak": float(a.peak_blocks_in_use),
            "utilization": a.blocks_in_use / max(a.num_usable, 1),
            # internal fragmentation: allocated-but-unwritten tail slots
            "frag_tokens": float(alloc_tok - used_tok),
            "frag_frac": (alloc_tok - used_tok) / max(alloc_tok, 1),
        }
