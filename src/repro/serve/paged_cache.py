"""Paged KV-cache bookkeeping: refcounted block allocator, per-sequence
block tables, and a prefix -> block-chain cache index (vLLM-style).

The dense ``model.init_cache`` layout sizes every sequence's cache to the
worst-case length, so a batch of mixed-length requests pays
``batch x max_len`` KV bytes even though most of that is never written.
The serving engine instead carves each layer's KV storage into fixed-size
**blocks** (``block_size`` tokens each, vLLM-style paging) and maps logical
token positions to physical blocks through a per-sequence **block table**:

* device side — per-attention-layer pools ``k_pages``/``v_pages`` of shape
  ``(num_blocks, block_size, kv_heads, head_dim)`` (see
  ``model.init_paged_cache``); block ids are shared across layers, so one
  table drives every layer's gather,
* host side — this module: a refcounting :class:`BlockAllocator` plus
  :class:`BlockTable` slot state (alloc on admission, append on decode,
  free on eviction) with fragmentation / high-water statistics.

Block id 0 is reserved as the **null block**: padded batch slots and
unused block-table entries point at it, so the device-side scatter/gather
is always in-bounds and inactive slots can never corrupt live pages.

Prefix sharing
--------------

Shared system prompts are the common case at scale, so full blocks are
published into a **prefix index** keyed by ``(parent_block, token
tuple)`` — a radix chain rooted at the null block.  Admission walks the
index over the new prompt and maps every matched block read-only into
the sequence's table (refcount + 1, KV recompute skipped).  Because the
KV content of position ``p`` depends on the *entire* prefix before it
(every layer past the first attends to all prior positions), an index
entry is only valid reached through its parent chain from the root —
which the walk guarantees by construction.

Freeing decrements refcounts; a block only re-enters the free list at
refcount zero, and cached (registered) blocks are parked *cold* at the
far end of the LIFO so they are recycled last and stay matchable as long
as possible.  Recycling a cached block invalidates its index entry and
cascades to registered descendants (their chain root is gone; a stale
entry under a rewritten parent would serve wrong KV).  Writes never
touch a full block; appending into a *partially* shared tail block
copy-on-write forks it when other holders exist (``pending_copies``
records the device page copy the engine must perform before its next
step), or simply un-registers it when this sequence is the sole holder.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

NULL_BLOCK = 0


def blocks_for(num_tokens: int, block_size: int) -> int:
    """Physical blocks needed to hold ``num_tokens`` cache positions."""
    return -(-num_tokens // block_size)


class BlockAllocator:
    """Refcounting LIFO free-list over block ids ``1..num_blocks-1``
    (0 = null block).

    ``alloc`` hands out blocks at refcount 1; sharing a block across
    sequences is ``incref``; release is ``decref``, and a block re-enters
    the free list **only at refcount zero** — the invariant the serve
    tests' state machine drives.  ``free`` (the pre-sharing API) is a
    decref over a list and errors on blocks that are not live.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO: recently-freed blocks are re-used first (warm pages)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refcount: Dict[int, int] = {}
        self.peak_blocks_in_use = 0

    @property
    def num_usable(self) -> int:
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_usable - self.num_free

    def refcount(self, block: int) -> int:
        return self._refcount.get(block, 0)

    def can_alloc(self, n: int) -> bool:
        return self.num_free >= n

    def _touch_peak(self) -> None:
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)

    def alloc(self, n: int = 1) -> List[int]:
        """Pop ``n`` blocks at refcount 1; raises MemoryError when the pool
        is exhausted (callers check :meth:`can_alloc` / admission first)."""
        if not self.can_alloc(n):
            raise MemoryError(
                f"paged KV pool OOM: want {n} blocks, {self.num_free} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refcount[b] = 1
        self._touch_peak()
        return out

    def incref(self, block: int) -> int:
        """Add a holder.  A refcount-0 block (cached, parked in the free
        list) is pulled back out; live blocks just gain a reference."""
        if not (0 < block < self.num_blocks):
            raise ValueError(f"incref of invalid block {block}")
        rc = self._refcount.get(block, 0)
        if rc == 0:
            self._free.remove(block)
        self._refcount[block] = rc + 1
        self._touch_peak()
        return rc + 1

    def decref(self, block: int, *, cold: bool = False) -> int:
        """Drop a holder; the block re-enters the free list only when the
        count hits zero.  ``cold`` parks it at the far end of the LIFO
        (recycled last — used for blocks the prefix index still maps)."""
        if block == NULL_BLOCK:
            raise ValueError("cannot free the null block")
        rc = self._refcount.get(block, 0)
        if rc <= 0 or not (0 < block < self.num_blocks):
            raise ValueError(f"double/invalid free of block {block}")
        rc -= 1
        if rc == 0:
            del self._refcount[block]
            if cold:
                self._free.insert(0, block)
            else:
                self._free.append(block)
        else:
            self._refcount[block] = rc
        return rc

    def free(self, blocks: List[int]) -> None:
        """Release one reference on each block (pre-sharing API)."""
        for b in blocks:
            self.decref(b)


@dataclass
class BlockTable:
    """One sequence's logical->physical block mapping + its length."""

    blocks: List[int] = field(default_factory=list)
    num_tokens: int = 0                  # cache positions written so far
    tokens: List[int] = field(default_factory=list)   # ids at positions
    num_cached: int = 0                  # positions admitted from the index

    def allocated_tokens(self, block_size: int) -> int:
        return len(self.blocks) * block_size


@dataclass
class _CacheNode:
    """Index bookkeeping for one registered (cached) physical block."""

    key: Tuple[int, Tuple[int, ...]]     # (parent block, token tuple)
    parent: int
    partial: bool                        # fewer than block_size tokens
    children: Set[int] = field(default_factory=set)


class PagedKVCache:
    """Host-side paging state for ``max_slots`` concurrent sequences.

    Owns the allocator, one :class:`BlockTable` per slot, and the prefix
    index, and renders them into the dense ``(max_slots,
    max_blocks_per_seq)`` int32 table + ``(max_slots,)`` length vector
    the device kernels consume.  The device pools themselves live in the
    model pytree (``model.init_paged_cache``) — this class never touches
    device memory, but it *schedules* device work: copy-on-write forks
    append ``(src, dst)`` page copies to :attr:`pending_copies`, which
    the engine drains before its next step.
    """

    def __init__(self, *, num_blocks: int, block_size: int,
                 max_slots: int, max_blocks_per_seq: int,
                 prefix_sharing: bool = True):
        self.allocator = BlockAllocator(num_blocks, block_size)
        self.block_size = block_size
        self.max_slots = max_slots
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefix_sharing = prefix_sharing
        self._tables: List[Optional[BlockTable]] = [None] * max_slots
        # prefix index: (parent block, token tuple) -> physical block
        self.prefix_index: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        self._node: Dict[int, _CacheNode] = {}
        self.pending_copies: List[Tuple[int, int]] = []   # (src, dst) pages
        # cumulative counters (engine mirrors them into its registry)
        self.prefix_hit_tokens = 0
        self.cow_forks = 0

    # ------------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, t in enumerate(self._tables) if t is None]

    def table(self, slot: int) -> BlockTable:
        t = self._tables[slot]
        assert t is not None, f"slot {slot} not allocated"
        return t

    # ----------------------------------------------------------- prefix index
    def _match(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Walk the index over ``prompt`` from the root; returns the
        matched block chain and the number of tokens it covers.  Capped at
        ``len(prompt) - 1``: the last prompt token is always recomputed so
        the step that feeds it produces the logits sampling needs."""
        blocks: List[int] = []
        pos, parent = 0, NULL_BLOCK
        limit = len(prompt) - 1
        while pos < limit:
            take = min(self.block_size, limit - pos)
            hit, hit_len = None, 0
            for j in range(take, 0, -1):    # longest match at this node
                cand = self.prefix_index.get(
                    (parent, tuple(prompt[pos:pos + j])))
                if cand is not None:
                    hit, hit_len = cand, j
                    break
            if hit is None:
                break
            blocks.append(hit)
            pos += hit_len
            if hit_len < self.block_size or self._node[hit].partial:
                break                       # partial block ends the chain
            parent = hit
        return blocks, pos

    def _maybe_register(self, t: BlockTable, block_idx: int,
                        num_tokens: int) -> None:
        """Publish ``t.blocks[block_idx]`` (holding ``num_tokens`` token
        positions) into the prefix index, if its parent chain is itself
        registered — the walk invariant that makes entries safe to serve."""
        b = t.blocks[block_idx]
        if b in self._node:
            return                          # already published (or matched)
        parent = t.blocks[block_idx - 1] if block_idx else NULL_BLOCK
        if parent != NULL_BLOCK and parent not in self._node:
            return                          # broken chain: stay private
        toks = t.tokens[block_idx * self.block_size:
                        block_idx * self.block_size + num_tokens]
        if len(toks) < num_tokens or any(x < 0 for x in toks):
            return                          # unknown token ids: stay private
        key = (parent, tuple(toks))
        if key in self.prefix_index:
            return                          # duplicate content elsewhere
        self.prefix_index[key] = b
        self._node[b] = _CacheNode(key=key, parent=parent,
                                   partial=num_tokens < self.block_size)
        if parent != NULL_BLOCK:
            self._node[parent].children.add(b)

    def _invalidate(self, block: int) -> None:
        """Drop a block's index entry and cascade to registered
        descendants: their chain runs through this block, so once it is
        recycled (or its content diverges) a match through them would
        serve KV computed under a prefix that no longer exists."""
        node = self._node.pop(block, None)
        if node is None:
            return
        if self.prefix_index.get(node.key) == block:
            del self.prefix_index[node.key]
        pnode = self._node.get(node.parent)
        if pnode is not None:
            pnode.children.discard(block)
        for child in list(node.children):
            self._invalidate(child)

    def _alloc(self, n: int) -> List[int]:
        """Allocator pop + cache invalidation: recycled cold blocks lose
        their index entries (and their descendants') before reuse."""
        out = self.allocator.alloc(n)
        for b in out:
            self._invalidate(b)
        return out

    # -------------------------------------------------------------- admission
    def can_admit(self, prompt: Union[int, Sequence[int]]) -> bool:
        """Admission check: enough free blocks for the prompt plus one
        lookahead position (so the first decode step cannot OOM the moment
        a request is admitted).  Given the token list (rather than a bare
        length) the check credits prefix-index hits — matched blocks are
        mapped, not allocated, so sharing admits more concurrent sessions
        from the same pool."""
        if isinstance(prompt, (int, np.integer)):
            need = blocks_for(int(prompt) + 1, self.block_size)
            return (need <= self.max_blocks_per_seq
                    and self.allocator.can_alloc(need))
        total = blocks_for(len(prompt) + 1, self.block_size)
        if total > self.max_blocks_per_seq:
            return False
        matched, _ = self._match(prompt) if self.prefix_sharing else ([], 0)
        # matched blocks need no allocation, but cold ones (refcount 0)
        # leave the free list when the table pins them
        cold = sum(1 for b in matched if self.allocator.refcount(b) == 0)
        return self.allocator.num_free >= (total - len(matched)) + cold

    def open_slot(self, slot: int,
                  prompt: Optional[Sequence[int]] = None) -> int:
        """Open a slot; with a prompt (and sharing on) the longest cached
        prefix is mapped into the table read-only.  Returns the number of
        prompt positions admitted from the cache (0 without a match)."""
        assert self._tables[slot] is None, f"slot {slot} busy"
        t = BlockTable()
        self._tables[slot] = t
        if not self.prefix_sharing or not prompt:
            return 0
        blocks, ntok = self._match(prompt)
        for b in blocks:
            self.allocator.incref(b)
        t.blocks = list(blocks)
        t.num_tokens = ntok
        t.tokens = list(prompt[:ntok])
        t.num_cached = ntok
        self.prefix_hit_tokens += ntok
        return ntok

    # ------------------------------------------------------------------ write
    def ensure_capacity(self, slot: int, n: int = 1) -> bool:
        """Make sure the next ``n`` token positions for ``slot`` have
        writable physical blocks; returns False on pool OOM (caller
        preempts a sequence).  When the first write lands inside a block
        other sequences also hold, the block is copy-on-write forked: a
        fresh block replaces it in this table and the page copy is queued
        on :attr:`pending_copies`.  A sole-holder cached tail is instead
        un-registered — its content is about to diverge in place."""
        t = self.table(slot)
        total = blocks_for(t.num_tokens + n, self.block_size)
        if total > self.max_blocks_per_seq:
            return False                     # sequence hit its table limit
        grow = total - len(t.blocks)
        off = t.num_tokens % self.block_size
        fork = 0
        if off != 0:
            tail = t.blocks[t.num_tokens // self.block_size]
            if self.allocator.refcount(tail) > 1:
                fork = 1
        if not self.allocator.can_alloc(grow + fork):
            return False
        if off != 0:
            bi = t.num_tokens // self.block_size
            tail = t.blocks[bi]
            if fork:
                [fresh] = self._alloc(1)
                self.pending_copies.append((tail, fresh))
                self.allocator.decref(tail, cold=tail in self._node)
                t.blocks[bi] = fresh
                self.cow_forks += 1
            elif tail in self._node:
                # sole holder writing into a cached partial block: its
                # content diverges, so the index entry must go
                self._invalidate(tail)
        if grow > 0:
            t.blocks.extend(self._alloc(grow))
        return True

    def commit_token(self, slot: int, token: int = -1) -> None:
        """Account one cache position written at ``num_tokens`` (call after
        the device step that performed the write).  ``token`` is the id
        written there; blocks whose ids are unknown (< 0) are never
        published into the prefix index."""
        t = self.table(slot)
        assert t.num_tokens < t.allocated_tokens(self.block_size), \
            "commit_token without ensure_capacity"
        t.tokens.append(int(token))
        t.num_tokens += 1
        if self.prefix_sharing and t.num_tokens % self.block_size == 0:
            self._maybe_register(t, t.num_tokens // self.block_size - 1,
                                 self.block_size)

    def close_slot(self, slot: int) -> None:
        """Release the slot.  With sharing on, the partial tail is first
        published (exact-tuple entry) so an identical re-prefill — the
        recompute-preemption path — can reclaim it, then every block drops
        one reference; registered blocks park cold in the free list."""
        t = self.table(slot)
        if self.prefix_sharing and t.num_tokens > 0:
            tail_len = t.num_tokens % self.block_size
            if tail_len:
                self._maybe_register(t, t.num_tokens // self.block_size,
                                     tail_len)
        for b in t.blocks:
            self.allocator.decref(b, cold=b in self._node)
        self._tables[slot] = None

    # ------------------------------------------------------------ device view
    def take_pending_copies(self) -> List[Tuple[int, int]]:
        out, self.pending_copies = self.pending_copies, []
        return out

    def device_tables(self) -> np.ndarray:
        """(max_slots, max_blocks_per_seq) int32; unused entries -> null."""
        out = np.full((self.max_slots, self.max_blocks_per_seq), NULL_BLOCK,
                      np.int32)
        for i, t in enumerate(self._tables):
            if t is not None and t.blocks:
                out[i, :len(t.blocks)] = t.blocks
        return out

    def seq_lens(self) -> np.ndarray:
        """(max_slots,) int32 — cache positions already written per slot."""
        return np.asarray(
            [0 if t is None else t.num_tokens for t in self._tables],
            np.int32)

    # ------------------------------------------------------------ statistics
    def stats(self) -> Dict[str, float]:
        a = self.allocator
        live = [t for t in self._tables if t is not None]
        alloc_tok = sum(t.allocated_tokens(self.block_size) for t in live)
        used_tok = sum(t.num_tokens for t in live)
        held = sum(len(t.blocks) for t in live)
        return {
            "blocks_total": float(a.num_usable),
            "blocks_in_use": float(a.blocks_in_use),
            "blocks_peak": float(a.peak_blocks_in_use),
            "utilization": a.blocks_in_use / max(a.num_usable, 1),
            # internal fragmentation: allocated-but-unwritten tail slots
            "frag_tokens": float(alloc_tok - used_tok),
            "frag_frac": (alloc_tok - used_tok) / max(alloc_tok, 1),
            # sharing: table references minus unique live blocks = whole
            # blocks the pool did NOT have to hold twice right now
            "shared_saved_blocks": float(held - a.blocks_in_use),
            "cached_blocks": float(len(self._node)),
            "prefix_hit_tokens": float(self.prefix_hit_tokens),
            "cow_forks": float(self.cow_forks),
        }
