"""Continuous-batching serving engine over the paged KV cache.

Iteration-level (Orca-style) scheduling: every engine step runs ONE jitted
``decode_step_paged`` over a fixed number of batch slots; each slot feeds
either a **chunk** of its remaining prompt (prefill, teacher-forced, up to
``prefill_chunk`` tokens shared between prefilling slots per step) or its
last sampled token (decode).  Prefill and decode therefore interleave
freely inside a step — a long admission costs decoding slots one chunked
step, not one step per prompt token — new requests are admitted the
moment a slot and enough KV blocks are free, finished sequences are
evicted (their blocks return to the pool) at the step boundary, and the
compiled step functions never change shape: exactly two compiles (the
C=1 decode-only step and the C=chunk mixed step) cover the whole serving
session.

Memory is managed by ``serve.paged_cache``: admission matches the
longest cached prefix (shared system prompts map read-only into the new
sequence's block table, skipping their recompute entirely) and requires
free blocks only for the unshared remainder plus one lookahead; decode
allocates incrementally, copy-on-write forks queued by the cache are
executed as device page copies before the next step, and on pool
exhaustion the youngest sequence is preempted (its blocks are freed —
refcounts only, shared blocks survive — and it re-queues with its
generated tokens folded into the prompt — vLLM's recompute preemption;
its registered blocks typically make the re-prefill a cache hit).

Every step is priced through the component energy model
(``core.energy.monitor``) exactly as the trainers do, and the run summary
converts energy to operational CO2e via ``core.carbon.accounting``.

Robustness: requests whose queue wait exceeds ``ttft_deadline_s`` fail
gracefully (an empty, ``failed`` completion — counted and traced as a
``fault.deadline`` instant) instead of waiting forever under pressure;
recompute preemption is bounded by ``max_requeues``, past which the
request finishes with whatever it generated (``fault.requeue_limit``).
A seeded :class:`~repro.core.faultinject.FaultPlan` can additionally
force deterministic slot preemptions (``crashes(uid, step)`` — a serving
worker blip), which is how the requeue bound and deadline behavior are
exercised reproducibly.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flops as F
from repro.core.carbon.accounting import CarbonLedger
from repro.core.energy.devices import TPU_V5E, DeviceSpec
from repro.core.energy.monitor import ComponentModel, EnergyMonitor
from repro.core.faultinject import FaultInjector, FaultPlan
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer
from repro.serve.paged_cache import PagedKVCache, blocks_for
from repro.serve.sampling import SamplingParams, sample_tokens

PyTree = Any


@dataclass
class _ReqTelemetry:
    """Host-side lifecycle clock for one request: survives preemption and
    requeue (TTFT is measured submit→first *ever* sampled token; the
    end-to-end tokens/s denominator is submit→finish; inter-token gaps
    span preemptions too, which is exactly when they blow up)."""
    submit_s: float
    first_token_s: float = -1.0
    last_token_s: float = -1.0
    phase: Any = None                 # open lifecycle span handle
    phase_name: str = ""


@dataclass(frozen=True)
class Request:
    uid: str
    prompt: List[int]
    max_new: int
    sampling: SamplingParams = SamplingParams()
    eos_id: int = -1                  # < 0: never stops early


@dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8
    block_size: int = 16
    num_blocks: int = 128             # pool size (block 0 is the null page)
    max_blocks_per_seq: int = 32
    attn_impl: str = "gather"         # gather (XLA) | pallas (flash-decode)
    cache_dtype: str = "bfloat16"     # bfloat16 | float32 | int8 (quantized
                                      # pages + per-vector fp32 scales)
    prefill_chunk: int = 8            # prompt tokens fed per step (shared
                                      # across prefilling slots; 1 =
                                      # token-by-token teacher forcing)
    prefix_sharing: bool = True       # cache + reuse prompt-prefix blocks
    seed: int = 0
    ttft_deadline_s: float = 0.0      # fail queued requests whose wait
                                      # exceeds this (0 = no deadline)
    max_requeues: int = 32            # recompute-preemption bound per
                                      # request; past it the request
                                      # finishes with what it has


@dataclass
class Completion:
    uid: str
    prompt: List[int]
    tokens: List[int] = field(default_factory=list)
    preemptions: int = 0
    failed: bool = False              # deadline / requeue-limit casualty
    fail_reason: str = ""             # "deadline" | "requeue_limit"


@dataclass
class _Slot:
    req: Request
    fed: int = 0                      # tokens fed (prompt + sampled;
                                      # prefix-cache hits count as fed)
    generated: List[int] = field(default_factory=list)
    preemptions: int = 0

    @property
    def next_token(self) -> int:
        if self.fed < len(self.req.prompt):
            return self.req.prompt[self.fed]
        return self.generated[self.fed - len(self.req.prompt)]

    def next_tokens(self, n: int) -> List[int]:
        pl = len(self.req.prompt)
        return [self.req.prompt[j] if j < pl else self.generated[j - pl]
                for j in range(self.fed, self.fed + n)]


class ServeEngine:
    """Continuous-batching engine for one model replica."""

    def __init__(self, params: PyTree, cfg: ModelConfig, ecfg: EngineConfig,
                 *, device: DeviceSpec = TPU_V5E,
                 intensity_kg_per_kwh: Optional[float] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 slo=None):
        if not M.paged_decode_supported(cfg):
            raise NotImplementedError(
                f"{cfg.name}: paged serving needs attn/mlp/moe-only decoders "
                "(SSM/MLA/encoder-decoder caches are not token-paged)")
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.device = device
        dtype = jnp.dtype(ecfg.cache_dtype)
        self.pages = M.init_paged_cache(cfg, ecfg.num_blocks,
                                        ecfg.block_size, dtype)
        self.kv = PagedKVCache(num_blocks=ecfg.num_blocks,
                               block_size=ecfg.block_size,
                               max_slots=ecfg.max_slots,
                               max_blocks_per_seq=ecfg.max_blocks_per_seq,
                               prefix_sharing=ecfg.prefix_sharing)
        self._slots: List[Optional[_Slot]] = [None] * ecfg.max_slots
        self._waiting: Deque[Request] = deque()
        self._preempt_counts: Dict[str, int] = {}
        self._orig_prompts: Dict[str, List[int]] = {}
        self._key = jax.random.PRNGKey(ecfg.seed)
        self.completions: Dict[str, Completion] = {}
        self.monitor = EnergyMonitor(ComponentModel.for_device(device))
        self.ledger = CarbonLedger() if intensity_kg_per_kwh is None else \
            CarbonLedger(intensity_kg_per_kwh=intensity_kg_per_kwh)
        self.steps = 0
        self.tokens_generated = 0
        self._frag_tokens_peak = 0.0
        self._util_peak = 0.0
        # telemetry: lifecycle spans ride the process-global tracer;
        # histograms (TTFT, per-request tokens/s, per-step KV stats) live
        # in a per-engine registry so runs don't bleed into each other
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._tracer = get_tracer()
        self._rt: Dict[str, _ReqTelemetry] = {}
        # fault events (deadline expiries, requeue-limit hits, injected
        # preemptions) always flow through an injector so they land on
        # the obs timeline with the validated fault schema
        self.injector = FaultInjector(fault_plan, registry=self.metrics)
        # PR 9: a repro.obs.SLOMonitor closes the loop — the engine
        # feeds it every TTFT / inter-token observation, and while the
        # "serve_ttft" SLO burns, admission tightens to half the slots
        # (brownout: protect in-flight latency, shed queue pressure via
        # the existing TTFT-deadline machinery) until the burn recovers
        self.slo = slo

        from repro.models import params as MP
        from repro.train.trainer import donation_supported
        donate = (1,) if donation_supported() else ()
        impl = ecfg.attn_impl
        self._step_fn = jax.jit(
            lambda p, c, t, bt, sl: M.decode_step_paged(
                p, cfg, c, t, bt, sl, attn_impl=impl),
            donate_argnums=donate)
        # second (and last) compiled shape: the C=prefill_chunk mixed step
        self._chunk_fn = jax.jit(
            lambda p, c, t, nf, bt, sl: M.decode_step_paged(
                p, cfg, c, t, bt, sl, num_feed=nf, attn_impl=impl),
            donate_argnums=donate)

        # copy-on-write page copy: every pool leaf is indexed by page on
        # axis 0 (scan-stacked groups carry a leading depth axis instead)
        groups = MP.decoder_groups(cfg)
        depths = [g.depth for g in groups]

        def _copy_pages(cache, src, dst):
            out = {}
            for gi, d in enumerate(depths):
                unit = cache[f"g{gi}"]
                if d > 1:
                    out[f"g{gi}"] = jax.tree.map(
                        lambda l: l.at[:, dst].set(l[:, src]), unit)
                else:
                    out[f"g{gi}"] = jax.tree.map(
                        lambda l: l.at[dst].set(l[src]), unit)
            return out

        self._copy_fn = jax.jit(
            _copy_pages, donate_argnums=(0,) if donate else ())

        # per-block KV bytes across all layers (for peak-memory stats)
        leaves = jax.tree.leaves(self.pages)
        self.pool_bytes = int(sum(l.size * l.dtype.itemsize for l in leaves))
        self.bytes_per_block = self.pool_bytes / ecfg.num_blocks
        # what a bf16 pool of the same geometry would weigh per block —
        # the int8 savings feeding the kv-bytes-saved gauge
        n_attn = sum(g.depth * sum(1 for k in g.sublayers if k == "attn")
                     for g in groups)
        fp_bpb = (n_attn * 2 * ecfg.block_size * cfg.num_kv_heads
                  * cfg.resolved_head_dim * 2)
        self._quant_saved_per_block = max(0.0, fp_bpb - self.bytes_per_block)

    # ----------------------------------------------------------- telemetry
    def _phase_begin(self, uid: str, name: str, **attrs) -> None:
        rt = self._rt[uid]
        rt.phase = self._tracer.begin(name, "serve.request",
                                      track=f"req:{uid}", uid=uid, **attrs)
        rt.phase_name = name

    def _phase_end(self, uid: str, state: str, **attrs) -> None:
        rt = self._rt.get(uid)
        if rt is not None and rt.phase is not None:
            rt.phase.end(state=state, **attrs)
            rt.phase = None

    # ------------------------------------------------------------- scheduling
    def submit(self, req: Request) -> None:
        if req.max_new < 1:
            # max_new >= 1 also guarantees admission is satisfiable: the
            # submit bound below then covers can_admit's +1 lookahead block
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        need = blocks_for(len(req.prompt) + req.max_new, self.ecfg.block_size)
        limit = min(self.ecfg.max_blocks_per_seq, self.kv.allocator.num_usable)
        if need > limit:
            raise ValueError(
                f"request {req.uid}: {need} blocks needed, engine limit "
                f"{limit} — raise num_blocks/max_blocks_per_seq")
        self._orig_prompts[req.uid] = list(req.prompt)
        self._rt[req.uid] = _ReqTelemetry(submit_s=self._tracer.now_s())
        self._phase_begin(req.uid, "queued",
                          prompt_len=len(req.prompt), max_new=req.max_new)
        self._waiting.append(req)

    def _admit(self) -> None:
        free = self.kv.free_slots()
        live = self.ecfg.max_slots - len(free)
        while free and self._waiting \
                and self.kv.can_admit(list(self._waiting[0].prompt)):
            if self.slo is not None and live >= 1 \
                    and live >= max(1, self.ecfg.max_slots // 2) \
                    and self.slo.burning("serve_ttft"):
                # TTFT SLO burning: stop filling slots past half
                # occupancy so in-flight prefills finish sooner; the
                # queue drains through the deadline machinery instead of
                # piling more concurrent work onto a latency breach
                self.metrics.counter("serve/admission_deferred").inc(1)
                break
            live += 1
            req = self._waiting.popleft()
            slot = free.pop(0)
            # longest cached prefix maps in read-only; those positions are
            # already "fed" — their KV recompute is skipped entirely
            cached = self.kv.open_slot(slot, req.prompt)
            s = _Slot(req)
            s.fed = cached
            self._slots[slot] = s
            self.metrics.counter("serve/prompt_tokens").inc(len(req.prompt))
            if cached:
                self.metrics.counter("serve/prefix_hit_tokens").inc(cached)
            self._phase_end(req.uid, "admitted")
            self._phase_begin(req.uid, "prefill", slot=slot,
                              cached_tokens=cached)

    def _fail_request(self, uid: str, prompt: List[int],
                      generated: List[int], reason: str, **attrs) -> None:
        """Gracefully fail a request: it completes with whatever it
        generated (nothing, for a queue-deadline expiry), marked
        ``failed``, counted, and traced as a ``fault.<reason>`` instant
        — instead of waiting or recomputing forever under pressure."""
        orig = self._orig_prompts[uid]
        full = list(prompt) + list(generated)
        self.completions[uid] = Completion(
            uid=uid, prompt=orig, tokens=full[len(orig):],
            preemptions=self._preempt_counts.get(uid, 0),
            failed=True, fail_reason=reason)
        self._phase_end(uid, f"failed_{reason}")
        self.injector.emit(reason, uid, **attrs)
        self.metrics.counter(f"serve/failed_{reason}").inc(1)

    def _preempt_slot(self, slot: int, *, injected: bool = False) -> None:
        """Free one slot, folding its generated tokens into a re-queued
        prompt (recompute preemption).  Past ``max_requeues`` the
        request fails gracefully with its partial output instead of
        recomputing forever."""
        s = self._slots[slot]
        self.kv.close_slot(slot)
        self._slots[slot] = None
        count = self._preempt_counts.get(s.req.uid, 0) + 1
        self._preempt_counts[s.req.uid] = count
        self.metrics.counter("serve/preemptions").inc(1)
        if count > self.ecfg.max_requeues:
            self._fail_request(s.req.uid, s.req.prompt, s.generated,
                               "requeue_limit", requeues=count - 1,
                               bound=self.ecfg.max_requeues)
            return
        merged = Request(uid=s.req.uid,
                         prompt=list(s.req.prompt) + list(s.generated),
                         max_new=s.req.max_new - len(s.generated),
                         sampling=s.req.sampling, eos_id=s.req.eos_id)
        self._waiting.appendleft(merged)
        # lifecycle: whatever phase was running ends preempted; the
        # request re-queues (its TTFT clock keeps running from submit)
        self._phase_end(merged.uid, "preempted",
                        generated=len(s.generated))
        self._phase_begin(merged.uid, "queued", requeued=True)
        self._tracer.instant("preempt", "serve", uid=merged.uid,
                             injected=injected)

    def _preempt_youngest(self) -> bool:
        """Recompute-preempt the least-progressed slot.  Returns False
        when nothing is left to preempt."""
        live = [i for i, s in enumerate(self._slots) if s is not None]
        if not live:
            return False
        self._preempt_slot(min(live, key=lambda i: self._slots[i].fed))
        return True

    def _expire_deadlines(self) -> None:
        """Fail queued requests whose wait blew the TTFT deadline (a
        request that already produced its first token is never
        expired — the deadline is on *time to first token* only)."""
        if self.ecfg.ttft_deadline_s <= 0 or not self._waiting:
            return
        now = self._tracer.now_s()
        keep: Deque[Request] = deque()
        for req in self._waiting:
            rt = self._rt.get(req.uid)
            waited = now - rt.submit_s if rt is not None else 0.0
            if rt is not None and rt.first_token_s < 0 \
                    and waited > self.ecfg.ttft_deadline_s:
                self._fail_request(req.uid, req.prompt, [], "deadline",
                                   waited_s=round(waited, 4),
                                   deadline_s=self.ecfg.ttft_deadline_s)
            else:
                keep.append(req)
        self._waiting = keep

    def _inject_preemptions(self) -> None:
        """Deterministic worker blips from the fault plan: a slot whose
        request the plan crashes at this step loses its KV state and
        recompute-preempts (bounded by ``max_requeues`` like any other
        preemption)."""
        plan = self.injector.plan
        if not plan.active or plan.crash_prob <= 0:
            return
        for i in range(self.ecfg.max_slots):
            s = self._slots[i]
            if s is not None and plan.crashes(s.req.uid, self.steps):
                self.injector.emit("crash", s.req.uid, step=self.steps,
                                   slot=i)
                self._preempt_slot(i, injected=True)

    def _plan_feeds(self) -> Dict[int, int]:
        """Per-slot token counts for this step: decode slots always feed
        one token; prefilling slots split the ``prefill_chunk`` budget
        (each gets at least one token, so nothing starves when many
        prefill at once).  Reserves KV capacity — including copy-on-write
        headroom — preempting the least-progressed sequence on pool
        exhaustion."""
        budget = max(1, self.ecfg.prefill_chunk)
        feeds: Dict[int, int] = {}
        for i in range(self.ecfg.max_slots):
            s = self._slots[i]
            if s is None:
                continue
            remaining = len(s.req.prompt) - s.fed
            if remaining <= 0:
                feeds[i] = 1                              # decoding
            else:
                take = min(remaining, max(1, budget))
                feeds[i] = take
                budget -= take
        for i in list(feeds):
            while self._slots[i] is not None \
                    and not self.kv.ensure_capacity(i, feeds[i]):
                if not self._preempt_youngest():
                    raise MemoryError("paged pool exhausted with no "
                                      "preemptable sequence")
        return {i: c for i, c in feeds.items()
                if self._slots[i] is not None}

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """Run one engine iteration; returns tokens committed this step."""
        with self._tracer.span("engine_step", "serve", track="engine",
                               metric="serve/step_s") as sp:
            return self._step_inner(sp)

    def _step_inner(self, sp) -> int:
        self._expire_deadlines()
        self._inject_preemptions()
        self._admit()
        if not any(s is not None for s in self._slots):
            return 0
        feeds = self._plan_feeds()
        if not feeds:
            return 0
        sp.set(active=len(feeds))

        t0 = time.perf_counter()
        # drain queued copy-on-write forks as device page copies BEFORE
        # the step touches the pool (the forked sequence writes into its
        # private copy this very step)
        copies = self.kv.take_pending_copies()
        if copies:
            self.metrics.counter("serve/cow_forks").inc(len(copies))
            for src, dst in copies:
                self.pages = self._copy_fn(self.pages, jnp.int32(src),
                                           jnp.int32(dst))
        n = self.ecfg.max_slots
        C = self.ecfg.prefill_chunk if max(feeds.values()) > 1 else 1
        tokens = np.zeros((n, C), np.int32)
        nfeed = np.zeros((n,), np.int32)
        temp = np.zeros((n,), np.float32)
        topk = np.zeros((n,), np.int32)
        fed_tokens: Dict[int, List[int]] = {}
        for i, cnt in feeds.items():
            s = self._slots[i]
            fed_tokens[i] = s.next_tokens(cnt)
            tokens[i, :cnt] = fed_tokens[i]
            nfeed[i] = cnt
            temp[i] = s.req.sampling.temperature
            topk[i] = s.req.sampling.top_k
        bt = jnp.asarray(self.kv.device_tables())
        sl = jnp.asarray(self.kv.seq_lens())

        if C == 1:
            logits, self.pages = self._step_fn(self.params, self.pages,
                                               jnp.asarray(tokens), bt, sl)
        else:
            logits, self.pages = self._chunk_fn(self.params, self.pages,
                                                jnp.asarray(tokens),
                                                jnp.asarray(nfeed), bt, sl)
        self._key, sub = jax.random.split(self._key)
        sampled = np.asarray(sample_tokens(logits.astype(jnp.float32), sub,
                                           jnp.asarray(temp),
                                           jnp.asarray(topk)))

        committed = 0
        flops = hbm = 0.0
        now = self._tracer.now_s()
        for i, cnt in feeds.items():
            s = self._slots[i]
            for tok in fed_tokens[i]:
                self.kv.commit_token(i, tok)
            cache_len = self.kv.table(i).num_tokens
            for c in range(cnt):
                flops += F.decode_flops(self.cfg, 1,
                                        cache_len - cnt + 1 + c)
            hbm += F.kv_cache_bytes(self.cfg, 1, cache_len)
            s.fed += cnt
            if s.fed == len(s.req.prompt):
                # first sampled token for this (possibly merged) prompt:
                # prefill is over, the decode phase starts now
                self._phase_end(s.req.uid, "prefilled")
                self._phase_begin(s.req.uid, "decode", slot=i)
                rt = self._rt.get(s.req.uid)
                if rt is not None and rt.first_token_s < 0:
                    rt.first_token_s = self._tracer.now_s()
                    # TTFT survives preemption/requeue: the clock starts
                    # at submit and only the FIRST ever token stops it
                    self.metrics.histogram("serve/ttft_s").observe(
                        rt.first_token_s - rt.submit_s)
                    if self.slo is not None:
                        self.slo.observe("serve_ttft",
                                         rt.first_token_s - rt.submit_s)
            if s.fed >= len(s.req.prompt):          # this logit row counts
                tok = int(sampled[i])
                s.generated.append(tok)
                self.tokens_generated += 1
                committed += 1
                rt = self._rt.get(s.req.uid)
                if rt is not None:
                    # inter-token gap, surviving preemption: the p99 here
                    # is what chunked prefill is buying down
                    if rt.last_token_s >= 0:
                        gap = max(now - rt.last_token_s, 1e-7)
                        self.metrics.histogram(
                            "serve/inter_token_s",
                            lo=1e-7, hi=3600.0).observe(gap)
                        if self.slo is not None:
                            self.slo.observe("serve_inter_token", gap)
                    rt.last_token_s = now
                done = (len(s.generated) >= s.req.max_new
                        or (s.req.eos_id >= 0 and tok == s.req.eos_id))
                if done:
                    self._finish(i)
        # weights stream once per step, caches once per active sequence
        hbm += self.cfg.active_param_count() * 2
        self.monitor.record_step(flops=flops, hbm_bytes=hbm,
                                 duration_s=time.perf_counter() - t0)
        # fragmentation is only meaningful while sequences are live, so
        # sample it per step (stats() runs after everything is evicted);
        # the registry keeps the high-water marks so post-run peak stats
        # never read zero just because every slot was evicted
        st = self.kv.stats()
        self._frag_tokens_peak = max(self._frag_tokens_peak,
                                     st["frag_tokens"])
        self._util_peak = max(self._util_peak, st["utilization"])
        # bytes the fast path is NOT spending: prefix-shared blocks that
        # multiple sequences map (held minus physically allocated) plus
        # the int8-vs-bf16 delta on every block actually in use
        saved = (st.get("shared_saved_blocks", 0.0) * self.bytes_per_block
                 + self._quant_saved_per_block
                 * self.kv.allocator.blocks_in_use)
        self.metrics.gauge("serve/kv_bytes_saved").set_max(saved)
        self.metrics.gauge("serve/kv_utilization_peak").set_max(
            st["utilization"])
        self.metrics.gauge("serve/kv_frag_tokens_peak").set_max(
            st["frag_tokens"])
        self.metrics.histogram("serve/kv_utilization",
                               lo=1e-4, hi=2.0).observe(st["utilization"])
        self.metrics.counter("serve/tokens").inc(committed)
        self._tracer.counter("kv.utilization", st["utilization"])
        self._tracer.counter("kv.frag_tokens", st["frag_tokens"])
        self.steps += 1
        return committed

    def _finish(self, slot: int) -> None:
        s = self._slots[slot]
        # after recompute preemption the slot's prompt carries previously
        # generated tokens; the completion reports the ORIGINAL prompt and
        # everything generated beyond it
        orig = self._orig_prompts[s.req.uid]
        full = list(s.req.prompt) + list(s.generated)
        n_gen = len(full) - len(orig)
        self.completions[s.req.uid] = Completion(
            uid=s.req.uid, prompt=orig, tokens=full[len(orig):],
            preemptions=self._preempt_counts.get(s.req.uid, 0))
        self._phase_end(s.req.uid, "finished", tokens=n_gen)
        rt = self._rt.get(s.req.uid)
        if rt is not None:
            # end-to-end rate: completion tokens over submit→finish wall,
            # so preemption + recompute show up as a lower rate, not a
            # reset clock
            dt = self._tracer.now_s() - rt.submit_s
            if dt > 0 and n_gen > 0:
                self.metrics.histogram("serve/tokens_per_s",
                                       lo=1e-3, hi=1e6).observe(n_gen / dt)
        self.metrics.counter("serve/requests_finished").inc(1)
        self.kv.close_slot(slot)
        self._slots[slot] = None

    @property
    def busy(self) -> bool:
        return bool(self._waiting) or any(s is not None for s in self._slots)

    def warmup(self) -> None:
        """Compile both step shapes (the C=1 decode step and the
        C=prefill_chunk mixed step) plus the sampler by running one
        throwaway request end to end, then discard its artifacts.  Call
        ``reset_stats()`` afterwards so compile time and energy stay out
        of the measured window (J/token especially — XLA compilation
        burns host joules that have nothing to do with serving)."""
        plen = max(2, self.ecfg.prefill_chunk + 1)   # forces the chunk fn
        tok = self.cfg.vocab_size - 1
        self.submit(Request(uid="_warmup", prompt=[tok] * plen, max_new=2))
        while self.busy:
            self.step()
        self.completions.pop("_warmup", None)
        self._rt.pop("_warmup", None)
        self._orig_prompts.pop("_warmup", None)

    def reset_stats(self) -> None:
        """Start a fresh measurement window (call after a warmup run so
        compile time/energy stays out of the reported numbers).  Clears
        completions, counters, the energy monitor, and the allocator /
        fragmentation peaks — but not live sequences or the cache."""
        self.completions.clear()
        self.monitor.reset()
        self.steps = 0
        self.tokens_generated = 0
        self.wall_s = 0.0
        self._frag_tokens_peak = 0.0
        self._util_peak = 0.0
        self.metrics = MetricsRegistry()    # fresh histogram window
        self.injector.registry = self.metrics
        self._rt = {uid: rt for uid, rt in self._rt.items()
                    if rt.phase is not None}    # keep live lifecycles
        self.kv.allocator.peak_blocks_in_use = self.kv.allocator.blocks_in_use

    # ------------------------------------------------------------------- run
    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> Dict[str, Completion]:
        for r in requests or []:
            self.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        jax.tree.leaves(self.pages)[0].block_until_ready()
        self.wall_s = time.perf_counter() - t0
        return self.completions

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        wall = getattr(self, "wall_s", 0.0)
        out = {
            "steps": float(self.steps),
            "tokens_generated": float(self.tokens_generated),
            "tokens_per_s": self.tokens_generated / wall if wall else 0.0,
            "energy_j": self.monitor.total_j,
            "j_per_token": (self.monitor.total_j
                            / max(self.tokens_generated, 1)),
            "pool_bytes": float(self.pool_bytes),
            "peak_cache_bytes": (self.kv.allocator.peak_blocks_in_use
                                 * self.bytes_per_block),
            # per-step peaks from the metrics registry: the instantaneous
            # kv.stats() go to zero once every sequence is evicted at the
            # end of a run, the high-water gauges don't
            "frag_tokens_peak": self.metrics.gauge(
                "serve/kv_frag_tokens_peak").value,
            "utilization_peak": self.metrics.gauge(
                "serve/kv_utilization_peak").value,
            **self.kv.stats(),
        }
        out["deadline_failures"] = float(
            self.metrics.counter("serve/failed_deadline").value)
        out["requeue_limit_failures"] = float(
            self.metrics.counter("serve/failed_requeue_limit").value)
        out["requests_failed"] = (out["deadline_failures"]
                                  + out["requeue_limit_failures"])
        # prefix-cache effectiveness over the measurement window
        hit = self.metrics.counter("serve/prefix_hit_tokens").value
        seen = self.metrics.counter("serve/prompt_tokens").value
        out["prefix_hit_tokens"] = float(hit)
        out["prefix_hit_rate"] = hit / max(seen, 1)
        out["cow_forks_total"] = float(
            self.metrics.counter("serve/cow_forks").value)
        out["kv_bytes_saved"] = self.metrics.gauge(
            "serve/kv_bytes_saved").value
        ttft = self.metrics.histogram("serve/ttft_s")
        if ttft.count:
            out["ttft_p50_s"] = ttft.percentile(50)
            out["ttft_p99_s"] = ttft.percentile(99)
        itk = self.metrics.histogram("serve/inter_token_s",
                                     lo=1e-7, hi=3600.0)
        if itk.count:
            out["inter_token_p50_s"] = itk.percentile(50)
            out["inter_token_p99_s"] = itk.percentile(99)
        rate = self.metrics.histogram("serve/tokens_per_s",
                                      lo=1e-3, hi=1e6)
        if rate.count:
            out["req_tokens_per_s_p50"] = rate.percentile(50)
        kwh = self.monitor.total_wh / 1000.0
        self.ledger.entries.clear()
        self.ledger.add_operational_kwh("serve", kwh)
        out["carbon_g"] = self.ledger.operational_kg * 1000.0
        return out
