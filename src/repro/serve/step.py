"""Serving steps: prefill and single-token decode (what decode shapes lower).

``serve_step`` consumes ONE new token per sequence against a KV/SSM cache of
``seq_len`` — the assigned ``decode_32k``/``long_500k`` shapes.  For
``long_500k`` (batch 1) the attention caches are *sequence-sharded* over the
``data`` axis (see ``distributed.sharding.cache_shardings``); GSPMD then
lowers the cache update to a masked in-place write and the softmax reduction
to the flash-decoding partial-max/sum all-reduce pattern.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

PyTree = Any


@functools.lru_cache(maxsize=64)
def jitted_decode_step(cfg: ModelConfig) -> Callable:
    """One jitted decode step per config.  ``ModelConfig`` is a frozen
    (hashable) dataclass, so repeated ``greedy_generate`` calls reuse the
    compiled step instead of re-jitting a fresh lambda every call (each
    new lambda is a distinct function to jax's jit cache, so the old code
    recompiled on every generate)."""
    return jax.jit(lambda p, c, t, i: M.decode_step(p, cfg, c, t, i))


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params: PyTree, cache: PyTree, tokens: jax.Array,
                   index: jax.Array, enc: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, PyTree]:
        logits, new_cache = M.decode_step(params, cfg, cache, tokens, index,
                                          enc=enc)
        return logits, new_cache
    return serve_step


def make_prefill(cfg: ModelConfig) -> Callable:
    def prefill(params: PyTree, batch: Dict[str, jax.Array]) -> jax.Array:
        return M.forward_logits(params, cfg, batch)
    return prefill


def greedy_generate(params: PyTree, cfg: ModelConfig, prompt: jax.Array,
                    max_new: int, *, cache_len: Optional[int] = None,
                    enc: Optional[jax.Array] = None) -> jax.Array:
    """Token-by-token greedy decoding (prompt teacher-forced through the
    cache one token at a time — exercises exactly the serve_step path)."""
    B, S = prompt.shape
    T = cache_len or (S + max_new)
    cache = M.init_cache(cfg, B, T)
    if enc is not None:
        # project encoder K/V once; decode steps read the warmed cache
        cache = M.warm_cross_cache(params, cfg, cache, enc)
    step = jitted_decode_step(cfg)
    toks = prompt
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, toks[:, i:i + 1], jnp.int32(i))
    out = [prompt]
    cur = jnp.argmax(logits, axis=-1)[:, None]
    for j in range(max_new - 1):
        out.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(S + j))
        cur = jnp.argmax(logits, axis=-1)[:, None]
    out.append(cur)
    return jnp.concatenate(out, axis=1)
