"""Full-model forward passes: train loss, prefill, cached decode.

One entry point pair serves every architecture in the zoo:

* :func:`forward_train`  — tokens (+ optional frontend embeddings) -> loss
* :func:`decode_step`    — one new token against a KV/SSM cache

Batch dict keys (all optional except ``tokens``/``labels``):

``tokens``        (B, S) int32             decoder input ids
``labels``        (B, S) int32, -1 masked  next-token targets
``positions``     (B, S) or (3, B, S)      rope / M-RoPE position ids
``vision_embeds`` (B, Sv, d)               VLM frontend stub output (prepended)
``frames``        (B, Se, d)               audio frontend stub output (encoder)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import params as P
from repro.models.blocks import (PAGED_SUBLAYERS, group_decode,
                                 group_decode_paged, group_forward,
                                 init_group_cache, init_paged_sublayer_cache)
from repro.models.config import ModelConfig
from repro.models.layers import norm
from repro.models.params import _sinusoidal

PyTree = Any


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #

def embed_tokens(params: PyTree, cfg: ModelConfig, tokens: jax.Array
                 ) -> jax.Array:
    emb = params["embed"]["tok"]
    return jnp.take(emb, tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))


def lm_logits(params: PyTree, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(h.dtype)
        logits = jnp.einsum("bsd,vd->bsv", h, w)
    else:
        logits = h @ params["lm_head"].astype(h.dtype)
    if cfg.padded_vocab_size != cfg.vocab_size:
        # vocab-padding mask (ModelConfig.vocab_pad_multiple): padded ids
        # never win softmax/argmax
        pad = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Masked mean CE in fp32.  labels == -1 are ignored."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / denom, denom


def default_positions(batch: Dict[str, jax.Array], cfg: ModelConfig,
                      seq_len: int, bsz: int) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (bsz, seq_len))
    if cfg.pos_embedding == "mrope":
        return jnp.broadcast_to(pos[None], (3, bsz, seq_len))
    return pos


# --------------------------------------------------------------------------- #
# Encoder (whisper)
# --------------------------------------------------------------------------- #

def encoder_forward(params: PyTree, cfg: ModelConfig, frames: jax.Array,
                    ctx: Dict[str, Any]) -> jax.Array:
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    B, S, d = x.shape
    x = x + jnp.asarray(_sinusoidal(S, d), x.dtype)[None]
    enc_ctx = dict(ctx, causal=False,
                   positions=jnp.broadcast_to(
                       jnp.arange(S, dtype=jnp.int32)[None], (B, S)))
    # whisper encoder uses absolute positions only; disable rope there
    for gi, g in enumerate(P.encoder_groups(cfg)):
        x, _ = group_forward(params["encoder"][f"g{gi}"], g, x, cfg, enc_ctx)
    return norm(params["encoder"]["final_norm"], x, cfg)


# --------------------------------------------------------------------------- #
# Decoder trunk
# --------------------------------------------------------------------------- #

def decoder_trunk(params: PyTree, cfg: ModelConfig, x: jax.Array,
                  ctx: Dict[str, Any]) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(P.decoder_groups(cfg)):
        x, a = group_forward(params["decoder"][f"g{gi}"], g, x, cfg, ctx)
        aux = aux + a
    return norm(params["final_norm"], x, cfg), aux


def _mtp_loss(params: PyTree, cfg: ModelConfig, h: jax.Array,
              tokens: jax.Array, labels: jax.Array,
              ctx: Dict[str, Any]) -> jax.Array:
    """DeepSeek-V3 multi-token prediction (depth 1..k sequential blocks)."""
    from repro.models.blocks import _sublayer_train
    total = jnp.zeros((), jnp.float32)
    prev = h                                               # (B,S,d)
    for k in range(cfg.mtp_depth):
        mp = params["mtp"][f"d{k}"]
        shift = k + 1
        prev_trim = prev[:, :-1, :]
        emb_next = embed_tokens(params, cfg, tokens[:, shift:])
        merged = jnp.concatenate(
            [norm(mp["norm_prev"], prev_trim, cfg),
             norm(mp["norm_emb"], emb_next, cfg)], axis=-1)
        x = merged @ mp["proj"].astype(merged.dtype)
        pos = ctx["positions"]
        pos_k = pos[..., shift:] if pos.ndim <= 2 else pos[..., shift:]
        sub_ctx = dict(ctx, positions=pos_k)
        aux = jnp.zeros((), jnp.float32)
        for key, p_sub in sorted(mp["block"].items()):
            kind = key.split("_", 1)[1]
            x, aux = _sublayer_train(kind, p_sub, x, aux, cfg, sub_ctx)
        x = norm(params["final_norm"], x, cfg)
        logits = lm_logits(params, cfg, x)
        lbl = labels[:, shift:]
        loss_k, _ = cross_entropy(logits, lbl)
        total = total + loss_k
        prev = x
        tokens = tokens  # unchanged; next depth shifts further
    return total * cfg.mtp_loss_coef / max(cfg.mtp_depth, 1)


def forward_train(params: PyTree, cfg: ModelConfig,
                  batch: Dict[str, jax.Array], *,
                  remat: str = "none", attn_impl: str = "chunked"
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (scalar loss, metrics).

    ``attn_impl`` selects the attention kernel for every attention sublayer:
    "naive" (fp32 oracle), "chunked" (XLA flash twin, default), or "pallas"
    (fused TPU kernel with the FA-2 custom-VJP backward; interpret mode on
    CPU).  All three train — gradients flow through each impl.
    """
    from repro.distributed.act_sharding import BATCH, constrain
    tokens = batch["tokens"]
    labels = batch["labels"]
    B = tokens.shape[0]

    if cfg.is_encoder_decoder:
        enc = encoder_forward(params, cfg, batch["frames"],
                              {"remat": remat, "attn_impl": attn_impl})
        x = embed_tokens(params, cfg, tokens)
        if "pos" in params["embed"]:
            S = tokens.shape[1]
            x = x + params["embed"]["pos"][:S].astype(x.dtype)[None]
        S = tokens.shape[1]
        ctx = {"positions": default_positions(batch, cfg, S, B),
               "remat": remat, "attn_impl": attn_impl, "causal": True,
               "enc": enc}
    else:
        x = embed_tokens(params, cfg, tokens)
        if "pos" in params["embed"]:
            x = x + params["embed"]["pos"][:tokens.shape[1]].astype(x.dtype)[None]
        if "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([v, x], axis=1)
        S = x.shape[1]
        ctx = {"positions": default_positions(batch, cfg, S, B),
               "remat": remat, "attn_impl": attn_impl, "causal": True}

    x = constrain(x, BATCH, None, None)
    h, aux = decoder_trunk(params, cfg, x, ctx)
    logits = lm_logits(params, cfg, h)
    logits = constrain(logits, BATCH, None, "model")
    loss, n_tok = cross_entropy(logits, labels)
    metrics = {"ce_loss": loss, "aux_loss": aux, "tokens": n_tok}
    total = loss + aux
    if cfg.mtp_depth > 0:
        mtp = _mtp_loss(params, cfg, h, tokens, labels, ctx)
        metrics["mtp_loss"] = mtp
        total = total + mtp
    return total, metrics


def forward_logits(params: PyTree, cfg: ModelConfig,
                   batch: Dict[str, jax.Array], *,
                   attn_impl: str = "naive") -> jax.Array:
    """Full-sequence logits (tests / prefill scoring)."""
    from repro.distributed.act_sharding import BATCH, constrain
    tokens = batch["tokens"]
    B = tokens.shape[0]
    if cfg.is_encoder_decoder:
        enc = encoder_forward(params, cfg, batch["frames"],
                              {"attn_impl": attn_impl})
        x = embed_tokens(params, cfg, tokens)
        if "pos" in params["embed"]:
            x = x + params["embed"]["pos"][:tokens.shape[1]].astype(x.dtype)[None]
        ctx = {"positions": default_positions(batch, cfg, tokens.shape[1], B),
               "causal": True, "enc": enc, "attn_impl": attn_impl}
    else:
        x = embed_tokens(params, cfg, tokens)
        if "pos" in params["embed"]:
            x = x + params["embed"]["pos"][:tokens.shape[1]].astype(x.dtype)[None]
        if "vision_embeds" in batch:
            x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x],
                                axis=1)
        ctx = {"positions": default_positions(batch, cfg, x.shape[1], B),
               "causal": True, "attn_impl": attn_impl}
    x = constrain(x, BATCH, None, None)
    h, _ = decoder_trunk(params, cfg, x, ctx)
    logits = lm_logits(params, cfg, h)
    return constrain(logits, BATCH, None, "model")


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    cache: Dict[str, Any] = {}
    for gi, g in enumerate(P.decoder_groups(cfg)):
        cache[f"g{gi}"] = init_group_cache(g, cfg, batch, max_len, dtype)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def warm_cross_cache(params: PyTree, cfg: ModelConfig, cache: PyTree,
                     enc: jax.Array) -> PyTree:
    """Fill every xattn sublayer's cache with pre-projected encoder K/V.

    Called once after ``init_cache`` when serving an encoder-decoder —
    decode steps then run ``xattn_decode`` against the cache instead of
    re-projecting the full encoder context every token."""
    from repro.models.layers import project_cross_kv
    cache = dict(cache)
    for gi, g in enumerate(P.decoder_groups(cfg)):
        gkey = f"g{gi}"
        for j, kind in enumerate(g.sublayers):
            if kind != "xattn":
                continue
            key = f"s{j}_{kind}"
            p = params["decoder"][gkey][key]
            if g.depth == 1:
                k, v = project_cross_kv(p, enc, cfg)
            else:
                k, v = jax.vmap(
                    lambda pl: project_cross_kv(pl, enc, cfg))(p)
            old = cache[gkey][key]
            cache[gkey] = dict(cache[gkey])
            cache[gkey][key] = {"k": k.astype(old["k"].dtype),
                                "v": v.astype(old["v"].dtype)}
    return cache


def paged_decode_supported(cfg: ModelConfig) -> bool:
    """Whether the paged serving engine can run this architecture: every
    decoder sublayer must be token-paged or stateless (attn/mlp/moe).
    SSM recurrent state, MLA latent caches and warmed cross-attention are
    not paged (their per-sequence state is O(1) or encoder-length)."""
    if cfg.is_encoder_decoder:
        return False
    return all(kind in PAGED_SUBLAYERS
               for g in P.decoder_groups(cfg) for kind in g.sublayers)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> PyTree:
    """Per-layer page pools (no batch dim — sequences share the pool via
    their block tables; block 0 is the null page, see serve.paged_cache)."""
    cache: Dict[str, Any] = {}
    for gi, g in enumerate(P.decoder_groups(cfg)):
        unit = {f"s{j}_{kind}": init_paged_sublayer_cache(
                    kind, cfg, num_blocks, block_size, dtype)
                for j, kind in enumerate(g.sublayers)}
        if g.depth > 1:
            unit = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (g.depth,) + a.shape).copy(),
                unit)
        cache[f"g{gi}"] = unit
    return cache


def decode_step_paged(params: PyTree, cfg: ModelConfig, cache: PyTree,
                      tokens: jax.Array, block_tables: jax.Array,
                      seq_lens: jax.Array, *, attn_impl: str = "gather",
                      num_feed: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, PyTree]:
    """One decode / chunked-prefill step over a paged KV cache with
    PER-SEQUENCE positions.

    tokens: (B, C) int32 teacher-forced rows (C == 1 is plain decode);
    block_tables: (B, NB) int32 page ids; seq_lens: (B,) int32 cache
    positions already written — row ``c`` is written at position
    ``seq_lens[b] + c`` and attends to ``seq_lens[b] + c + 1`` valid
    positions (all C rows scatter before attention, so same-step
    causality is the per-row length mask).  ``num_feed``: (B,) rows
    actually fed per sequence; rows past it write to the null page and
    the returned logits come from row ``num_feed - 1`` (row ``C - 1``
    when omitted).  Unlike :func:`decode_step` there is no shared scalar
    ``index``: every sequence sits at its own offset, which is what
    continuous batching schedules.  Returns (logits (B, vocab), new cache).
    """
    B, C = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    pos_bc = seq_lens[:, None].astype(jnp.int32) \
        + jnp.arange(C, dtype=jnp.int32)[None, :]            # (B, C)
    if "pos" in params["embed"]:
        pos_tab = params["embed"]["pos"]
        idx = jnp.clip(pos_bc, 0, pos_tab.shape[0] - 1)
        x = x + jnp.take(pos_tab, idx, axis=0).astype(x.dtype)
    positions = pos_bc
    if cfg.pos_embedding == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, B, C))
    ctx: Dict[str, Any] = {"positions": positions,
                           "block_tables": block_tables,
                           "seq_lens": seq_lens,
                           "num_feed": num_feed,
                           "attn_impl": attn_impl}
    new_cache: Dict[str, Any] = {}
    for gi, g in enumerate(P.decoder_groups(cfg)):
        x, new_cache[f"g{gi}"] = group_decode_paged(
            params["decoder"][f"g{gi}"], g, x, cache[f"g{gi}"], cfg, ctx)
    h = norm(params["final_norm"], x, cfg)
    logits = lm_logits(params, cfg, h)
    if num_feed is None:
        return logits[:, C - 1, :], new_cache
    last = jnp.clip(num_feed - 1, 0, C - 1).astype(jnp.int32)
    return jnp.take_along_axis(
        logits, last[:, None, None], axis=1)[:, 0, :], new_cache


def decode_step(params: PyTree, cfg: ModelConfig, cache: PyTree,
                tokens: jax.Array, index: jax.Array, *,
                positions: Optional[jax.Array] = None,
                enc: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, PyTree]:
    """One decode step.  tokens: (B, 1) int32; index: scalar cache offset.

    Returns (logits (B, vocab), new cache).
    """
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens)
    if "pos" in params["embed"]:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["embed"]["pos"], index, 1, axis=0).astype(x.dtype)[None, 0]
    if positions is None:
        positions = jnp.full((B, 1), index, jnp.int32)
        if cfg.pos_embedding == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, B, 1))
    ctx: Dict[str, Any] = {"index": index, "positions": positions}
    if enc is not None:
        ctx["enc"] = enc
    new_cache: Dict[str, Any] = {}
    for gi, g in enumerate(P.decoder_groups(cfg)):
        x, new_cache[f"g{gi}"] = group_decode(
            params["decoder"][f"g{gi}"], g, x, cache[f"g{gi}"], cfg, ctx)
    h = norm(params["final_norm"], x, cfg)
    logits = lm_logits(params, cfg, h)
    return logits[:, 0, :], new_cache
