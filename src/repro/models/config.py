"""Model configuration for every architecture family in the zoo.

A single frozen dataclass describes dense, MoE, SSM (Mamba2), hybrid (Jamba),
encoder-decoder (Whisper) and VLM-backbone (Qwen2-VL) models.  Family-specific
fields default to "off" so that a dense config stays small.

Every assigned architecture in ``repro.configs`` instantiates exactly one of
these; reduced smoke variants use ``ModelConfig.reduced()``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (Mixtral / DeepSeek-V3 / Jamba style)."""

    num_experts: int = 0            # routed experts
    experts_per_token: int = 0      # top-k
    num_shared_experts: int = 0     # DeepSeek shared expert(s), always active
    d_ff_expert: int = 0            # per-expert FFN hidden size
    capacity_factor: float = 1.25   # static capacity for sort-based dispatch
    router_aux_loss_coef: float = 0.01
    router_dtype: str = "float32"
    # expert-parallel layout: "auto" (batch over data, experts over model
    # where divisible), "ep_full" (experts over model x data, batch
    # replicated in the dispatch buffer), "unconstrained" (GSPMD decides)
    layout: str = "auto"

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    d_state: int = 0
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 128           # SSD chunk length (MXU-aligned)
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def enabled(self) -> bool:
        return self.d_state > 0

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention settings."""

    q_lora_rank: int = 0            # 0 => dense q projection
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // num_heads

    # --- attention flavour -------------------------------------------------
    attention: str = "gqa"          # gqa | mla | none
    sliding_window: int = 0         # >0 => SWA (Mixtral)
    qkv_bias: bool = False          # Qwen-style QKV bias
    mla: MLAConfig = field(default_factory=MLAConfig)

    # --- positional encoding ----------------------------------------------
    pos_embedding: str = "rope"     # rope | mrope | sinusoidal | learned
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE (t,h,w) section split

    # --- FFN ----------------------------------------------------------------
    mlp_activation: str = "silu"    # silu (SwiGLU) | gelu (plain)
    moe: MoEConfig = field(default_factory=MoEConfig)
    moe_layer_period: int = 0       # every Nth layer is MoE (Jamba: 2); 0=all
    first_dense_layers: int = 0     # DeepSeek-V3: first k layers stay dense

    # --- SSM / hybrid -------------------------------------------------------
    ssm: SSMConfig = field(default_factory=SSMConfig)
    attn_layer_period: int = 0      # Jamba: 1 attention layer every N (8)
    attn_layer_offset: int = 0      # index of the attention layer in a period

    # --- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0        # whisper: 1500 frames
    max_target_positions: int = 0   # whisper decoder: 448

    # --- multimodal frontend stub -------------------------------------------
    frontend: Optional[str] = None  # vision_stub | audio_stub | None

    # --- extras ---------------------------------------------------------------
    mtp_depth: int = 0              # DeepSeek multi-token-prediction depth
    mtp_loss_coef: float = 0.1
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    max_seq_len: int = 131_072

    # --- dtypes ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- layout ---------------------------------------------------------------
    # Megatron-style vocab padding: embedding/lm-head vocab dim rounds up
    # to this multiple so vocab-parallel sharding divides any TP extent
    # (<=128).  Without it, archs with odd vocabs (mamba2 50280, granite
    # 49155, whisper 51865) replicate the ENTIRE logits matmul across the
    # model axis — measured 16x the logit flops, 75% of mamba2's prefill
    # compute (EXPERIMENTS.md §Perf beyond-paper #8).  Padded ids are
    # masked to -inf in lm_logits; 0 disables.
    vocab_pad_multiple: int = 128

    # --- citation -------------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    def layer_kind(self, i: int) -> str:
        """Return 'attn' or 'ssm' for decoder layer ``i`` (hybrid interleave)."""
        if self.arch_type == "ssm":
            return "ssm"
        if self.attn_layer_period > 0:
            return ("attn" if i % self.attn_layer_period == self.attn_layer_offset
                    else "ssm")
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.moe.enabled:
            return False
        if i < self.first_dense_layers:
            return False
        if self.moe_layer_period > 0:
            return i % self.moe_layer_period == (self.moe_layer_period - 1)
        return True

    # ---------------------------------------------------------------- counting
    @property
    def padded_vocab_size(self) -> int:
        m = self.vocab_pad_multiple
        if m <= 0:
            return self.vocab_size
        return -(-self.vocab_size // m) * m

    def param_count(self) -> int:
        """Exact parameter count (used for 6·N·D roofline bookkeeping)."""
        from repro.models import params as P
        return P.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import params as P
        return P.count_params(self, active_only=True)

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                vocab_size: int = 512, max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (CPU-runnable)."""
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        head_dim = max(16, d_model // heads)
        changes = dict(
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=2 * d_model if self.d_ff else 0,
            vocab_size=vocab_size,
            max_seq_len=4096,
        )
        if self.moe.enabled:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, max_experts),
                experts_per_token=min(self.moe.experts_per_token, 2),
                d_ff_expert=2 * d_model,
            )
        if self.ssm.enabled:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=32, head_dim=32, chunk_size=32)
        if self.mla.enabled:
            changes["mla"] = MLAConfig(
                q_lora_rank=0, kv_lora_rank=64,
                qk_nope_head_dim=head_dim, qk_rope_head_dim=head_dim // 2,
                v_head_dim=head_dim)
        if self.attn_layer_period > 0:
            # keep an attn layer inside the reduced stack
            changes["attn_layer_period"] = num_layers
            changes["attn_layer_offset"] = num_layers - 1
        if self.moe_layer_period > 0:
            changes["moe_layer_period"] = 2
        if self.first_dense_layers > 0:
            changes["first_dense_layers"] = 1
        if self.is_encoder_decoder:
            changes["encoder_layers"] = num_layers
            changes["encoder_seq_len"] = 64
            changes["max_target_positions"] = 64
        if self.mtp_depth > 0:
            changes["mtp_depth"] = 1
        if self.mrope_sections:
            changes["mrope_sections"] = (head_dim // 4, head_dim // 8,
                                         head_dim // 8)
        return dataclasses.replace(self, **changes)

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.attention == "mla":
            assert self.mla.enabled
        if self.arch_type == "ssm":
            assert self.ssm.enabled and self.attention in ("none", "gqa")
        if self.arch_type == "hybrid":
            assert self.ssm.enabled and self.attn_layer_period > 0
        if self.pos_embedding == "mrope":
            assert sum(self.mrope_sections) * 2 == self.resolved_head_dim, (
                self.mrope_sections, self.resolved_head_dim)
