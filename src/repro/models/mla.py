"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Train/prefill uses the expanded form (latent up-projected to per-head K/V,
standard attention).  Decode uses the *absorbed* form: the cache stores only
the compressed latent (kv_lora_rank) plus the shared rope key — the W^UK
projection is absorbed into the query so scores are computed directly in
latent space.  Cache bytes per token: kv_lora_rank + qk_rope_head_dim,
vs. 2·H·head_dim for vanilla MHA — the paper's key serving win.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (NEG_INF, apply_rope, attention_core, norm)


def _queries(p: Dict[str, Any], h: jax.Array, cfg: ModelConfig):
    m = cfg.mla
    if m.q_lora_rank > 0:
        qa = norm(p["q_norm"], h @ p["wq_a"].astype(h.dtype), cfg)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"].astype(h.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    return jnp.split(q, [m.qk_nope_head_dim], axis=-1)   # (nope, rope)


def _latent(p: Dict[str, Any], h: jax.Array, cfg: ModelConfig):
    m = cfg.mla
    kv_a = h @ p["wkv_a"].astype(h.dtype)                 # (B,S,rank+rope)
    latent, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    latent = norm(p["kv_norm"], latent, cfg)
    return latent, k_rope


def mla_forward(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array, causal: bool = True,
                impl: str = "chunked") -> jax.Array:
    """Full-sequence MLA sublayer (expanded form)."""
    from repro.distributed.act_sharding import BATCH, constrain
    from repro.models.layers import run_attention
    m = cfg.mla
    h = norm(p["norm"], x, cfg)
    q_nope, q_rope = _queries(p, h, cfg)
    latent, k_rope = _latent(p, h, cfg)

    kv = jnp.einsum("bsr,rhk->bshk", latent, p["wkv_b"].astype(h.dtype))
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)

    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    H = q_nope.shape[2]
    k_rope = jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, m.qk_rope_head_dim))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    q = constrain(q, BATCH, None, "model", None)
    k = constrain(k, BATCH, None, "model", None)
    v = constrain(v, BATCH, None, "model", None)
    out = run_attention(q, k, v, causal=causal, impl=impl)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {"latent": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def mla_decode(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
               cache: Dict[str, jax.Array], index: jax.Array,
               positions: jax.Array
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode with the absorbed latent cache.  x: (B,1,d)."""
    m = cfg.mla
    h = norm(p["norm"], x, cfg)
    q_nope, q_rope = _queries(p, h, cfg)                  # (B,1,H,·)
    latent_t, k_rope_t = _latent(p, h, cfg)               # (B,1,rank),(B,1,rope)

    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope_t = apply_rope(k_rope_t[:, :, None, :], positions,
                          cfg.rope_theta)[:, :, 0, :]

    latent_c = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent_t.astype(cache["latent"].dtype), index, axis=1)
    k_rope_c = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype), index, axis=1)

    w_k, w_v = jnp.split(p["wkv_b"].astype(x.dtype), [m.qk_nope_head_dim],
                         axis=-1)                         # (r,H,nope),(r,H,v)
    # absorb W^UK into the query: latent-space query (B,H,r)
    q_abs = jnp.einsum("bshk,rhk->bhr", q_nope, w_k)
    lat = latent_c.astype(jnp.float32)
    scores = (jnp.einsum("bhr,btr->bht", q_abs.astype(jnp.float32), lat)
              + jnp.einsum("bshk,btk->bht",
                           q_rope.astype(jnp.float32),
                           k_rope_c.astype(jnp.float32)))
    scores = scores / jnp.sqrt(jnp.float32(m.qk_head_dim))
    T = lat.shape[1]
    valid = jnp.arange(T)[None, None, :] <= index
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bht,btr->bhr", probs, lat)      # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", out_lat.astype(x.dtype), w_v)
    y = jnp.einsum("bhv,hvd->bd", out, p["wo"].astype(x.dtype))
    return x + y[:, None, :], {"latent": latent_c, "k_rope": k_rope_c}
