"""Mamba2 (SSD) mixer sublayer: full-sequence (train/prefill) and decode.

Projection layout follows the Mamba2 reference but with *separate* z/x/B/C/dt
projections instead of one fused ``in_proj`` — mathematically identical and
much friendlier to tensor-parallel sharding (each output dim carries a single
logical axis; no cross-shard slicing).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import norm, rms_gate_norm
from repro.kernels.ssd_scan import ref as ssd_ref


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum of shifted slices — K is tiny (4), unrolled adds beat conv lowering
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for i in range(K):
        out = out + pad[:, i:i + S, :] * w[i].astype(x.dtype)
    return out


def _conv_step(buf: jax.Array, x_t: jax.Array, w: jax.Array):
    """Single-step causal conv.  buf: (B,K-1,C) past inputs; x_t: (B,C)."""
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)     # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w.astype(x_t.dtype))
    return y, window[:, 1:, :]


def _project(p: Dict[str, Any], h: jax.Array, cfg: ModelConfig):
    ssm = cfg.ssm
    z = h @ p["wz"].astype(h.dtype)
    x = h @ p["wx"].astype(h.dtype)
    B = h @ p["wB"].astype(h.dtype)
    C = h @ p["wC"].astype(h.dtype)
    dt = jax.nn.softplus(
        (h @ p["wdt"].astype(h.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, x, B, C, dt


def ssm_forward(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
                use_kernel: bool = False) -> jax.Array:
    """Full-sequence Mamba2 sublayer.  x: (B,S,d)."""
    ssm = cfg.ssm
    Bsz, S, d = x.shape
    di = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    gs = ssm.n_groups * ssm.d_state

    h = norm(p["norm"], x, cfg)
    z, xin, Bp, Cp, dt = _project(p, h, cfg)
    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"]))
    Bp = jax.nn.silu(_causal_conv(Bp, p["conv_B"]))
    Cp = jax.nn.silu(_causal_conv(Cp, p["conv_C"]))

    xh = xin.reshape(Bsz, S, nh, ssm.head_dim)
    Bh = Bp.reshape(Bsz, S, ssm.n_groups, ssm.d_state)
    Ch = Cp.reshape(Bsz, S, ssm.n_groups, ssm.d_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y = ssd_ops.ssd(xh, dt, A, Bh, Ch, ssm.chunk_size)
    else:
        y = ssd_ref.ssd_reference(xh, dt, A, Bh, Ch, ssm.chunk_size)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, S, di)
    y = rms_gate_norm(p["gate_norm"], y, z, cfg.norm_eps)
    return x + y @ p["out"].astype(x.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32
                   ) -> Dict[str, jax.Array]:
    ssm = cfg.ssm
    d = cfg.d_model
    di, nh = ssm.d_inner(d), ssm.num_heads(d)
    gs = ssm.n_groups * ssm.d_state
    K = ssm.conv_kernel
    return {
        "state": jnp.zeros((batch, nh, ssm.head_dim, ssm.d_state), dtype),
        "conv_x": jnp.zeros((batch, K - 1, di), dtype),
        "conv_B": jnp.zeros((batch, K - 1, gs), dtype),
        "conv_C": jnp.zeros((batch, K - 1, gs), dtype),
    }


def ssm_decode(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
               cache: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode.  x: (B,1,d)."""
    ssm = cfg.ssm
    Bsz, _, d = x.shape
    nh = ssm.num_heads(d)

    h = norm(p["norm"], x, cfg)[:, 0, :]                        # (B,d)
    z = h @ p["wz"].astype(h.dtype)
    xin = h @ p["wx"].astype(h.dtype)
    Bp = h @ p["wB"].astype(h.dtype)
    Cp = h @ p["wC"].astype(h.dtype)
    dt = jax.nn.softplus(
        (h @ p["wdt"].astype(h.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                     # (B,nh)

    xin, conv_x = _conv_step(cache["conv_x"].astype(h.dtype), xin, p["conv_x"])
    Bp, conv_B = _conv_step(cache["conv_B"].astype(h.dtype), Bp, p["conv_B"])
    Cp, conv_C = _conv_step(cache["conv_C"].astype(h.dtype), Cp, p["conv_C"])
    xin, Bp, Cp = jax.nn.silu(xin), jax.nn.silu(Bp), jax.nn.silu(Cp)

    xh = xin.reshape(Bsz, nh, ssm.head_dim)
    Bh = Bp.reshape(Bsz, ssm.n_groups, ssm.d_state)
    Ch = Cp.reshape(Bsz, ssm.n_groups, ssm.d_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, state = ssd_ref.ssd_step(cache["state"], xh, dt, A, Bh, Ch)
    y = y + p["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(Bsz, 1, ssm.d_inner(d))
    y = rms_gate_norm(p["gate_norm"], y, z[:, None, :], cfg.norm_eps)
    out = x + y @ p["out"].astype(x.dtype)
    new_cache = {"state": state.astype(cache["state"].dtype),
                 "conv_x": conv_x.astype(cache["conv_x"].dtype),
                 "conv_B": conv_B.astype(cache["conv_B"].dtype),
                 "conv_C": conv_C.astype(cache["conv_C"].dtype)}
    return out, new_cache
