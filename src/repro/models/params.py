"""Parameter specs, initialization, logical sharding axes and counting.

Every parameter in the zoo is described once by a :class:`ParamSpec`
(shape + logical axes + initializer).  From the spec tree we derive:

* ``init_params``   — concrete arrays (PRNG-seeded) for real execution,
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` tree for the dry-run,
* ``param_axes``    — logical-axis tree consumed by ``repro.distributed``,
* ``count_params``  — exact N for 6·N·D roofline bookkeeping.

Layer stacks are grouped into *scan groups* (see :func:`layer_groups`): a
maximal run of layers whose sub-layer signature repeats periodically is
stacked on a leading ``layers`` axis and executed with ``jax.lax.scan``.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | ssm_a | dt_bias | pos
    fan_in_dims: Tuple[int, ...] = (0,)   # dims contracted by the matmul

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


# --------------------------------------------------------------------------- #
# Sub-layer specs
# --------------------------------------------------------------------------- #

def _norm_spec(cfg: ModelConfig, dim: int, axis: str = "embed") -> Dict[str, ParamSpec]:
    out = {"scale": ParamSpec((dim,), (axis,), "ones")}
    if cfg.norm_type == "layernorm":
        out["bias"] = ParamSpec((dim,), (axis,), "zeros")
    return out


def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, Any]:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s: Dict[str, Any] = {"norm": _norm_spec(cfg, d)}
    s["wq"] = ParamSpec((d, H, hd), ("embed", "heads", "head"))
    s["wk"] = ParamSpec((d, K, hd), ("embed", "kv_heads", "head"))
    s["wv"] = ParamSpec((d, K, hd), ("embed", "kv_heads", "head"))
    s["wo"] = ParamSpec((H, hd, d), ("heads", "head", "embed"),
                        fan_in_dims=(0, 1))
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, hd), ("heads", "head"), "zeros")
        s["bk"] = ParamSpec((K, hd), ("kv_heads", "head"), "zeros")
        s["bv"] = ParamSpec((K, hd), ("kv_heads", "head"), "zeros")
    return s


def mla_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, H, m = cfg.d_model, cfg.num_heads, cfg.mla
    s: Dict[str, Any] = {"norm": _norm_spec(cfg, d)}
    if m.q_lora_rank > 0:
        s["wq_a"] = ParamSpec((d, m.q_lora_rank), ("embed", "q_rank"))
        s["q_norm"] = _norm_spec(cfg, m.q_lora_rank, "q_rank")
        s["wq_b"] = ParamSpec((m.q_lora_rank, H, m.qk_head_dim),
                              ("q_rank", "heads", "head"))
    else:
        s["wq"] = ParamSpec((d, H, m.qk_head_dim), ("embed", "heads", "head"))
    # latent KV: down-proj to kv_lora_rank (+ shared rope dims)
    s["wkv_a"] = ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", "kv_rank"))
    s["kv_norm"] = _norm_spec(cfg, m.kv_lora_rank, "kv_rank")
    s["wkv_b"] = ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                           ("kv_rank", "heads", "head"))
    s["wo"] = ParamSpec((H, m.v_head_dim, d), ("heads", "head", "embed"),
                        fan_in_dims=(0, 1))
    return s


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s: Dict[str, Any] = {"norm": _norm_spec(cfg, d)}
    s["wi"] = ParamSpec((d, f), ("embed", "mlp"))
    if cfg.mlp_activation == "silu":
        s["wg"] = ParamSpec((d, f), ("embed", "mlp"))
    s["wo"] = ParamSpec((f, d), ("mlp", "embed"))
    return s


def moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, m = cfg.d_model, cfg.moe
    f = m.d_ff_expert or cfg.d_ff
    s: Dict[str, Any] = {"norm": _norm_spec(cfg, d)}
    s["router"] = ParamSpec((d, m.num_experts), ("embed", "experts"))
    s["wi"] = ParamSpec((m.num_experts, d, f), ("experts", "embed", "mlp"),
                        fan_in_dims=(1,))
    if cfg.mlp_activation == "silu":
        s["wg"] = ParamSpec((m.num_experts, d, f), ("experts", "embed", "mlp"),
                            fan_in_dims=(1,))
    s["wo"] = ParamSpec((m.num_experts, f, d), ("experts", "mlp", "embed"),
                        fan_in_dims=(1,))
    if m.num_shared_experts > 0:
        fs = f * m.num_shared_experts
        s["shared_wi"] = ParamSpec((d, fs), ("embed", "mlp"))
        if cfg.mlp_activation == "silu":
            s["shared_wg"] = ParamSpec((d, fs), ("embed", "mlp"))
        s["shared_wo"] = ParamSpec((fs, d), ("mlp", "embed"))
    return s


def ssm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, ssm = cfg.d_model, cfg.ssm
    di = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    gs = ssm.n_groups * ssm.d_state
    k = ssm.conv_kernel
    s: Dict[str, Any] = {"norm": _norm_spec(cfg, d)}
    s["wz"] = ParamSpec((d, di), ("embed", "mamba_inner"))
    s["wx"] = ParamSpec((d, di), ("embed", "mamba_inner"))
    s["wB"] = ParamSpec((d, gs), ("embed", "state"))
    s["wC"] = ParamSpec((d, gs), ("embed", "state"))
    s["wdt"] = ParamSpec((d, nh), ("embed", "mamba_heads"))
    s["conv_x"] = ParamSpec((k, di), (None, "mamba_inner"))
    s["conv_B"] = ParamSpec((k, gs), (None, "state"))
    s["conv_C"] = ParamSpec((k, gs), (None, "state"))
    s["A_log"] = ParamSpec((nh,), ("mamba_heads",), "ssm_a")
    s["D"] = ParamSpec((nh,), ("mamba_heads",), "ones")
    s["dt_bias"] = ParamSpec((nh,), ("mamba_heads",), "dt_bias")
    s["gate_norm"] = ParamSpec((di,), ("mamba_inner",), "ones")
    s["out"] = ParamSpec((di, d), ("mamba_inner", "embed"))
    return s


def xattn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Cross-attention (whisper decoder)."""
    return attn_specs(cfg, cross=True)


SUBLAYER_BUILDERS = {
    "attn": attn_specs,
    "mla": mla_specs,
    "mlp": mlp_specs,
    "moe": moe_specs,
    "ssm": ssm_specs,
    "xattn": xattn_specs,
}


# --------------------------------------------------------------------------- #
# Layer grouping (scan groups)
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ScanGroup:
    """``depth`` scan steps, each applying ``sublayers`` in order."""

    sublayers: Tuple[str, ...]      # e.g. ("attn","mlp") or 8-layer Jamba unit
    depth: int                      # scan length
    first_layer: int                # absolute index of first decoder layer


def _layer_signature(cfg: ModelConfig, i: int) -> Tuple[str, ...]:
    kind = cfg.layer_kind(i)
    if kind == "ssm":
        mixer = "ssm"
    elif cfg.attention == "mla":
        mixer = "mla"
    else:
        mixer = "attn"
    if cfg.layer_is_moe(i):
        return (mixer, "moe")
    if cfg.d_ff == 0:
        return (mixer,)          # pure-SSM blocks (Mamba2) carry no FFN
    return (mixer, "mlp")


def layer_groups(cfg: ModelConfig, *, decoder: bool = True) -> List[ScanGroup]:
    """Partition the decoder stack into periodic scan groups."""
    n = cfg.num_layers
    sigs = [_layer_signature(cfg, i) for i in range(n)]
    groups: List[ScanGroup] = []
    start = 0
    # prefix of layers different from the tail pattern (DeepSeek dense head)
    if cfg.first_dense_layers > 0:
        k = cfg.first_dense_layers
        assert all(s == sigs[0] for s in sigs[:k])
        groups.append(ScanGroup(sigs[0], k, 0))
        start = k
    rest = sigs[start:]
    if not rest:
        return groups
    period = 1
    for p in range(1, len(rest) + 1):
        if len(rest) % p == 0 and all(
                rest[i] == rest[i % p] for i in range(len(rest))):
            period = p
            break
    unit: List[str] = []
    for sig in rest[:period]:
        unit.extend(sig)
    groups.append(ScanGroup(tuple(unit), len(rest) // period, start))
    return groups


def encoder_groups(cfg: ModelConfig) -> List[ScanGroup]:
    assert cfg.is_encoder_decoder
    return [ScanGroup(("attn", "mlp"), cfg.encoder_layers, 0)]


def decoder_groups(cfg: ModelConfig) -> List[ScanGroup]:
    if cfg.is_encoder_decoder:
        return [ScanGroup(("attn", "xattn", "mlp"), cfg.num_layers, 0)]
    return layer_groups(cfg)


# --------------------------------------------------------------------------- #
# Spec tree for a whole model
# --------------------------------------------------------------------------- #

def _stack(spec_tree: PyTree, depth: int) -> PyTree:
    def add_axis(s: ParamSpec) -> ParamSpec:
        return ParamSpec((depth,) + s.shape, ("layers",) + s.axes, s.init,
                         tuple(d + 1 for d in s.fan_in_dims))
    return jax.tree.map(add_axis, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def group_spec(cfg: ModelConfig, group: ScanGroup) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for j, kind in enumerate(group.sublayers):
        tree[f"s{j}_{kind}"] = SUBLAYER_BUILDERS[kind](cfg)
    return _stack(tree, group.depth) if group.depth > 1 else tree


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {}
    # vocab dims use the PADDED size so vocab-parallel sharding divides
    # the TP extent (see ModelConfig.vocab_pad_multiple); lm_logits masks
    # the pad region to -inf
    spec["embed"] = {"tok": ParamSpec((cfg.padded_vocab_size, d),
                                      ("vocab", "embed"), fan_in_dims=())}
    if cfg.pos_embedding == "learned":
        n_pos = cfg.max_target_positions or cfg.max_seq_len
        spec["embed"]["pos"] = ParamSpec((n_pos, d), (None, "embed"), "pos",
                                         fan_in_dims=())
    if cfg.is_encoder_decoder:
        enc = {}
        for gi, g in enumerate(encoder_groups(cfg)):
            enc[f"g{gi}"] = group_spec(cfg, g)
        enc["final_norm"] = _norm_spec(cfg, d)
        spec["encoder"] = enc
    dec: Dict[str, Any] = {}
    for gi, g in enumerate(decoder_groups(cfg)):
        dec[f"g{gi}"] = group_spec(cfg, g)
    spec["decoder"] = dec
    spec["final_norm"] = _norm_spec(cfg, d)
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((d, cfg.padded_vocab_size),
                                    ("embed", "vocab"))
    if cfg.mtp_depth > 0:
        mtp: Dict[str, Any] = {}
        for k in range(cfg.mtp_depth):
            mtp[f"d{k}"] = {
                "proj": ParamSpec((2 * d, d), ("mlp", "embed")),
                "norm_prev": _norm_spec(cfg, d),
                "norm_emb": _norm_spec(cfg, d),
                "block": {"s0_" + _layer_signature(cfg, cfg.num_layers - 1)[0]:
                          SUBLAYER_BUILDERS[
                              _layer_signature(cfg, cfg.num_layers - 1)[0]](cfg),
                          "s1_mlp": mlp_specs(cfg)},
            }
        spec["mtp"] = mtp
    return spec


# --------------------------------------------------------------------------- #
# Materialization
# --------------------------------------------------------------------------- #

def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _sinusoidal(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * i / d)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return out.astype(np.float32)


def _init_one(spec: ParamSpec, key: jax.Array, cfg: ModelConfig) -> jax.Array:
    dtype = jnp.dtype(cfg.param_dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # A in [1, 16) => A_log = log(A)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)
    if spec.init == "dt_bias":
        lo, hi = cfg.ssm.dt_min, cfg.ssm.dt_max
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(hi) - math.log(lo)) + math.log(lo))
        # inverse softplus
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
    if spec.init == "pos":
        return jnp.asarray(_sinusoidal(spec.shape[0], spec.shape[1]), dtype)
    fan_in = max(1, int(np.prod([spec.shape[d] for d in spec.fan_in_dims]))
                 if spec.fan_in_dims else spec.shape[-1])
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(cfg: ModelConfig, rng: jax.Array) -> PyTree:
    spec = model_spec(cfg)
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_one(s, k, cfg) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> PyTree:
    def to_sds(s: ParamSpec):
        dt = jnp.float32 if s.init in ("ssm_a", "dt_bias") else \
            jnp.dtype(cfg.param_dtype)
        return jax.ShapeDtypeStruct(s.shape, dt)
    return jax.tree.map(to_sds, model_spec(cfg), is_leaf=_is_spec)


def param_axes(cfg: ModelConfig) -> PyTree:
    return jax.tree.map(lambda s: s.axes, model_spec(cfg), is_leaf=_is_spec)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total (or per-token-active) parameter count.

    ``active_only`` scales routed-expert params by top-k/num_experts — the
    MoE 6·N_active·D convention.
    """
    spec = model_spec(cfg)
    total = 0
    m = cfg.moe
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            spec, is_leaf=_is_spec)[0]:
        sz = leaf.size()
        if active_only and m.enabled and "experts" in (leaf.axes or ()):
            sz = int(sz * m.experts_per_token / m.num_experts)
        total += sz
    return total
