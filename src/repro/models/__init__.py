"""Model zoo: pure-pytree parameterized architectures (dense/MoE/SSM/hybrid/
encoder-decoder/VLM) with scan-based layer stacks."""
