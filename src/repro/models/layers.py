"""Core layers: norms, rotary embeddings (RoPE / M-RoPE), attention.

Pure functions over param pytrees.  Attention supports:

* GQA (num_kv_heads < num_heads) via head-group broadcast,
* causal, bidirectional (encoder), and sliding-window (Mixtral) masks,
* full-sequence (train/prefill) and single-token decode against a KV cache,
* sequence-sharded decode (flash-decoding partial-softmax merge) is layered
  on top in ``repro.serve.context_parallel``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

PyTree = Any
NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #

def norm(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """RMSNorm / LayerNorm: statistics in f32, normalize-multiply in the
    input dtype (the (B,S,1) rsqrt factor is exact in f32; applying it in
    bf16 costs <1e-3 relative error).  Standard practice; measured neutral
    on the dry-run byte proxy — XLA canonicalizes the converts
    (EXPERIMENTS.md §Perf #9, refuted hypothesis)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + cfg.norm_eps).astype(dtype)
        return x * inv * p["scale"].astype(dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + cfg.norm_eps).astype(dtype)
    out = (x - mean.astype(dtype)) * inv
    return out * p["scale"].astype(dtype) + p["bias"].astype(dtype)


def rms_gate_norm(scale: jax.Array, x: jax.Array, gate: jax.Array,
                  eps: float) -> jax.Array:
    """Mamba2 gated RMSNorm: norm(x * silu(z))."""
    dtype = x.dtype
    xf = (x * jax.nn.silu(gate)).astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings
# --------------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    angles = angles[..., None, :]                      # (..., S, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal rotary embedding.

    positions: (3, ..., S) — (temporal, height, width) position ids.
    ``sections`` split the d/2 frequency dims among t/h/w; text tokens carry
    identical t=h=w ids so M-RoPE degenerates to 1-D RoPE for them.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (d/2,)
    # per-frequency section index -> select t/h/w position stream
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=d // 2)
    onehot = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)  # (d/2, C)
    # angles per stream: (C, ..., S, d/2) -> select stream per frequency
    ang = positions[..., None].astype(jnp.float32) * freqs
    angles = jnp.einsum("c...f,fc->...f", ang, onehot)
    angles = angles[..., None, :]                      # (..., S, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positional(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.pos_embedding == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.pos_embedding == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x   # learned / sinusoidal handled at the embedding layer


# --------------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------------- #

def _mask_bias(q_len: int, kv_len: int, *, causal: bool, window: int,
               q_offset: jax.Array | int = 0) -> jax.Array:
    """(q_len, kv_len) additive mask bias in fp32."""
    q_pos = jnp.arange(q_len) + q_offset
    k_pos = jnp.arange(kv_len)
    ok = jnp.ones((q_len, kv_len), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: int = 0,
                   q_offset: jax.Array | int = 0,
                   kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention.  q: (B,S,H,D), k/v: (B,T,K,D) with H % K == 0.

    ``kv_valid_len`` masks cache positions >= valid length (decode).
    Softmax in fp32; output in q.dtype.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // K
    qf = q.reshape(B, S, K, g, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    bias = _mask_bias(S, T, causal=causal, window=window, q_offset=q_offset)
    logits = logits + bias
    if kv_valid_len is not None:
        valid = jnp.arange(T)[None, :] < kv_valid_len.reshape(-1, 1)
        logits = logits + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
    return out.reshape(B, S, H, Dv).astype(q.dtype)


def _project_qkv(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig,
                 kv_x: Optional[jax.Array] = None):
    kv_in = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


ATTN_IMPLS = ("naive", "chunked", "pallas")


def run_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, window: int = 0, impl: str = "chunked",
                  scale: Optional[float] = None) -> jax.Array:
    """Dispatch: naive oracle / chunked flash (XLA) / Pallas TPU kernel.

    All three are differentiable — "pallas" carries a fused FA-2 backward
    (interpret mode off-TPU), so every impl is a valid training path.
    """
    if impl == "naive":
        return attention_core(q, k, v, causal=causal, window=window)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      scale=scale)
    if impl != "chunked":
        raise ValueError(f"unknown attn_impl {impl!r}; expected one of "
                         f"{ATTN_IMPLS}")
    from repro.kernels.flash_attention.chunked import chunked_attention
    return chunked_attention(q, k, v, causal=causal, window=window,
                             scale=scale)


def attn_forward(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
                 positions: jax.Array, causal: bool = True,
                 impl: str = "chunked") -> jax.Array:
    """Full-sequence self-attention sublayer (train / prefill)."""
    from repro.distributed.act_sharding import BATCH, constrain
    h = norm(p["norm"], x, cfg)
    q, k, v = _project_qkv(p, h, cfg)
    q = positional(q, positions, cfg)
    k = positional(k, positions, cfg)
    q = constrain(q, BATCH, None, "model", None)
    k = constrain(k, BATCH, None, "model", None)
    v = constrain(v, BATCH, None, "model", None)
    out = run_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                        impl=impl)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def xattn_forward(p: Dict[str, Any], x: jax.Array, enc: jax.Array,
                  cfg: ModelConfig, impl: str = "chunked") -> jax.Array:
    """Cross-attention sublayer (whisper decoder)."""
    h = norm(p["norm"], x, cfg)
    q, k, v = _project_qkv(p, h, cfg, kv_x=enc)
    out = run_attention(q, k, v, causal=False, impl=impl)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def xattn_decode(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
                 cache_k: jax.Array, cache_v: jax.Array) -> jax.Array:
    """Cross-attention decode against PRE-PROJECTED encoder K/V.

    The encoder context is static during decode, so K/V are projected once
    at cache-warm time (``model.warm_cross_cache``) — the legacy path
    re-projected the full 1500-frame encoder every token, which was ~100%
    of whisper's decode FLOPs (EXPERIMENTS.md §Roofline)."""
    h = norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    out = attention_core(q, cache_k.astype(q.dtype),
                         cache_v.astype(q.dtype), causal=False)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def project_cross_kv(p: Dict[str, Any], enc: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    """(k, v) for a single xattn sublayer from encoder output (B,T,d)."""
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(enc.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(enc.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(enc.dtype)
        v = v + p["bv"].astype(enc.dtype)
    return k, v


def attn_decode(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
                cache_k: jax.Array, cache_v: jax.Array, index: jax.Array,
                positions: jax.Array):
    """Single-token decode.  x: (B,1,d).  cache_k/v: (B,T,K,D).

    Returns (y, new_cache_k, new_cache_v).
    """
    h = norm(p["norm"], x, cfg)
    q, k, v = _project_qkv(p, h, cfg)
    q = positional(q, positions, cfg)
    k = positional(k, positions, cfg)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), index, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), index, axis=1)
    B = x.shape[0]
    valid = jnp.full((B,), index + 1)
    window = cfg.sliding_window
    out = attention_core(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                         causal=False, window=window, q_offset=index,
                         kv_valid_len=valid)
    y = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def attn_decode_paged(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig, *,
                      cache: Dict[str, jax.Array],
                      block_tables: jax.Array, seq_lens: jax.Array,
                      positions: jax.Array, impl: str = "gather",
                      num_feed: Optional[jax.Array] = None):
    """Decode / chunked-prefill step against a PAGED KV cache.  x: (B,C,d)
    — C teacher-forced rows per sequence (C == 1 is plain decode).

    ``cache`` holds the shared block pools: ``k_pages``/``v_pages`` of
    shape (P, bs, K, D), plus ``k_scale``/``v_scale`` ((P, bs, K) fp32)
    when the pools are int8 (per-vector quant via ``kernels.quant8``,
    applied at append time here and inverted inside the attention
    gather).  block_tables: (B, NB) int32 page ids; seq_lens: (B,) cache
    positions already written (row ``c`` lands at ``seq_lens[b] + c``).
    ``num_feed``: (B,) rows actually fed per sequence this step; rows
    past it scatter to the null page and their output is ignored.
    Inactive batch slots carry ``seq_lens == 0`` and block tables full of
    the null page — their scatter hits page 0 (never allocated) and their
    output is ignored.

    Returns (y, new_cache).
    """
    from repro.kernels.flash_attention.decode import (flash_decode_paged,
                                                     paged_attention_reference)
    from repro.kernels.quant8.ops import quantize_kv
    k_pages, v_pages = cache["k_pages"], cache["v_pages"]
    k_scale = cache.get("k_scale")
    v_scale = cache.get("v_scale")
    B, C, _ = x.shape
    bs = k_pages.shape[1]
    nb = block_tables.shape[1]
    h = norm(p["norm"], x, cfg)
    q, k, v = _project_qkv(p, h, cfg)
    q = positional(q, positions, cfg)
    k = positional(k, positions, cfg)
    # scatter row c's K/V into its page: position seq_len + c -> block
    # (seq_len + c) // bs, offset (seq_len + c) % bs.  Active slots own
    # disjoint pages, so indices collide only on the null page (inactive
    # slots / rows past num_feed) where any value is fine.
    pos_idx = seq_lens[:, None] + jnp.arange(C, dtype=seq_lens.dtype)[None, :]
    page_ids = jnp.take_along_axis(
        block_tables, jnp.clip(pos_idx // bs, 0, nb - 1), axis=1)   # (B, C)
    if num_feed is not None:
        fed = jnp.arange(C)[None, :] < num_feed[:, None]
        page_ids = jnp.where(fed, page_ids, 0)
    offs = pos_idx % bs
    if k_scale is not None:                    # int8 pools: quantize at append
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_pages = k_pages.at[page_ids, offs].set(kq)
        v_pages = v_pages.at[page_ids, offs].set(vq)
        k_scale = k_scale.at[page_ids, offs].set(ks)
        v_scale = v_scale.at[page_ids, offs].set(vs)
    else:
        k_pages = k_pages.at[page_ids, offs].set(k.astype(k_pages.dtype))
        v_pages = v_pages.at[page_ids, offs].set(v.astype(v_pages.dtype))
    valid = seq_lens + 1                 # incl. the first row just written
    window = cfg.sliding_window
    qf = q.astype(jnp.float32)
    qf = qf[:, 0] if C == 1 else qf            # (B,H,D) | (B,C,H,D)
    attn = flash_decode_paged if impl == "pallas" \
        else paged_attention_reference
    out = attn(qf, k_pages, v_pages, block_tables, valid, window=window,
               k_scale=k_scale, v_scale=v_scale)
    if C == 1:
        out = out[:, None]
    out = out.astype(x.dtype)                  # (B, C, H, Dv)
    y = x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    new_cache = {"k_pages": k_pages, "v_pages": v_pages}
    if k_scale is not None:
        new_cache.update(k_scale=k_scale, v_scale=v_scale)
    return y, new_cache


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #

def mlp_forward(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = norm(p["norm"], x, cfg)
    wi = p["wi"].astype(x.dtype)
    if cfg.mlp_activation == "silu":
        a = jax.nn.silu(h @ wi) * (h @ p["wg"].astype(x.dtype))
    else:
        a = jax.nn.gelu(h @ wi)
    return x + a @ p["wo"].astype(x.dtype)
