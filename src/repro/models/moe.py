"""Mixture-of-experts FFN with sort-based static-capacity dispatch.

TPU-friendly formulation: no dynamic shapes.  Dispatch is computed *per
batch row* (vmapped over B): each row sorts its S·k assignments by expert
id and scatters into a fixed-capacity buffer ``(E, C_row, d)``.  The
resulting global buffer is (B, E, C, d) — batch dim shards over ``data``
(FSDP axis), expert dim over ``model`` (expert parallelism), so under GSPMD
the expert einsum is fully partitioned and dispatch lowers to the
data↔model all-to-all exchange that real MoE systems schedule explicitly.

Tokens beyond capacity are dropped (GShard/Switch semantics); the capacity
factor controls the drop rate.  Router runs in fp32; a Switch-style
load-balance auxiliary loss is returned.

Single-token decode (S == 1) uses a flat whole-batch dispatch instead —
per-row capacity would waste (B, E, 8, d) on one token per row.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import norm


def router_probs(p: Dict[str, Any], h: jax.Array, cfg: ModelConfig):
    """h: (..., d) -> fp32 probs (..., E) + top-k weights/ids."""
    m = cfg.moe
    logits = h.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.experts_per_token)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)   # renormalize
    return probs, top_w, top_i


def load_balance_loss(probs: jax.Array, top_i: jax.Array,
                      num_experts: int) -> jax.Array:
    """Switch-transformer aux loss: E * <f_e> . <p_e> (over all tokens)."""
    probs2 = probs.reshape(-1, num_experts)
    ids = top_i.reshape(-1, top_i.shape[-1])
    assign = jax.nn.one_hot(ids, num_experts, dtype=jnp.float32)
    frac_tokens = jnp.mean(jnp.sum(assign, axis=1), axis=0)
    frac_probs = jnp.mean(probs2, axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_probs)


def capacity(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(num_tokens * m.experts_per_token / m.num_experts
                  * m.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU lane alignment


def _expert_ffn(p: Dict[str, Any], buf: jax.Array, cfg: ModelConfig) -> jax.Array:
    """buf: (..., E, C, d) -> same shape through per-expert FFN."""
    wi = p["wi"].astype(buf.dtype)
    wo = p["wo"].astype(buf.dtype)
    if cfg.mlp_activation == "silu":
        wg = p["wg"].astype(buf.dtype)
        a = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", buf, wi))
        a = a * jnp.einsum("...ecd,edf->...ecf", buf, wg)
    else:
        a = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", buf, wi))
    return jnp.einsum("...ecf,efd->...ecd", a, wo)


def _shared_ffn(p: Dict[str, Any], h: jax.Array, cfg: ModelConfig) -> jax.Array:
    wi = p["shared_wi"].astype(h.dtype)
    wo = p["shared_wo"].astype(h.dtype)
    if cfg.mlp_activation == "silu":
        a = jax.nn.silu(h @ wi) * (h @ p["shared_wg"].astype(h.dtype))
    else:
        a = jax.nn.gelu(h @ wi)
    return a @ wo


def _dispatch_combine(h: jax.Array, top_w: jax.Array, top_i: jax.Array,
                      p: Dict[str, Any], cfg: ModelConfig, C: int
                      ) -> jax.Array:
    """Batched dispatch.  h: (B, N, d); top_w/top_i: (B, N, k) -> (B, N, d).

    The capacity buffer is (B, E, C, d): batch shards over ``data``, experts
    over ``model`` — GSPMD lowers the scatter/gather to the data<->model
    all-to-all exchange of a real expert-parallel system.
    """
    from repro.distributed.act_sharding import BATCH, constrain
    m = cfg.moe
    B, N, d = h.shape
    k = m.experts_per_token
    E = m.num_experts

    flat_e = top_i.reshape(B, N * k)
    flat_w = top_w.reshape(B, N * k)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    # per-row expert counts via batched scatter-add
    rows = jnp.arange(B)[:, None]
    counts = jnp.zeros((B, E), jnp.int32).at[rows, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(N * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1)                       # slot within expert
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)   # drop -> scratch row
    token_src = sort_idx // k

    # Expert-parallel layout, chosen at trace time:
    # * full EP (deepseek: E=256 == model x data = 256): experts shard over
    #   BOTH axes, batch replicates — expert weights live unsharded on
    #   their device (no per-microbatch FSDP all-gather of all experts),
    #   dispatch/combine lower to the data<->expert all-to-all.
    # * legacy (mixtral 8e / jamba 16e): batch over data, experts over
    #   model where divisible.
    from repro.distributed.act_sharding import axis_extent
    ep = axis_extent("model") * axis_extent("data")
    ep_full = ep > 1 and E % ep == 0 and m.layout == "ep_full"
    e_axes = ("model", "data") if ep_full else "model"
    b_axes = None if ep_full else BATCH
    if m.layout == "unconstrained":
        e_axes = b_axes = None

    buf = jnp.zeros((B, E * C + 1, d), h.dtype).at[rows, slot].set(
        h[rows, token_src])
    buf = constrain(buf[:, :-1].reshape(B, E, C, d), b_axes, e_axes,
                    None, None)
    out = _expert_ffn(p, buf, cfg)
    out = constrain(out, b_axes, e_axes, None, None).reshape(B, E * C, d)
    out = jnp.concatenate([out, jnp.zeros((B, 1, d), h.dtype)], axis=1)

    w = jnp.take_along_axis(flat_w, sort_idx, axis=1) * keep
    gathered = out[rows, slot] * w[..., None].astype(h.dtype)
    return jnp.zeros((B, N, d), h.dtype).at[rows, token_src].add(gathered)


def moe_forward(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (residual output, aux loss)."""
    m = cfg.moe
    B, S, d = x.shape
    h = norm(p["norm"], x, cfg)

    probs, top_w, top_i = router_probs(p, h, cfg)
    aux = load_balance_loss(probs, top_i, m.num_experts) * m.router_aux_loss_coef

    if S == 1:
        # decode: flat whole-batch dispatch (1 token per sequence)
        C = capacity(B, cfg)
        y = _dispatch_combine(h.reshape(1, B, d), top_w.reshape(1, B, -1),
                              top_i.reshape(1, B, -1), p, cfg, C
                              ).reshape(B, 1, d)
    else:
        C = capacity(S, cfg)
        y = _dispatch_combine(h, top_w, top_i, p, cfg, C)

    if m.num_shared_experts > 0:
        y = y + _shared_ffn(p, h, cfg)
    return x + y, aux
