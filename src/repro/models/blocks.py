"""Scan-group execution: train/prefill forward and cached decode.

A :class:`repro.models.params.ScanGroup` holds ``depth`` identical layer
units, each a sequence of sublayers (e.g. ``("attn","mlp")`` or the 8-layer
Jamba period).  Parameters are stacked on a leading ``layers`` axis and the
unit is executed under ``jax.lax.scan`` — HLO size stays O(unique layers),
which keeps 126-layer compiles tractable.  Optional rematerialization wraps
the scan body with ``jax.checkpoint``.

The execution context ``ctx`` threads ``attn_impl`` ("naive" | "chunked" |
"pallas" — all differentiable, see :func:`repro.models.layers.run_attention`)
and ``remat`` from the train/eval step down to every attention sublayer, so
the jitted step — not the layer code — owns the kernel choice.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba2, mla, moe
from repro.models.config import ModelConfig
from repro.models.layers import (attn_decode, attn_forward, mlp_forward,
                                 xattn_forward)
from repro.models.params import ScanGroup

PyTree = Any

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


# --------------------------------------------------------------------------- #
# Train / prefill
# --------------------------------------------------------------------------- #

def _sublayer_train(kind: str, p: PyTree, x: jax.Array, aux: jax.Array,
                    cfg: ModelConfig, ctx: Dict[str, Any]):
    impl = ctx.get("attn_impl", "chunked")
    if kind == "attn":
        return attn_forward(p, x, cfg, positions=ctx["positions"],
                            causal=ctx.get("causal", True), impl=impl), aux
    if kind == "mla":
        return mla.mla_forward(p, x, cfg, positions=ctx["positions"],
                               causal=ctx.get("causal", True),
                               impl=impl), aux
    if kind == "mlp":
        return mlp_forward(p, x, cfg), aux
    if kind == "moe":
        y, l = moe.moe_forward(p, x, cfg)
        return y, aux + l
    if kind == "ssm":
        return mamba2.ssm_forward(p, x, cfg,
                                  use_kernel=ctx.get("use_kernel", False)), aux
    if kind == "xattn":
        return xattn_forward(p, x, ctx["enc"], cfg, impl=impl), aux
    raise ValueError(kind)


def group_forward(gparams: PyTree, group: ScanGroup, x: jax.Array,
                  cfg: ModelConfig, ctx: Dict[str, Any]
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden, aux_loss)."""
    from repro.distributed.act_sharding import BATCH, constrain

    def unit(p_unit: PyTree, carry):
        h, aux = carry
        for j, kind in enumerate(group.sublayers):
            h, aux = _sublayer_train(kind, p_unit[f"s{j}_{kind}"], h, aux,
                                     cfg, ctx)
            h = constrain(h, BATCH, None, None)   # keep batch-sharded in scan
        return h, aux

    aux0 = jnp.zeros((), jnp.float32)
    if group.depth == 1:
        return unit(gparams, (x, aux0))

    def body(carry, p_unit):
        return unit(p_unit, carry), None

    policy = REMAT_POLICIES.get(ctx.get("remat", "none"))
    if ctx.get("remat", "none") != "none":
        body = jax.checkpoint(body, policy=policy)
    (h, aux), _ = jax.lax.scan(body, (x, aux0), gparams)
    return h, aux


# --------------------------------------------------------------------------- #
# Decode (single token, cached)
# --------------------------------------------------------------------------- #

def init_sublayer_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                        dtype=jnp.bfloat16) -> PyTree:
    if kind == "attn":
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return {"k": jnp.zeros((batch, max_len, K, hd), dtype),
                "v": jnp.zeros((batch, max_len, K, hd), dtype)}
    if kind == "mla":
        return mla.init_mla_cache(cfg, batch, max_len, dtype)
    if kind == "ssm":
        return mamba2.init_ssm_cache(cfg, batch)
    if kind == "xattn":
        # pre-projected encoder K/V (warmed once by model.warm_cross_cache)
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        T = cfg.encoder_seq_len
        return {"k": jnp.zeros((batch, T, K, hd), dtype),
                "v": jnp.zeros((batch, T, K, hd), dtype)}
    return {}   # stateless: mlp / moe


def _sublayer_decode(kind: str, p: PyTree, x: jax.Array, cache: PyTree,
                     cfg: ModelConfig, ctx: Dict[str, Any]):
    if kind == "attn":
        y, ck, cv = attn_decode(p, x, cfg, cache_k=cache["k"],
                                cache_v=cache["v"], index=ctx["index"],
                                positions=ctx["positions"])
        return y, {"k": ck, "v": cv}
    if kind == "mla":
        return mla.mla_decode(p, x, cfg, cache=cache, index=ctx["index"],
                              positions=ctx["positions"])
    if kind == "ssm":
        return mamba2.ssm_decode(p, x, cfg, cache=cache)
    if kind == "mlp":
        return mlp_forward(p, x, cfg), cache
    if kind == "moe":
        y, _ = moe.moe_forward(p, x, cfg)
        return y, cache
    if kind == "xattn":
        if ctx.get("enc") is not None:
            # legacy path: re-project encoder K/V this step (kept for
            # equivalence tests; the serve path uses the warmed cache)
            return xattn_forward(p, x, ctx["enc"], cfg), cache
        from repro.models.layers import xattn_decode
        return xattn_decode(p, x, cfg, cache_k=cache["k"],
                            cache_v=cache["v"]), cache
    raise ValueError(kind)


def init_group_cache(group: ScanGroup, cfg: ModelConfig, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> PyTree:
    unit = {f"s{j}_{kind}": init_sublayer_cache(kind, cfg, batch, max_len,
                                                dtype)
            for j, kind in enumerate(group.sublayers)}
    if group.depth == 1:
        return unit
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (group.depth,) + a.shape).copy(), unit)


# --------------------------------------------------------------------------- #
# Decode (single token, paged KV cache — the serving-engine path)
# --------------------------------------------------------------------------- #

PAGED_SUBLAYERS = ("attn", "mlp", "moe")


def init_paged_sublayer_cache(kind: str, cfg: ModelConfig, num_blocks: int,
                              block_size: int, dtype=jnp.bfloat16) -> PyTree:
    """Per-sublayer page pools.  Unlike the dense cache there is no batch
    dim — sequences share the pool through their block tables.  With
    ``dtype`` int8 the pools are quantized per (page slot, kv head)
    vector and carry fp32 ``k_scale``/``v_scale`` pools alongside —
    (head_dim + 4) / (2 * head_dim) of the bf16 KV bytes per block."""
    if kind == "attn":
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(dtype)
        pools = {"k_pages": jnp.zeros((num_blocks, block_size, K, hd), dt),
                 "v_pages": jnp.zeros((num_blocks, block_size, K, hd), dt)}
        if dt == jnp.int8:
            pools["k_scale"] = jnp.ones((num_blocks, block_size, K),
                                        jnp.float32)
            pools["v_scale"] = jnp.ones((num_blocks, block_size, K),
                                        jnp.float32)
        return pools
    if kind in ("mlp", "moe"):
        return {}                                  # stateless
    raise NotImplementedError(
        f"paged decode supports sublayers {PAGED_SUBLAYERS}, got {kind!r} "
        "(SSM/MLA/xattn caches are not token-paged)")


def _sublayer_decode_paged(kind: str, p: PyTree, x: jax.Array, cache: PyTree,
                           cfg: ModelConfig, ctx: Dict[str, Any]):
    if kind == "attn":
        from repro.models.layers import attn_decode_paged
        return attn_decode_paged(
            p, x, cfg, cache=cache,
            block_tables=ctx["block_tables"], seq_lens=ctx["seq_lens"],
            positions=ctx["positions"], num_feed=ctx.get("num_feed"),
            impl=ctx.get("attn_impl", "gather"))
    if kind == "mlp":
        return mlp_forward(p, x, cfg), cache
    if kind == "moe":
        y, _ = moe.moe_forward(p, x, cfg)
        return y, cache
    raise NotImplementedError(kind)


def group_decode_paged(gparams: PyTree, group: ScanGroup, x: jax.Array,
                       cache: PyTree, cfg: ModelConfig, ctx: Dict[str, Any]
                       ) -> Tuple[jax.Array, PyTree]:
    def unit(p_unit: PyTree, c_unit: PyTree, h: jax.Array):
        new_c = {}
        for j, kind in enumerate(group.sublayers):
            key = f"s{j}_{kind}"
            h, new_c[key] = _sublayer_decode_paged(kind, p_unit[key], h,
                                                   c_unit[key], cfg, ctx)
        return h, new_c

    if group.depth == 1:
        return unit(gparams, cache, x)

    def body(h, xs):
        p_unit, c_unit = xs
        h, new_c = unit(p_unit, c_unit, h)
        return h, new_c

    h, new_cache = jax.lax.scan(body, x, (gparams, cache))
    return h, new_cache


def group_decode(gparams: PyTree, group: ScanGroup, x: jax.Array,
                 cache: PyTree, cfg: ModelConfig, ctx: Dict[str, Any]
                 ) -> Tuple[jax.Array, PyTree]:
    def unit(p_unit: PyTree, c_unit: PyTree, h: jax.Array):
        new_c = {}
        for j, kind in enumerate(group.sublayers):
            key = f"s{j}_{kind}"
            h, new_c[key] = _sublayer_decode(kind, p_unit[key], h,
                                             c_unit[key], cfg, ctx)
        return h, new_c

    if group.depth == 1:
        return unit(gparams, cache, x)

    def body(h, xs):
        p_unit, c_unit = xs
        h, new_c = unit(p_unit, c_unit, h)
        return h, new_c

    h, new_cache = jax.lax.scan(body, x, (gparams, cache))
    return h, new_cache
