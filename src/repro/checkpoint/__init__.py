"""Elastic placement-aware checkpointing.

``ckpt`` does the file I/O (save / restore / reshard / prune),
``spec.CheckpointSpec`` carries the placement-derived sharding contract,
and ``elastic`` prices writes and bytes-actually-missing recovery over
the wide-area topology for the orchestrator and the fault-strategy
frontier.
"""

from repro.checkpoint import ckpt
from repro.checkpoint.ckpt import (HealReport, RestorePolicy,
                                   ShardChecksumError, ShardReadError)
from repro.checkpoint.elastic import (TransferCost, heal_cost,
                                      recovery_cost, state_layer_bytes,
                                      write_cost)
from repro.checkpoint.spec import CheckpointSpec

__all__ = ["ckpt", "CheckpointSpec", "HealReport", "RestorePolicy",
           "ShardChecksumError", "ShardReadError", "TransferCost",
           "heal_cost", "recovery_cost", "state_layer_bytes",
           "write_cost"]
