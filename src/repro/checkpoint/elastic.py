"""Pricing elastic checkpoint write / recovery over the wide-area net.

The §5 trade-off (checkpointing vs replication vs recomputation) only
means something if recovery is priced from *bytes actually missing*, not
a constant: a device that survives churn keeps its shard on local disk
and pays nothing; a joining device fetches only the layer slices its new
stage owns, from the nearest surviving holder (intra-region first, WAN
only when no same-region copy exists, the durable backbone store as the
last resort).  The naive baseline — every node of the new placement
pulls the *full* state from the store across the WAN — is what a
placement-blind checkpoint forces and what
:mod:`benchmarks.bench_elastic` gates the win against.

Transfers into distinct nodes run concurrently (disjoint access links);
transfers into the same node serialize on its access link — the same
alpha-beta discipline as :mod:`repro.core.net.collectives`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.checkpoint.spec import CheckpointSpec
from repro.core.net import Topology

STORE = "__store__"                   # durable copy at the backbone


@dataclass
class TransferCost:
    """Aggregate of one write or recovery round of transfers."""
    time_s: float = 0.0               # concurrent nodes, serialized per node
    bytes_moved: float = 0.0
    wan_bytes: float = 0.0            # subset crossing regions / the store
    energy_wh: float = 0.0            # radio energy of every endpoint
    per_region_bytes: Dict[str, float] = field(default_factory=dict)
    transfers: int = 0

    def _add_region(self, region: str, nbytes: float) -> None:
        self.per_region_bytes[region] = \
            self.per_region_bytes.get(region, 0.0) + nbytes


def state_layer_bytes(cfg, param_dtype: int = 2, moment_dtype: int = 4
                      ) -> Tuple[float, float]:
    """(bytes per decoder layer, placement-independent bytes) of the
    checkpointed train state (weights + two Adam moments — grads are not
    checkpointed)."""
    from repro.models import params as PM

    def _size(sub) -> int:
        tot, stack = 0, [sub]
        while stack:
            x = stack.pop()
            if isinstance(x, dict):
                stack.extend(x.values())
            else:
                tot += x.size()
        return tot

    spec = PM.model_spec(cfg)
    total = _size(spec)
    dec = _size(spec["decoder"])
    per_param = param_dtype + 2 * moment_dtype
    return (dec * per_param / max(cfg.num_layers, 1),
            (total - dec) * per_param)


def _store_rtt_s(topo: Topology, node: str, nbytes: float) -> float:
    """Device <-> durable backbone store: access link then WAN uplink."""
    p = topo.params
    bw = min(topo.access_bw_Bps(node), p.wan_bw_Bps)
    delay = (p.access_latency_s + p.access_jitter_s
             + p.wan_latency_s + p.wan_jitter_s)
    return delay + nbytes / bw


def _charge(cost: TransferCost, busy: Dict[str, float], topo: Topology,
            src: str, dst: str, nbytes: float) -> None:
    """One transfer src -> dst (src may be the backbone STORE)."""
    if nbytes <= 0:
        return
    region = topo.device_region
    if STORE in (src, dst):
        dev = dst if src == STORE else src
        t = _store_rtt_s(topo, dev, nbytes)
        cost.wan_bytes += nbytes
        busy[dev] = busy.get(dev, 0.0) + t
    else:
        t = topo.p2p_time_s(nbytes, src, dst)
        if region[src] != region[dst]:
            cost.wan_bytes += nbytes
        busy[src] = busy.get(src, 0.0) + t
        busy[dst] = busy.get(dst, 0.0) + t
    cost.bytes_moved += nbytes
    cost._add_region(region[dst] if dst != STORE else "store", nbytes)
    cost.transfers += 1


def _finalize(cost: TransferCost, busy: Dict[str, float], topo: Topology
              ) -> TransferCost:
    cost.time_s = max(busy.values(), default=0.0)
    for n, t in busy.items():
        if n in topo.device_spec:
            cost.energy_wh += topo.device_spec[n].power_comm_w * t / 3600.0
    return cost


def write_cost(topo: Topology, placement, spec: CheckpointSpec,
               layer_bytes: float, global_bytes: float) -> TransferCost:
    """Price one checkpoint write under ``spec``.

    Every stage node snapshots its own slice to local disk for free;
    the network pays for (a) §5 neighbour replication — each writer
    pushes its shard to its ``replication`` downstream pipeline
    neighbours — and (b) one durable copy, uploaded shard-by-shard by
    replica 0 to the backbone store (stage 0 also uploads the
    placement-independent leaves).
    """
    cost = TransferCost()
    busy: Dict[str, float] = {}
    slices = spec.slices()
    for ri, pipe in enumerate(placement.pipelines):
        S = len(pipe)
        for i, sp in enumerate(pipe):
            shard_b = (slices[i][1] - slices[i][0]) * layer_bytes
            for k in range(1, spec.replication + 1):
                dst = pipe[(i + k) % S].node
                _charge(cost, busy, topo, sp.node, dst, shard_b)
            if ri == 0:
                up = shard_b + (global_bytes if i == 0 else 0.0)
                _charge(cost, busy, topo, sp.node, STORE, up)
    return _finalize(cost, busy, topo)


def heal_cost(topo: Topology,
              fetches: List[Tuple[str, str, float]]) -> TransferCost:
    """Price a self-healing restore's shard re-fetches.

    ``fetches`` is ``(src_holder, dst_node, nbytes)`` per healed file —
    what :func:`repro.checkpoint.ckpt.heal_step` reports, mapped onto
    fleet nodes.  Same alpha-beta discipline as write/recovery: fetches
    into distinct nodes run concurrently, a source may be the backbone
    ``STORE`` (the WAN-priced last resort when every neighbour copy of a
    shard rotted).
    """
    cost = TransferCost()
    busy: Dict[str, float] = {}
    for src, dst, nbytes in fetches:
        _charge(cost, busy, topo, src, dst, nbytes)
    return _finalize(cost, busy, topo)


def _best_source(topo: Topology, dst: str, holders) -> Optional[str]:
    """Nearest surviving holder of a shard: the destination itself
    (free), else same-region, else any region, else the store."""
    region = topo.device_region
    alive = [h for h in holders if h in region]
    if dst in alive:
        return None
    same = sorted(h for h in alive if region[h] == region[dst])
    if same:
        return same[0]
    other = sorted(h for h in alive)
    if other:
        return other[0]
    return STORE


def recovery_cost(topo: Topology, new_placement, *,
                  old_spec: Optional[CheckpointSpec],
                  layer_bytes: float, global_bytes: float,
                  naive: bool = False) -> TransferCost:
    """Price restoring checkpointed state onto ``new_placement``.

    Placement-aware (default): each stage node of the new placement
    fetches only the layer ranges it does not already hold, per old
    shard, from the nearest surviving holder; brand-new nodes also fetch
    the placement-independent leaves from any old node.  ``naive=True``
    (or ``old_spec=None``) prices the placement-blind baseline: every
    node pulls the full state from the backbone store.
    """
    cost = TransferCost()
    busy: Dict[str, float] = {}
    L = new_placement.num_layers
    if old_spec is not None and old_spec.num_layers != L:
        raise ValueError(f"checkpoint spec has {old_spec.num_layers} "
                         f"layers, new placement {L}")
    total_bytes = L * layer_bytes + global_bytes
    if naive or old_spec is None:
        for pipe in new_placement.pipelines:
            for sp in pipe:
                _charge(cost, busy, topo, STORE, sp.node, total_bytes)
        return _finalize(cost, busy, topo)

    old_slices = old_spec.slices()
    old_nodes = sorted({n for hs in old_spec.holders for n in hs})
    for pipe in new_placement.pipelines:
        for sp in pipe:
            a, b = sp.layers.start, sp.layers.stop
            for o, (c, d) in enumerate(old_slices):
                lo, hi = max(a, c), min(b, d)
                if lo >= hi:
                    continue
                holders = old_spec.holders[o] if old_spec.holders else ()
                src = _best_source(topo, sp.node, holders)
                if src is None:
                    continue          # survivor still holds this range
                _charge(cost, busy, topo, src, sp.node,
                        (hi - lo) * layer_bytes)
            if sp.node not in old_nodes:
                # a joining device also needs the placement-independent
                # leaves (every old node replicates them)
                src = _best_source(topo, sp.node, old_nodes)
                if src is not None:
                    _charge(cost, busy, topo, src, sp.node, global_bytes)
    return _finalize(cost, busy, topo)
