"""Pure-numpy sharded checkpointing (no orbax dependency).

Flat key/value layout: each leaf saved as ``<step>/<escaped-path>.npy``
plus a json manifest.  Supports the orchestrator's fault-tolerance loop
(write interval / restore) and partial proactive replication (§5): a
checkpoint can be written in ``num_shards`` slices so stage-local replicas
hold only their neighbours' shards.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

PyTree = Any


def _escape(path_str: str) -> str:
    return path_str.replace("/", "_").replace("'", "").replace("[", "(") \
        .replace("]", ")")


def _leaf_paths(tree: PyTree) -> List[str]:
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save(directory: str | Path, step: int, tree: PyTree, *,
         num_shards: int = 1, shard_id: int = 0) -> Path:
    """Write (a shard of) a checkpoint; returns the step directory."""
    d = Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "num_leaves": len(flat),
                "num_shards": num_shards,
                "keys": [jax.tree_util.keystr(p) for p, _ in flat]}
    for i, (path, leaf) in enumerate(flat):
        if i % num_shards != shard_id:
            continue
        a = np.asarray(leaf)
        if a.dtype.kind == "V" and a.dtype.itemsize == 2:
            # ml_dtypes.bfloat16 has no numpy cast path: store the bit
            # pattern as uint16 (restore views it back via proto.dtype)
            a = a.view(np.uint16)
        np.save(d / (_escape(jax.tree_util.keystr(path)) + ".npy"), a)
    (d / f"manifest_{shard_id}.json").write_text(json.dumps(manifest))
    return d


def restore(directory: str | Path, tree_like: PyTree,
            step: Optional[int] = None) -> PyTree:
    """Restore into the structure of ``tree_like`` (dtypes preserved)."""
    base = Path(directory)
    if step is None:
        steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {base}")
        step = steps[-1]
    d = base / f"step_{step:08d}"
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, proto in flat:
        f = d / (_escape(jax.tree_util.keystr(path)) + ".npy")
        arr = np.load(f)
        if arr.dtype == np.uint16 and jax.numpy.dtype(proto.dtype) \
                .itemsize == 2 and jax.numpy.dtype(proto.dtype).kind == "V":
            arr = arr.view(jax.numpy.dtype(proto.dtype))
        leaves.append(jax.numpy.asarray(arr, dtype=proto.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def latest_step(directory: str | Path) -> Optional[int]:
    base = Path(directory)
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*"))
    return steps[-1] if steps else None


def prune(directory: str | Path, keep: int = 2) -> None:
    base = Path(directory)
    steps = sorted(base.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p)
