"""Pure-numpy elastic sharded checkpointing (no orbax dependency).

Flat key/value layout under ``<dir>/step_<N>/``: every leaf is one or
more ``.npy`` files plus per-writer json manifests.  Two shard layouts:

* ``leaf_modulo`` — the legacy layout: leaf ``i`` belongs to shard
  ``i % num_shards`` and is saved whole.  Placement-blind; kept for
  single-host trainers and as the compatibility path.
* ``layer_sliced`` — the elastic layout, driven by a
  :class:`~repro.checkpoint.spec.CheckpointSpec` derived from the
  :class:`~repro.core.placement.PlacementSpec` that is executing: each
  stage shard saves its contiguous layer-range slice of every
  scan-stacked decoder leaf (file ``<leaf>.L<a>-<b>.npy`` holds
  ``leaf[a:b]``), non-layer leaves are distributed round-robin, and
  ``replication`` makes each writer also persist its upstream
  neighbours' shards (§5 partial proactive replication).  Because slice
  files are named by *layer range*, not by writer, the layout is
  placement-agnostic on read: :func:`restore_for_placement` re-slices
  the stacked layer arrays across *different* stage boundaries, so a
  3-stage checkpoint restores bit-identically onto a 2-stage fleet
  (and back) after churn.

``restore`` validates completeness against the manifest before touching
any array and raises one :class:`IncompleteCheckpointError` naming every
missing leaf/shard file; ``prune`` is shard-aware: only steps complete
across all shards count toward ``keep``, and a newer still-incomplete
(in-flight) step is never deleted.

**Self-healing** (this file's robustness layer): every data file's CRC32
lands in the manifest at write time, so silent bit-rot is detectable on
read, not just absence.  Restores go through a
:class:`RestorePolicy` — transient I/O errors are retried with
exponential backoff and every *still*-unreadable shard is named in one
aggregated :class:`ShardReadError`; with ``sources=`` (neighbour
``held_shards`` holders, per the spec's §5 replication) a missing or
corrupt shard is **re-fetched** from the first holder whose copy
checksums clean — retry + backoff + a per-source wall-clock budget so
one dead holder cannot stall the heal — and the fetch is priced through
:func:`repro.checkpoint.elastic.heal_cost`.  A corrupted survivor thus
degrades to a neighbour (or WAN) fetch instead of a crash.
"""

from __future__ import annotations

import io
import json
import shutil
import time
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.checkpoint.spec import CheckpointSpec

PyTree = Any

LAYOUT_LEAF_MODULO = "leaf_modulo"
LAYOUT_LAYER_SLICED = "layer_sliced"


class IncompleteCheckpointError(FileNotFoundError):
    """A restore/validation found manifest-expected files missing."""


class ShardReadError(IncompleteCheckpointError):
    """Shard files present but unreadable (corrupt or persistent I/O
    failure) after retries and healing; names every bad shard."""


class ShardChecksumError(ValueError):
    """A shard file's bytes do not match its manifest CRC32."""


@dataclass(frozen=True)
class RestorePolicy:
    """Retry/heal discipline for shard reads.

    ``retries`` transient-I/O retries per file with exponential backoff
    starting at ``backoff_s``; checksum mismatches are *not* retried
    locally (bit-rot is deterministic) — they go to the heal path.  Each
    heal source gets at most ``source_timeout_s`` of cumulative
    wall-clock before it is skipped for the remaining files.
    """
    retries: int = 2
    backoff_s: float = 0.05
    source_timeout_s: float = 5.0
    verify_checksums: bool = True


@dataclass
class HealReport:
    """What a self-healing restore actually did."""
    healed: List[Dict[str, Any]] = field(default_factory=list)
    # each: {file, reason: missing|corrupt, source, bytes}
    unrecovered: List[str] = field(default_factory=list)
    bytes_fetched: int = 0
    per_source_bytes: Dict[str, int] = field(default_factory=dict)
    retried_reads: int = 0
    sources_timed_out: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.unrecovered


def _escape(path_str: str) -> str:
    return path_str.replace("/", "_").replace("'", "").replace("[", "(") \
        .replace("]", ")")


def _slice_name(key: str, a: int, b: int) -> str:
    return f"{_escape(key)}.L{a:05d}-{b:05d}.npy"


def _leaf_name(key: str) -> str:
    return _escape(key) + ".npy"


def _flat(tree: PyTree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def _save_array(path: Path, leaf) -> int:
    """Write one ``.npy``; returns the CRC32 of the bytes written (the
    manifest records it so bit-rot is detectable on read)."""
    a = np.asarray(leaf)
    if a.dtype.kind == "V" and a.dtype.itemsize == 2:
        # ml_dtypes.bfloat16 has no numpy cast path: store the bit
        # pattern as uint16 (restore views it back via proto.dtype)
        a = a.view(np.uint16)
    buf = io.BytesIO()
    np.save(buf, a)
    data = buf.getvalue()
    path.write_bytes(data)
    return zlib.crc32(data)


def _read_bytes_retry(path: Path, policy: RestorePolicy,
                      report: Optional[HealReport] = None) -> bytes:
    """Read raw bytes, retrying transient I/O errors with exponential
    backoff.  Missing files are not transient — they raise immediately
    (the caller's completeness/heal machinery owns that case)."""
    delay = policy.backoff_s
    for attempt in range(policy.retries + 1):
        try:
            return path.read_bytes()
        except FileNotFoundError:
            raise
        except OSError:
            if attempt == policy.retries:
                raise
            if report is not None:
                report.retried_reads += 1
            time.sleep(delay)
            delay *= 2


_DEFAULT_POLICY = RestorePolicy()


def _load_array(path: Path, proto_dtype, *,
                crc: Optional[int] = None,
                policy: RestorePolicy = _DEFAULT_POLICY,
                report: Optional[HealReport] = None) -> np.ndarray:
    data = _read_bytes_retry(path, policy, report)
    if crc is not None and policy.verify_checksums \
            and zlib.crc32(data) != crc:
        raise ShardChecksumError(
            f"{path.name}: CRC32 mismatch against manifest (bit-rot or "
            "partial write)")
    try:
        arr = np.load(io.BytesIO(data), allow_pickle=False)
    except ValueError as e:
        raise ShardChecksumError(f"{path.name}: unparseable npy "
                                 f"({e})") from e
    pd = jax.numpy.dtype(proto_dtype)
    if arr.dtype == np.uint16 and pd.itemsize == 2 and pd.kind == "V":
        arr = arr.view(pd)
    return arr


def _is_layer_leaf(key: str, leaf, num_layers: int) -> bool:
    """Scan-stacked decoder leaf: leading axis is the layer stack.

    Same contract as the pipeline executor (uniform dense decoder
    stacks): the leaf sits under ``decoder`` and its leading dim equals
    ``num_layers``.  Everything else (embeddings, lm head, norms,
    optimizer scalars) is placement-independent and saved whole.
    """
    shape = np.shape(leaf)
    return ("decoder" in key and len(shape) >= 1
            and shape[0] == num_layers and num_layers > 1)


# --------------------------------------------------------------------------- #
# Saving
# --------------------------------------------------------------------------- #

def _step_dir(directory: Union[str, Path], step: int) -> Path:
    return Path(directory) / f"step_{step:08d}"


def save(directory: Union[str, Path], step: int, tree: PyTree, *,
         num_shards: int = 1, shard_id: int = 0) -> Path:
    """Write (a leaf-modulo shard of) a checkpoint; returns the step dir."""
    d = _step_dir(directory, step)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flat(tree)
    checksums: Dict[str, int] = {}
    manifest = {"step": step, "layout": LAYOUT_LEAF_MODULO,
                "num_leaves": len(flat), "num_shards": num_shards,
                "shard_id": shard_id,
                "keys": [jax.tree_util.keystr(p) for p, _ in flat],
                "checksums": checksums}
    for i, (path, leaf) in enumerate(flat):
        if i % num_shards != shard_id:
            continue
        name = _leaf_name(jax.tree_util.keystr(path))
        checksums[name] = _save_array(d / name, leaf)
    (d / f"manifest_{shard_id}.json").write_text(json.dumps(manifest))
    return d


def save_sharded(directory: Union[str, Path], step: int, tree: PyTree,
                 spec: CheckpointSpec, shard_id: int) -> Path:
    """Write stage-shard ``shard_id`` of a layer-sliced checkpoint.

    The writer persists its own layer-range slices plus (per
    ``spec.replication``) its upstream neighbours' — slice files are
    named by layer range, so neighbour copies land on the same paths and
    the union stays complete even if one writer never finishes.
    """
    if not 0 <= shard_id < spec.num_shards:
        raise ValueError(f"shard_id={shard_id} outside "
                         f"0..{spec.num_shards - 1}")
    d = _step_dir(directory, step)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flat(tree)
    keys = [jax.tree_util.keystr(p) for p, _ in flat]
    layer_keys = [k for k, (_, leaf) in zip(keys, flat)
                  if _is_layer_leaf(k, leaf, spec.num_layers)]
    layer_set = set(layer_keys)
    held = set(spec.held_shards(shard_id))
    slices = spec.slices()
    checksums: Dict[str, int] = {}
    nonlayer_i = 0
    for key, (_, leaf) in zip(keys, flat):
        if key in layer_set:
            for s in held:
                a, b = slices[s]
                name = _slice_name(key, a, b)
                checksums[name] = _save_array(d / name,
                                              np.asarray(leaf)[a:b])
        else:
            if nonlayer_i % spec.num_shards in held:
                name = _leaf_name(key)
                checksums[name] = _save_array(d / name, leaf)
            nonlayer_i += 1
    manifest = {"step": step, "layout": LAYOUT_LAYER_SLICED,
                "num_leaves": len(flat), "num_shards": spec.num_shards,
                "shard_id": shard_id, "keys": keys,
                "layer_keys": layer_keys,
                "num_layers": spec.num_layers,
                "boundaries": list(spec.boundaries),
                "replication": spec.replication,
                "holders": [list(h) for h in spec.holders],
                "checksums": checksums}
    (d / f"manifest_{shard_id}.json").write_text(json.dumps(manifest))
    return d


def _as_ckpt_spec(spec, replication: int = 0) -> CheckpointSpec:
    if isinstance(spec, CheckpointSpec):
        if replication and replication != spec.replication:
            # an explicit nonzero replication= wins over the spec's
            return CheckpointSpec(
                spec.num_layers, spec.boundaries,
                min(replication, spec.num_shards - 1), spec.holders)
        return spec
    if hasattr(spec, "pipelines"):               # PlacementSpec duck-type
        return CheckpointSpec.from_placement(spec, replication)
    raise TypeError(f"expected CheckpointSpec or PlacementSpec, got "
                    f"{type(spec).__name__}")


def save_for_placement(directory: Union[str, Path], step: int, tree: PyTree,
                       spec, *, replication: int = 0) -> Path:
    """Write every stage shard of a layer-sliced checkpoint.

    ``spec`` is a :class:`CheckpointSpec` or a ``PlacementSpec`` (each
    stage slot then saves exactly the layer range it executes).  This is
    the host-side simulation of all stage writers; a real fleet calls
    :func:`save_sharded` once per stage.
    """
    cspec = _as_ckpt_spec(spec, replication)
    d = _step_dir(directory, step)
    for s in range(cspec.num_shards):
        save_sharded(directory, step, tree, cspec, s)
    return d


# --------------------------------------------------------------------------- #
# Manifest reading + completeness validation
# --------------------------------------------------------------------------- #

def _read_manifest(d: Path) -> Dict[str, Any]:
    manifests = sorted(d.glob("manifest_*.json"))
    if not manifests:
        raise FileNotFoundError(f"no checkpoint manifest under {d}")
    m = json.loads(manifests[0].read_text())
    m.setdefault("layout", LAYOUT_LEAF_MODULO)
    m.setdefault("checksums", {})
    # each writer's manifest carries CRCs for only its held files;
    # verification needs the union (replicated copies share one CRC —
    # slice files are content-addressed by layer range)
    for extra in manifests[1:]:
        try:
            m["checksums"].update(
                json.loads(extra.read_text()).get("checksums", {}))
        except (json.JSONDecodeError, OSError):
            continue
    m["_manifests_present"] = len(manifests)
    return m


def _expected_files(m: Dict[str, Any]) -> List[Tuple[str, str]]:
    """Every data file the manifest expects: ``(filename, description)``
    naming the leaf and the shard responsible for writing it."""
    out: List[Tuple[str, str]] = []
    S = int(m.get("num_shards", 1))
    if m["layout"] == LAYOUT_LEAF_MODULO:
        for i, key in enumerate(m["keys"]):
            out.append((_leaf_name(key),
                        f"(leaf {key}, shard {i % S})"))
        return out
    layer_set = set(m["layer_keys"])
    slices = list(zip(m["boundaries"][:-1], m["boundaries"][1:]))
    nonlayer_i = 0
    for key in m["keys"]:
        if key in layer_set:
            for s, (a, b) in enumerate(slices):
                out.append((_slice_name(key, a, b),
                            f"(leaf {key} layers {a}:{b}, shard {s})"))
        else:
            out.append((_leaf_name(key),
                        f"(leaf {key}, shard {nonlayer_i % S})"))
            nonlayer_i += 1
    return out


def _missing_files(d: Path, m: Dict[str, Any]) -> List[str]:
    """Manifest-expected data files absent on disk."""
    return [f"{name} {desc}" for name, desc in _expected_files(m)
            if not (d / name).exists()]


def _validate(d: Path) -> Dict[str, Any]:
    m = _read_manifest(d)
    missing = _missing_files(d, m)
    if missing:
        shown = "\n  ".join(missing[:20])
        more = f"\n  ... and {len(missing) - 20} more" \
            if len(missing) > 20 else ""
        raise IncompleteCheckpointError(
            f"checkpoint {d} is incomplete ({len(missing)} of its "
            f"manifest's files missing):\n  {shown}{more}")
    return m


def _step_complete(d: Path) -> bool:
    try:
        _validate(d)
        return True
    except (FileNotFoundError, json.JSONDecodeError):
        return False


def _all_steps(directory: Union[str, Path]) -> List[int]:
    return sorted(int(p.name.split("_")[1])
                  for p in Path(directory).glob("step_*"))


def latest_step(directory: Union[str, Path]) -> Optional[int]:
    steps = _all_steps(directory)
    return steps[-1] if steps else None


def complete_steps(directory: Union[str, Path]) -> List[int]:
    """Steps whose manifest-expected files are all present."""
    base = Path(directory)
    return [s for s in _all_steps(directory)
            if _step_complete(_step_dir(base, s))]


def latest_complete_step(directory: Union[str, Path]) -> Optional[int]:
    steps = complete_steps(directory)
    return steps[-1] if steps else None


def _resolve_step(directory: Union[str, Path], step: Optional[int]) -> Path:
    base = Path(directory)
    if step is not None:
        return _step_dir(base, step)
    steps = _all_steps(base)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {base}")
    complete = [s for s in steps if _step_complete(_step_dir(base, s))]
    if complete:
        return _step_dir(base, complete[-1])
    # nothing complete: surface the newest step's precise gap
    return _step_dir(base, steps[-1])


# --------------------------------------------------------------------------- #
# Self-healing: checksum audit + re-fetch from neighbour holders
# --------------------------------------------------------------------------- #

def _crc_ok(path: Path, crc: Optional[int]) -> bool:
    if crc is None:
        return True                   # legacy manifest: existence only
    try:
        return zlib.crc32(path.read_bytes()) == crc
    except OSError:
        return False


def damaged_files(directory: Union[str, Path],
                  step: Optional[int] = None) -> List[Tuple[str, str]]:
    """Audit one step: ``(filename, reason)`` for every manifest-expected
    file that is absent (``missing``) or fails its CRC32 (``corrupt``).
    Empty list == the step restores clean."""
    d = _resolve_step(directory, step)
    m = _read_manifest(d)
    crcs = m.get("checksums", {})
    out: List[Tuple[str, str]] = []
    for name, _ in _expected_files(m):
        f = d / name
        if not f.exists():
            out.append((name, "missing"))
        elif not _crc_ok(f, crcs.get(name)):
            out.append((name, "corrupt"))
    return out


def _norm_sources(sources) -> List[Tuple[str, Path]]:
    """``sources`` entries are directories or ``(holder_name, dir)``
    pairs; plain directories are labelled by their own path."""
    out: List[Tuple[str, Path]] = []
    for s in sources:
        if isinstance(s, (tuple, list)) and len(s) == 2:
            out.append((str(s[0]), Path(s[1])))
        else:
            out.append((str(s), Path(s)))
    return out


def heal_step(directory: Union[str, Path], step: Optional[int] = None, *,
              sources: Sequence = (),
              policy: Optional[RestorePolicy] = None) -> HealReport:
    """Repair a damaged step in place from neighbour holders.

    Every missing/corrupt file (per :func:`damaged_files`) is re-fetched
    from the first source whose copy checksums clean against the
    manifest.  ``sources`` are the §5 ``held_shards`` holders — their
    local copy of the step directory (or its parent checkpoint dir).
    Per-source discipline: transient reads retry with backoff; a source
    whose cumulative wall-clock exceeds ``policy.source_timeout_s`` is
    skipped for the remaining files (one dead holder must not stall the
    heal).  Detection and repair land on the obs timeline as
    ``fault.corrupt`` / ``fault.heal`` instants; the caller prices the
    fetched bytes through :func:`repro.checkpoint.elastic.heal_cost`.
    """
    from repro.obs.trace import get_tracer
    policy = policy or _DEFAULT_POLICY
    d = _resolve_step(directory, step)
    m = _read_manifest(d)
    crcs = m.get("checksums", {})
    tr = get_tracer()
    report = HealReport()
    damaged = damaged_files(directory, step)
    if not damaged:
        return report
    srcs = _norm_sources(sources)
    spent: Dict[str, float] = {name: 0.0 for name, _ in srcs}
    for name, reason in damaged:
        tr.instant("fault.corrupt", "fault", track="faults",
                   entity=name, reason=reason, step=d.name)
        healed = False
        for holder, sdir in srcs:
            if spent[holder] > policy.source_timeout_s:
                if holder not in report.sources_timed_out:
                    report.sources_timed_out.append(holder)
                continue
            t0 = time.monotonic()
            try:
                cand = sdir / name
                if not cand.exists():
                    cand = sdir / d.name / name
                data = _read_bytes_retry(cand, policy, report)
            except OSError:
                spent[holder] += time.monotonic() - t0
                continue
            spent[holder] += time.monotonic() - t0
            crc = crcs.get(name)
            if crc is not None and zlib.crc32(data) != crc:
                continue              # this holder's copy rotted too
            (d / name).write_bytes(data)
            report.healed.append({"file": name, "reason": reason,
                                  "source": holder, "bytes": len(data)})
            report.bytes_fetched += len(data)
            report.per_source_bytes[holder] = \
                report.per_source_bytes.get(holder, 0) + len(data)
            tr.instant("fault.heal", "fault", track="faults",
                       entity=name, source=holder, nbytes=len(data),
                       reason=reason)
            healed = True
            break
        if not healed:
            report.unrecovered.append(f"{name} ({reason}, no clean "
                                      "source copy)")
    return report


# --------------------------------------------------------------------------- #
# Restoring
# --------------------------------------------------------------------------- #

def _check_keys(m: Dict[str, Any], keys: Sequence[str], d: Path) -> None:
    a, b = set(m["keys"]), set(keys)
    if a != b:
        extra = sorted(b - a)[:5]
        lacking = sorted(a - b)[:5]
        raise ValueError(
            f"tree structure does not match checkpoint {d}: "
            f"{len(b - a)} leaves absent from the checkpoint "
            f"(e.g. {extra}), {len(a - b)} checkpoint leaves unused "
            f"(e.g. {lacking})")


def _layer_key_set(m: Dict[str, Any]) -> set:
    if "_layer_key_set" not in m:                 # memoized per manifest
        m["_layer_key_set"] = set(m.get("layer_keys", []))
    return m["_layer_key_set"]


def _assemble_leaf(d: Path, m: Dict[str, Any], key: str, proto,
                   span: Optional[Tuple[int, int]] = None,
                   policy: RestorePolicy = _DEFAULT_POLICY,
                   report: Optional[HealReport] = None) -> np.ndarray:
    """Load one leaf; layer leaves re-slice across the manifest's
    boundaries, optionally cropped to ``span`` (a new stage's range)."""
    crcs = m.get("checksums", {})
    if m["layout"] == LAYOUT_LAYER_SLICED and key in _layer_key_set(m):
        lo, hi = span if span is not None else (0, m["num_layers"])
        parts = []
        for a, b in zip(m["boundaries"][:-1], m["boundaries"][1:]):
            s, e = max(a, lo), min(b, hi)
            if s >= e:
                continue
            name = _slice_name(key, a, b)
            arr = _load_array(d / name, proto.dtype,
                              crc=crcs.get(name), policy=policy,
                              report=report)
            parts.append(arr[s - a:e - a])
        return np.concatenate(parts, axis=0)
    name = _leaf_name(key)
    return _load_array(d / name, proto.dtype, crc=crcs.get(name),
                       policy=policy, report=report)


def _assemble_all(d: Path, m: Dict[str, Any], flat, spans,
                  policy: RestorePolicy,
                  report: Optional[HealReport]) -> List[Any]:
    """Assemble every leaf, aggregating read failures: one
    :class:`ShardReadError` names every shard that stayed unreadable
    after the policy's retries (mirrors the up-front
    :class:`IncompleteCheckpointError` for missing files)."""
    leaves: List[Any] = []
    bad: List[str] = []
    for (path, proto), span in zip(flat, spans):
        key = jax.tree_util.keystr(path)
        try:
            leaves.append(jax.numpy.asarray(
                _assemble_leaf(d, m, key, proto, span, policy, report),
                dtype=proto.dtype))
        except (OSError, ShardChecksumError) as e:
            bad.append(f"{key}: {e}")
    if bad:
        shown = "\n  ".join(bad[:20])
        more = f"\n  ... and {len(bad) - 20} more" if len(bad) > 20 \
            else ""
        raise ShardReadError(
            f"checkpoint {d}: {len(bad)} shard file(s) unreadable after "
            f"{policy.retries} retries (pass sources= to re-fetch from "
            f"neighbour holders):\n  {shown}{more}")
    return leaves


def restore(directory: Union[str, Path], tree_like: PyTree,
            step: Optional[int] = None, *, sources: Sequence = (),
            policy: Optional[RestorePolicy] = None,
            heal_report: Optional[HealReport] = None) -> PyTree:
    """Restore into the structure of ``tree_like`` (dtypes preserved).

    Works for both layouts; layer-sliced checkpoints are reassembled
    across whatever boundaries their manifest records, so the restoring
    placement need not match the writing one.  Completeness is validated
    up front: a partial checkpoint raises one
    :class:`IncompleteCheckpointError` naming every missing file.

    Robustness: shard reads are checksum-verified and retried per
    ``policy``; persistent failures aggregate into one
    :class:`ShardReadError` naming every unreadable shard.  With
    ``sources=`` (neighbour holder directories), missing/corrupt shards
    self-heal first via :func:`heal_step` — pass ``heal_report`` to
    observe what was fetched from whom.
    """
    policy = policy or _DEFAULT_POLICY
    d = _resolve_step(directory, step)
    if sources:
        rep = heal_step(directory, step, sources=sources, policy=policy)
        if heal_report is not None:
            heal_report.__dict__.update(rep.__dict__)
    m = _validate(d)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    _check_keys(m, [jax.tree_util.keystr(p) for p, _ in flat], d)
    leaves = _assemble_all(d, m, flat, [None] * len(flat), policy,
                           heal_report)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_for_placement(directory: Union[str, Path], new_spec,
                          tree_like: PyTree, step: Optional[int] = None,
                          *, stage: Optional[int] = None,
                          sources: Sequence = (),
                          policy: Optional[RestorePolicy] = None,
                          heal_report: Optional[HealReport] = None
                          ) -> PyTree:
    """Restore a checkpoint onto a *different* placement.

    ``new_spec`` is the placement (or :class:`CheckpointSpec` /
    boundary list) that will execute next.  With ``stage=None`` the full
    tree is reassembled (identical to :func:`restore` — layer slices
    concatenate across the old boundaries regardless of the new ones).
    With ``stage=s`` only that stage's state is materialized: layer
    leaves come back cropped to the new stage's ``[start, stop)`` range,
    reading only the old slice files that overlap it — the
    bytes-actually-missing read set a joining device fetches.

    Same robustness contract as :func:`restore`: checksum-verified
    retried reads, one aggregated :class:`ShardReadError`, and
    ``sources=`` self-healing through :func:`heal_step` — the
    orchestrator's churn path, so a corrupted survivor degrades to a
    neighbour/WAN fetch instead of a crash.
    """
    policy = policy or _DEFAULT_POLICY
    if isinstance(new_spec, CheckpointSpec):
        bounds: List[int] = list(new_spec.boundaries)
    elif hasattr(new_spec, "boundaries"):         # PlacementSpec duck-type
        bounds = list(new_spec.boundaries)
    else:
        bounds = list(new_spec)
    d = _resolve_step(directory, step)
    if sources:
        rep = heal_step(directory, step, sources=sources, policy=policy)
        if heal_report is not None:
            heal_report.__dict__.update(rep.__dict__)
    m = _validate(d)
    if m["layout"] == LAYOUT_LAYER_SLICED and m["num_layers"] != bounds[-1]:
        raise ValueError(
            f"checkpoint has {m['num_layers']} layers but the new "
            f"placement expects {bounds[-1]}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    _check_keys(m, [jax.tree_util.keystr(p) for p, _ in flat], d)
    span = None if stage is None else (bounds[stage], bounds[stage + 1])
    layer_set = _layer_key_set(m)
    spans = [span if jax.tree_util.keystr(p) in layer_set else None
             for p, _ in flat]
    raw = _assemble_all(d, m, flat, spans, policy, heal_report)
    leaves = []
    for (path, proto), arr in zip(flat, raw):
        key = jax.tree_util.keystr(path)
        if span is not None and m["layout"] == LAYOUT_LEAF_MODULO \
                and _is_layer_leaf(key, arr, bounds[-1]):
            # legacy whole-leaf layout: the file holds all layers, so
            # crop after the (unavoidably full) read
            arr = jax.numpy.asarray(np.asarray(arr)[span[0]:span[1]],
                                    dtype=proto.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def reshard(directory: Union[str, Path], new_spec, tree_like: PyTree, *,
            step: Optional[int] = None,
            out_directory: Optional[Union[str, Path]] = None,
            replication: int = 0) -> Path:
    """Rewrite a checkpoint under a new placement's sharding.

    Restores (re-slicing across the old boundaries) and saves under
    ``new_spec``'s — the post-churn state migration, done once by the
    orchestrator instead of every future restore paying the re-slice.
    Round-tripping 3-stage → 2-stage → 3-stage is bit-identical.
    """
    d = _resolve_step(directory, step)
    st = int(d.name.split("_")[1])
    state = restore(directory, tree_like, st)
    return save_for_placement(out_directory or directory, st, state,
                              new_spec, replication=replication)


# --------------------------------------------------------------------------- #
# Pruning
# --------------------------------------------------------------------------- #

def prune(directory: Union[str, Path], keep: int = 2) -> None:
    """Shard-aware prune: keep the newest ``keep`` *complete* steps.

    Only steps complete across all manifest shards count toward
    ``keep`` — the newest complete step is never deleted.  Incomplete
    steps older than the newest complete one are dead partial writes and
    are removed; incomplete steps *newer* than it may be in-flight
    writers and are left alone.
    """
    base = Path(directory)
    steps = _all_steps(base)
    complete = [s for s in steps if _step_complete(_step_dir(base, s))]
    if not complete:
        return                        # nothing provably restorable: keep all
    keep_set = set(complete[-max(keep, 1):])
    newest_complete = complete[-1]
    for s in steps:
        if s in keep_set or (s not in complete and s > newest_complete):
            continue
        shutil.rmtree(_step_dir(base, s))
