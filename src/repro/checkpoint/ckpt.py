"""Pure-numpy elastic sharded checkpointing (no orbax dependency).

Flat key/value layout under ``<dir>/step_<N>/``: every leaf is one or
more ``.npy`` files plus per-writer json manifests.  Two shard layouts:

* ``leaf_modulo`` — the legacy layout: leaf ``i`` belongs to shard
  ``i % num_shards`` and is saved whole.  Placement-blind; kept for
  single-host trainers and as the compatibility path.
* ``layer_sliced`` — the elastic layout, driven by a
  :class:`~repro.checkpoint.spec.CheckpointSpec` derived from the
  :class:`~repro.core.placement.PlacementSpec` that is executing: each
  stage shard saves its contiguous layer-range slice of every
  scan-stacked decoder leaf (file ``<leaf>.L<a>-<b>.npy`` holds
  ``leaf[a:b]``), non-layer leaves are distributed round-robin, and
  ``replication`` makes each writer also persist its upstream
  neighbours' shards (§5 partial proactive replication).  Because slice
  files are named by *layer range*, not by writer, the layout is
  placement-agnostic on read: :func:`restore_for_placement` re-slices
  the stacked layer arrays across *different* stage boundaries, so a
  3-stage checkpoint restores bit-identically onto a 2-stage fleet
  (and back) after churn.

``restore`` validates completeness against the manifest before touching
any array and raises one :class:`IncompleteCheckpointError` naming every
missing leaf/shard file; ``prune`` is shard-aware: only steps complete
across all shards count toward ``keep``, and a newer still-incomplete
(in-flight) step is never deleted.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.checkpoint.spec import CheckpointSpec

PyTree = Any

LAYOUT_LEAF_MODULO = "leaf_modulo"
LAYOUT_LAYER_SLICED = "layer_sliced"


class IncompleteCheckpointError(FileNotFoundError):
    """A restore/validation found manifest-expected files missing."""


def _escape(path_str: str) -> str:
    return path_str.replace("/", "_").replace("'", "").replace("[", "(") \
        .replace("]", ")")


def _slice_name(key: str, a: int, b: int) -> str:
    return f"{_escape(key)}.L{a:05d}-{b:05d}.npy"


def _leaf_name(key: str) -> str:
    return _escape(key) + ".npy"


def _flat(tree: PyTree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def _save_array(path: Path, leaf) -> None:
    a = np.asarray(leaf)
    if a.dtype.kind == "V" and a.dtype.itemsize == 2:
        # ml_dtypes.bfloat16 has no numpy cast path: store the bit
        # pattern as uint16 (restore views it back via proto.dtype)
        a = a.view(np.uint16)
    np.save(path, a)


def _load_array(path: Path, proto_dtype) -> np.ndarray:
    arr = np.load(path)
    pd = jax.numpy.dtype(proto_dtype)
    if arr.dtype == np.uint16 and pd.itemsize == 2 and pd.kind == "V":
        arr = arr.view(pd)
    return arr


def _is_layer_leaf(key: str, leaf, num_layers: int) -> bool:
    """Scan-stacked decoder leaf: leading axis is the layer stack.

    Same contract as the pipeline executor (uniform dense decoder
    stacks): the leaf sits under ``decoder`` and its leading dim equals
    ``num_layers``.  Everything else (embeddings, lm head, norms,
    optimizer scalars) is placement-independent and saved whole.
    """
    shape = np.shape(leaf)
    return ("decoder" in key and len(shape) >= 1
            and shape[0] == num_layers and num_layers > 1)


# --------------------------------------------------------------------------- #
# Saving
# --------------------------------------------------------------------------- #

def _step_dir(directory: Union[str, Path], step: int) -> Path:
    return Path(directory) / f"step_{step:08d}"


def save(directory: Union[str, Path], step: int, tree: PyTree, *,
         num_shards: int = 1, shard_id: int = 0) -> Path:
    """Write (a leaf-modulo shard of) a checkpoint; returns the step dir."""
    d = _step_dir(directory, step)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flat(tree)
    manifest = {"step": step, "layout": LAYOUT_LEAF_MODULO,
                "num_leaves": len(flat), "num_shards": num_shards,
                "shard_id": shard_id,
                "keys": [jax.tree_util.keystr(p) for p, _ in flat]}
    for i, (path, leaf) in enumerate(flat):
        if i % num_shards != shard_id:
            continue
        _save_array(d / _leaf_name(jax.tree_util.keystr(path)), leaf)
    (d / f"manifest_{shard_id}.json").write_text(json.dumps(manifest))
    return d


def save_sharded(directory: Union[str, Path], step: int, tree: PyTree,
                 spec: CheckpointSpec, shard_id: int) -> Path:
    """Write stage-shard ``shard_id`` of a layer-sliced checkpoint.

    The writer persists its own layer-range slices plus (per
    ``spec.replication``) its upstream neighbours' — slice files are
    named by layer range, so neighbour copies land on the same paths and
    the union stays complete even if one writer never finishes.
    """
    if not 0 <= shard_id < spec.num_shards:
        raise ValueError(f"shard_id={shard_id} outside "
                         f"0..{spec.num_shards - 1}")
    d = _step_dir(directory, step)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flat(tree)
    keys = [jax.tree_util.keystr(p) for p, _ in flat]
    layer_keys = [k for k, (_, leaf) in zip(keys, flat)
                  if _is_layer_leaf(k, leaf, spec.num_layers)]
    layer_set = set(layer_keys)
    held = set(spec.held_shards(shard_id))
    slices = spec.slices()
    nonlayer_i = 0
    for key, (_, leaf) in zip(keys, flat):
        if key in layer_set:
            for s in held:
                a, b = slices[s]
                _save_array(d / _slice_name(key, a, b),
                            np.asarray(leaf)[a:b])
        else:
            if nonlayer_i % spec.num_shards in held:
                _save_array(d / _leaf_name(key), leaf)
            nonlayer_i += 1
    manifest = {"step": step, "layout": LAYOUT_LAYER_SLICED,
                "num_leaves": len(flat), "num_shards": spec.num_shards,
                "shard_id": shard_id, "keys": keys,
                "layer_keys": layer_keys,
                "num_layers": spec.num_layers,
                "boundaries": list(spec.boundaries),
                "replication": spec.replication,
                "holders": [list(h) for h in spec.holders]}
    (d / f"manifest_{shard_id}.json").write_text(json.dumps(manifest))
    return d


def _as_ckpt_spec(spec, replication: int = 0) -> CheckpointSpec:
    if isinstance(spec, CheckpointSpec):
        if replication and replication != spec.replication:
            # an explicit nonzero replication= wins over the spec's
            return CheckpointSpec(
                spec.num_layers, spec.boundaries,
                min(replication, spec.num_shards - 1), spec.holders)
        return spec
    if hasattr(spec, "pipelines"):               # PlacementSpec duck-type
        return CheckpointSpec.from_placement(spec, replication)
    raise TypeError(f"expected CheckpointSpec or PlacementSpec, got "
                    f"{type(spec).__name__}")


def save_for_placement(directory: Union[str, Path], step: int, tree: PyTree,
                       spec, *, replication: int = 0) -> Path:
    """Write every stage shard of a layer-sliced checkpoint.

    ``spec`` is a :class:`CheckpointSpec` or a ``PlacementSpec`` (each
    stage slot then saves exactly the layer range it executes).  This is
    the host-side simulation of all stage writers; a real fleet calls
    :func:`save_sharded` once per stage.
    """
    cspec = _as_ckpt_spec(spec, replication)
    d = _step_dir(directory, step)
    for s in range(cspec.num_shards):
        save_sharded(directory, step, tree, cspec, s)
    return d


# --------------------------------------------------------------------------- #
# Manifest reading + completeness validation
# --------------------------------------------------------------------------- #

def _read_manifest(d: Path) -> Dict[str, Any]:
    manifests = sorted(d.glob("manifest_*.json"))
    if not manifests:
        raise FileNotFoundError(f"no checkpoint manifest under {d}")
    m = json.loads(manifests[0].read_text())
    m.setdefault("layout", LAYOUT_LEAF_MODULO)
    m["_manifests_present"] = len(manifests)
    return m


def _missing_files(d: Path, m: Dict[str, Any]) -> List[str]:
    """Manifest-expected data files absent on disk, each named with the
    leaf and the shard responsible for writing it."""
    missing: List[str] = []
    S = int(m.get("num_shards", 1))
    if m["layout"] == LAYOUT_LEAF_MODULO:
        for i, key in enumerate(m["keys"]):
            f = d / _leaf_name(key)
            if not f.exists():
                missing.append(f"{f.name} (leaf {key}, shard {i % S})")
        return missing
    layer_set = set(m["layer_keys"])
    slices = list(zip(m["boundaries"][:-1], m["boundaries"][1:]))
    nonlayer_i = 0
    for key in m["keys"]:
        if key in layer_set:
            for s, (a, b) in enumerate(slices):
                f = d / _slice_name(key, a, b)
                if not f.exists():
                    missing.append(
                        f"{f.name} (leaf {key} layers {a}:{b}, shard {s})")
        else:
            f = d / _leaf_name(key)
            if not f.exists():
                missing.append(f"{f.name} (leaf {key}, shard "
                               f"{nonlayer_i % S})")
            nonlayer_i += 1
    return missing


def _validate(d: Path) -> Dict[str, Any]:
    m = _read_manifest(d)
    missing = _missing_files(d, m)
    if missing:
        shown = "\n  ".join(missing[:20])
        more = f"\n  ... and {len(missing) - 20} more" \
            if len(missing) > 20 else ""
        raise IncompleteCheckpointError(
            f"checkpoint {d} is incomplete ({len(missing)} of its "
            f"manifest's files missing):\n  {shown}{more}")
    return m


def _step_complete(d: Path) -> bool:
    try:
        _validate(d)
        return True
    except (FileNotFoundError, json.JSONDecodeError):
        return False


def _all_steps(directory: Union[str, Path]) -> List[int]:
    return sorted(int(p.name.split("_")[1])
                  for p in Path(directory).glob("step_*"))


def latest_step(directory: Union[str, Path]) -> Optional[int]:
    steps = _all_steps(directory)
    return steps[-1] if steps else None


def complete_steps(directory: Union[str, Path]) -> List[int]:
    """Steps whose manifest-expected files are all present."""
    base = Path(directory)
    return [s for s in _all_steps(directory)
            if _step_complete(_step_dir(base, s))]


def latest_complete_step(directory: Union[str, Path]) -> Optional[int]:
    steps = complete_steps(directory)
    return steps[-1] if steps else None


def _resolve_step(directory: Union[str, Path], step: Optional[int]) -> Path:
    base = Path(directory)
    if step is not None:
        return _step_dir(base, step)
    steps = _all_steps(base)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {base}")
    complete = [s for s in steps if _step_complete(_step_dir(base, s))]
    if complete:
        return _step_dir(base, complete[-1])
    # nothing complete: surface the newest step's precise gap
    return _step_dir(base, steps[-1])


# --------------------------------------------------------------------------- #
# Restoring
# --------------------------------------------------------------------------- #

def _check_keys(m: Dict[str, Any], keys: Sequence[str], d: Path) -> None:
    a, b = set(m["keys"]), set(keys)
    if a != b:
        extra = sorted(b - a)[:5]
        lacking = sorted(a - b)[:5]
        raise ValueError(
            f"tree structure does not match checkpoint {d}: "
            f"{len(b - a)} leaves absent from the checkpoint "
            f"(e.g. {extra}), {len(a - b)} checkpoint leaves unused "
            f"(e.g. {lacking})")


def _layer_key_set(m: Dict[str, Any]) -> set:
    if "_layer_key_set" not in m:                 # memoized per manifest
        m["_layer_key_set"] = set(m.get("layer_keys", []))
    return m["_layer_key_set"]


def _assemble_leaf(d: Path, m: Dict[str, Any], key: str, proto,
                   span: Optional[Tuple[int, int]] = None) -> np.ndarray:
    """Load one leaf; layer leaves re-slice across the manifest's
    boundaries, optionally cropped to ``span`` (a new stage's range)."""
    if m["layout"] == LAYOUT_LAYER_SLICED and key in _layer_key_set(m):
        lo, hi = span if span is not None else (0, m["num_layers"])
        parts = []
        for a, b in zip(m["boundaries"][:-1], m["boundaries"][1:]):
            s, e = max(a, lo), min(b, hi)
            if s >= e:
                continue
            arr = _load_array(d / _slice_name(key, a, b), proto.dtype)
            parts.append(arr[s - a:e - a])
        return np.concatenate(parts, axis=0)
    return _load_array(d / _leaf_name(key), proto.dtype)


def restore(directory: Union[str, Path], tree_like: PyTree,
            step: Optional[int] = None) -> PyTree:
    """Restore into the structure of ``tree_like`` (dtypes preserved).

    Works for both layouts; layer-sliced checkpoints are reassembled
    across whatever boundaries their manifest records, so the restoring
    placement need not match the writing one.  Completeness is validated
    up front: a partial checkpoint raises one
    :class:`IncompleteCheckpointError` naming every missing file.
    """
    d = _resolve_step(directory, step)
    m = _validate(d)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    _check_keys(m, [jax.tree_util.keystr(p) for p, _ in flat], d)
    leaves = [jax.numpy.asarray(
        _assemble_leaf(d, m, jax.tree_util.keystr(path), proto),
        dtype=proto.dtype) for path, proto in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_for_placement(directory: Union[str, Path], new_spec,
                          tree_like: PyTree, step: Optional[int] = None,
                          *, stage: Optional[int] = None) -> PyTree:
    """Restore a checkpoint onto a *different* placement.

    ``new_spec`` is the placement (or :class:`CheckpointSpec` /
    boundary list) that will execute next.  With ``stage=None`` the full
    tree is reassembled (identical to :func:`restore` — layer slices
    concatenate across the old boundaries regardless of the new ones).
    With ``stage=s`` only that stage's state is materialized: layer
    leaves come back cropped to the new stage's ``[start, stop)`` range,
    reading only the old slice files that overlap it — the
    bytes-actually-missing read set a joining device fetches.
    """
    if isinstance(new_spec, CheckpointSpec):
        bounds: List[int] = list(new_spec.boundaries)
    elif hasattr(new_spec, "boundaries"):         # PlacementSpec duck-type
        bounds = list(new_spec.boundaries)
    else:
        bounds = list(new_spec)
    d = _resolve_step(directory, step)
    m = _validate(d)
    if m["layout"] == LAYOUT_LAYER_SLICED and m["num_layers"] != bounds[-1]:
        raise ValueError(
            f"checkpoint has {m['num_layers']} layers but the new "
            f"placement expects {bounds[-1]}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    _check_keys(m, [jax.tree_util.keystr(p) for p, _ in flat], d)
    span = None if stage is None else (bounds[stage], bounds[stage + 1])
    layer_set = _layer_key_set(m)
    leaves = []
    for path, proto in flat:
        key = jax.tree_util.keystr(path)
        arr = _assemble_leaf(d, m, key, proto,
                             span if key in layer_set else None)
        if span is not None and m["layout"] == LAYOUT_LEAF_MODULO \
                and _is_layer_leaf(key, arr, bounds[-1]):
            # legacy whole-leaf layout: the file holds all layers, so
            # crop after the (unavoidably full) read
            arr = arr[span[0]:span[1]]
        leaves.append(jax.numpy.asarray(arr, dtype=proto.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def reshard(directory: Union[str, Path], new_spec, tree_like: PyTree, *,
            step: Optional[int] = None,
            out_directory: Optional[Union[str, Path]] = None,
            replication: int = 0) -> Path:
    """Rewrite a checkpoint under a new placement's sharding.

    Restores (re-slicing across the old boundaries) and saves under
    ``new_spec``'s — the post-churn state migration, done once by the
    orchestrator instead of every future restore paying the re-slice.
    Round-tripping 3-stage → 2-stage → 3-stage is bit-identical.
    """
    d = _resolve_step(directory, step)
    st = int(d.name.split("_")[1])
    state = restore(directory, tree_like, st)
    return save_for_placement(out_directory or directory, st, state,
                              new_spec, replication=replication)


# --------------------------------------------------------------------------- #
# Pruning
# --------------------------------------------------------------------------- #

def prune(directory: Union[str, Path], keep: int = 2) -> None:
    """Shard-aware prune: keep the newest ``keep`` *complete* steps.

    Only steps complete across all manifest shards count toward
    ``keep`` — the newest complete step is never deleted.  Incomplete
    steps older than the newest complete one are dead partial writes and
    are removed; incomplete steps *newer* than it may be in-flight
    writers and are left alone.
    """
    base = Path(directory)
    steps = _all_steps(base)
    complete = [s for s in steps if _step_complete(_step_dir(base, s))]
    if not complete:
        return                        # nothing provably restorable: keep all
    keep_set = set(complete[-max(keep, 1):])
    newest_complete = complete[-1]
    for s in steps:
        if s in keep_set or (s not in complete and s > newest_complete):
            continue
        shutil.rmtree(_step_dir(base, s))
