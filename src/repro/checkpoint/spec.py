"""CheckpointSpec: the placement-derived sharding contract for elastic state.

A checkpoint is sharded *by the placement that produced it*: stage slot
``s`` persists the contiguous layer range ``boundaries[s]:boundaries[s+1]``
of every scan-stacked decoder leaf (plus its share of the placement-
independent leaves — embeddings, lm head, optimizer scalars).  Because the
manifest records those boundaries, the checkpoint can later be re-sliced
onto *any other* placement: :func:`repro.checkpoint.ckpt.
restore_for_placement` reassembles layer ranges across different stage
boundaries, which is what lets training survive churn that changes the
stage count or the layer split (§5's "preemptible execution and fast
state recovery").

``replication`` models the paper's §5 partial proactive replication:
each stage's writer additionally persists its ``replication`` upstream
neighbours' shards, so losing one writer loses no state.  ``holders``
records which topology nodes physically hold each shard — the input to
:mod:`repro.checkpoint.elastic`'s bytes-actually-missing recovery
pricing.

The layer-span math is shared with the pipeline executor
(:func:`repro.distributed.pipeline.stage_slices`): the slice a stage
checkpoints is exactly the slice it executes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.distributed.pipeline import stage_counts, stage_slices


@dataclass(frozen=True)
class CheckpointSpec:
    """Layer-range sharding of a training-state checkpoint.

    ``boundaries`` is the placement's stage boundary list
    ``(0, ..., num_layers)``; shard ``s`` owns layers
    ``boundaries[s]:boundaries[s+1]``.  ``holders[s]`` (optional) lists
    the topology node ids holding a copy of shard ``s``.
    """
    num_layers: int
    boundaries: Tuple[int, ...]
    replication: int = 0
    holders: Tuple[Tuple[str, ...], ...] = ()

    def __post_init__(self):
        b = list(self.boundaries)
        if len(b) < 2 or b[0] != 0 or b[-1] != self.num_layers \
                or b != sorted(b) or len(set(b)) != len(b):
            raise ValueError(
                f"boundaries {b} must strictly ascend from 0 to "
                f"{self.num_layers}")
        if not 0 <= self.replication <= self.num_shards - 1:
            raise ValueError(
                f"replication={self.replication} needs 0 <= r <= "
                f"{self.num_shards - 1} neighbour copies")
        if self.holders and len(self.holders) != self.num_shards:
            raise ValueError(
                f"holders covers {len(self.holders)} shards, spec has "
                f"{self.num_shards}")

    # ------------------------------------------------------------- shape
    @property
    def num_shards(self) -> int:
        return len(self.boundaries) - 1

    def slices(self) -> List[Tuple[int, int]]:
        """Per-shard [start, stop) layer spans (pipeline boundary math)."""
        return stage_slices(self.boundaries)

    def layer_counts(self) -> List[int]:
        return stage_counts(self.boundaries)

    def held_shards(self, shard_id: int) -> List[int]:
        """Shards writer ``shard_id`` persists: its own plus its
        ``replication`` upstream neighbours' (§5 partial proactive
        replication — losing one writer loses no shard)."""
        S = self.num_shards
        return [(shard_id - k) % S for k in range(self.replication + 1)]

    # ------------------------------------------------------------ builders
    @classmethod
    def from_placement(cls, placement, replication: int = 0
                       ) -> "CheckpointSpec":
        """Derive the sharding from a
        :class:`repro.core.placement.PlacementSpec`: shard ``s`` is stage
        ``s``'s layer range, held by every replica's stage-``s`` node
        (DP replicas carry identical state) plus, with ``replication``,
        the next ``replication`` downstream stages' nodes."""
        S = placement.num_stages
        rep = min(max(replication, 0), S - 1)
        holders: List[Tuple[str, ...]] = []
        for s in range(S):
            hs: List[str] = []
            for k in range(rep + 1):
                j = (s + k) % S
                for pipe in placement.pipelines:
                    hs.append(pipe[j].node)
            holders.append(tuple(dict.fromkeys(hs)))
        return cls(placement.num_layers, tuple(placement.boundaries),
                   rep, tuple(holders))

    @classmethod
    def single(cls, num_layers: int) -> "CheckpointSpec":
        """Trivial one-shard spec (a single writer holds everything)."""
        return cls(num_layers, (0, num_layers))

    def with_holders(self, holders: Sequence[Sequence[str]]
                     ) -> "CheckpointSpec":
        return CheckpointSpec(self.num_layers, self.boundaries,
                              self.replication,
                              tuple(tuple(h) for h in holders))
