"""Version-compatibility layer for the jax ≥ 0.5 explicit-mesh APIs.

The sharding layer targets the modern explicit-mesh world —
``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``,
``jax.sharding.AxisType`` and the Shardy named-axis IR — but the pinned
environment may carry jax 0.4.x, where none of those exist.  This module
is the single place that knows the difference:

* :func:`set_mesh` — the explicit-mesh context on new jax; on 0.4.x it
  falls back to the legacy *physical mesh* context (``with mesh:``),
  under which ``with_sharding_constraint`` accepts bare
  ``PartitionSpec``\\ s exactly like the modern ambient mesh does.
* :func:`get_abstract_mesh` — the real abstract mesh on new jax; on
  0.4.x a read-only view of the ambient physical mesh whose axes report
  :data:`AxisType.Auto`, except axes currently bound as manual
  collective axes (inside ``shard_map``/``pmap``), which report
  ``Manual`` so constraint code no-ops there just like on new jax.
* :data:`AxisType` — the real enum, or a stand-in with the same members.
* :data:`SHARDY_IR` — whether lowered programs carry named-axis (Shardy)
  shardings (``{"data"}``) rather than GSPMD device lists
  (``{devices=[2,4,1]<=[8]}``); IR-inspecting tests branch on this.

Everything degrades, nothing raises: on an unknown future jax the
accessors prefer the public APIs and only reach for 0.4.x internals when
those are absent.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional, Tuple

import jax

# jax.set_mesh (and Shardy-by-default lowering) arrive in the same API
# generation; its presence is the era marker the fallbacks key off.
HAS_EXPLICIT_MESH = hasattr(jax, "set_mesh")
SHARDY_IR = HAS_EXPLICIT_MESH


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on jax 0.4.x."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for jit/wsc/shard_map.

    New jax: ``jax.set_mesh``.  0.4.x: the legacy physical-mesh context
    (``Mesh`` is itself a context manager there) — bare-``PartitionSpec``
    sharding constraints resolve against it the same way.
    """
    if HAS_EXPLICIT_MESH:
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh                       # legacy: `with mesh:` context


@dataclass(frozen=True)
class _AmbientMeshView:
    """Duck-typed stand-in for an AbstractMesh (axis_names/sizes/types)."""
    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    axis_types: Tuple[AxisType, ...]


def _manual_axis_names() -> set:
    """Axis names currently bound as collective axes (shard_map/pmap)."""
    try:
        from jax._src import core
        env = core.get_axis_env()
        return set(getattr(env, "axis_sizes", {}) or {})
    except Exception:
        return set()


def get_abstract_mesh() -> Optional[_AmbientMeshView]:
    """The ambient mesh as (names, sizes, per-axis types), or None.

    New jax: delegates to ``jax.sharding.get_abstract_mesh``.  0.4.x:
    views the thread-local physical mesh; axes bound inside shard_map
    report Manual (constraints must no-op), the rest Auto.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    try:
        from jax._src.mesh import thread_resources
        pm = thread_resources.env.physical_mesh
    except Exception:
        return None
    if pm is None or pm.empty:
        return None
    manual = _manual_axis_names()
    names = tuple(pm.axis_names)
    sizes = tuple(int(pm.shape[n]) for n in names)
    types = tuple(AxisType.Manual if n in manual else AxisType.Auto
                  for n in names)
    return _AmbientMeshView(names, sizes, types)
