"""Pallas TPU kernel: blockwise symmetric int8 quantize / dequantize.

Layout: input viewed as (rows, block) with block a multiple of 128 lanes;
grid tiles rows.  Each tile computes per-row |max| via a VREG lane
reduction, derives the fp32 scale, and emits int8 values — a pure VPU
elementwise kernel (no MXU), bandwidth-bound by design: it exists to cut
collective bytes 4× (bf16→int8) in gradient all-reduce/all-gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (rows, block)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (rows, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_blocks(x: jax.Array, *, block: int = 256,
                    interpret: bool = False):
    """x: (N,), N % block == 0 -> (int8 (N,), fp32 scales (N/block,))."""
    n = x.shape[0]
    rows = n // block
    tile = min(ROW_TILE, rows)
    assert rows % tile == 0, (rows, tile)
    xb = x.reshape(rows, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rows // tile,),
        in_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0)),
                   pl.BlockSpec((tile, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q.reshape(-1), s[:, 0]


@functools.partial(jax.jit, static_argnames=("block", "dtype", "interpret"))
def dequantize_blocks(q: jax.Array, scale: jax.Array, *, block: int = 256,
                      dtype=jnp.float32, interpret: bool = False):
    n = q.shape[0]
    rows = n // block
    tile = min(ROW_TILE, rows)
    assert rows % tile == 0, (rows, tile)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // tile,),
        in_specs=[pl.BlockSpec((tile, block), lambda i: (i, 0)),
                  pl.BlockSpec((tile, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), dtype),
        interpret=interpret,
    )(q.reshape(rows, block), scale[:, None])
    return out.reshape(-1)
