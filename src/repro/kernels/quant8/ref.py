"""Pure-jnp oracle for blockwise symmetric int8 quantization.

Gradient-compression primitive for the paper's §5 communication-minimization
challenge (ZeRO++/QSDP-style quantized collectives): values are quantized
per contiguous block of ``block`` elements with a shared fp32 scale.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_reference(x: jax.Array, block: int = 256
                       ) -> Tuple[jax.Array, jax.Array]:
    """x: (N,) with N % block == 0 -> (int8 values (N,), fp32 scales (N/block,))."""
    xb = x.astype(jnp.float32).reshape(-1, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_reference(q: jax.Array, scale: jax.Array, block: int = 256,
                         dtype=jnp.float32) -> jax.Array:
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scale[:, None]).reshape(-1).astype(dtype)
