"""Public wrappers: quantize/dequantize arbitrary-shape arrays (pads tail)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quant8.kernel import dequantize_blocks, quantize_blocks
from repro.kernels.quant8 import ref as qref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize(x: jax.Array, block: int = 256, *, interpret=None
             ) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Any-shape tensor -> (int8 flat, scales, original shape)."""
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.shape[0] // block
    if rows % min(256, rows):   # irregular row count: fall back to the oracle
        q, s = qref.quantize_reference(flat, block)
    else:
        q, s = quantize_blocks(flat, block=block, interpret=interpret)
    return q, s, shape


def dequantize(q: jax.Array, scale: jax.Array, shape: Tuple[int, ...],
               block: int = 256, dtype=jnp.float32, *, interpret=None
               ) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    rows = q.shape[0] // block
    if rows % min(256, rows):
        flat = qref.dequantize_reference(q, scale, block, dtype)
    else:
        flat = dequantize_blocks(q, scale, block=block, dtype=dtype,
                                 interpret=interpret)
    import numpy as np
    n = int(np.prod(shape))
    return flat[:n].reshape(shape)


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 for KV-cache vectors: quant block = the
    trailing ``head_dim`` axis, one fp32 scale per (..., kv_head) vector —
    the same amax/127 scheme as :func:`quantize` with ``block = D``, kept
    shape-preserving so it can run inside the serve step's scatter (the
    flat kernel wants padded (N,) layouts).

    x: (..., D) -> (int8 (..., D), fp32 scales (...,)).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_kv`: (..., D) int8 + (...,) scales."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
