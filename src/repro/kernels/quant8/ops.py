"""Public wrappers: quantize/dequantize arbitrary-shape arrays (pads tail)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quant8.kernel import dequantize_blocks, quantize_blocks
from repro.kernels.quant8 import ref as qref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize(x: jax.Array, block: int = 256, *, interpret=None
             ) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Any-shape tensor -> (int8 flat, scales, original shape)."""
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.shape[0] // block
    if rows % min(256, rows):   # irregular row count: fall back to the oracle
        q, s = qref.quantize_reference(flat, block)
    else:
        q, s = quantize_blocks(flat, block=block, interpret=interpret)
    return q, s, shape


def dequantize(q: jax.Array, scale: jax.Array, shape: Tuple[int, ...],
               block: int = 256, dtype=jnp.float32, *, interpret=None
               ) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    rows = q.shape[0] // block
    if rows % min(256, rows):
        flat = qref.dequantize_reference(q, scale, block, dtype)
    else:
        flat = dequantize_blocks(q, scale, block=block, dtype=dtype,
                                 interpret=interpret)
    import numpy as np
    n = int(np.prod(shape))
    return flat[:n].reshape(shape)
