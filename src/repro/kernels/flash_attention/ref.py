"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,T,K,D), H % K == 0.  fp32 softmax."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf = q.reshape(B, S, K, g, D).astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)
    k_pos = jnp.arange(T)
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    logits = logits + jnp.where(ok, 0.0, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, v.shape[-1]).astype(q.dtype)
