"""Pallas TPU flash-attention kernel (causal / sliding-window / GQA).

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks); the last grid dim is
sequential on TPU, so the online-softmax running stats (m, l) and the fp32
output accumulator live in VMEM scratch and are carried across kv blocks.
Q/K/V stream HBM→VMEM in (BLOCK_Q×D) / (BLOCK_K×D) tiles; BLOCK sizes are
multiples of 128 so the q·kᵀ and p·v contractions land on the MXU.  GQA is
expressed in the K/V index_map (head h reads kv head h // group) — no
broadcasted materialization of K/V.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int,
               block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block skipping: a kv block strictly above the causal diagonal
    # (first k position > last q position) or entirely left of the
    # sliding window (last k position <= first q position - window) is
    # fully masked — no MXU work, no stat updates.  The grid still visits
    # the block (TPU grids are dense) but the body is predicated out:
    # for long causal sequences this halves kernel compute, matching the
    # analytic 0.5 causal factor in core/flops.
    live = ki * block_k < seq_len                        # padding block
    if causal:
        live &= ki * block_k <= qi * block_q + block_q - 1
    if window > 0:
        live &= (ki + 1) * block_k - 1 > qi * block_q - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = k_pos < seq_len                            # padding mask
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         scale: Optional[float] = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jax.Array:
    """q: (B,H,S,D); k/v: (B,K,T,D).  Returns (B,H,S,Dv)."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    group = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k

    grid = (B, H, Sp // block_q, Tp // block_k)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=T)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
