"""Pallas TPU flash-attention kernels (causal / sliding-window / GQA).

Forward: grid = (batch, q_heads, q_blocks, kv_blocks); the last grid dim is
sequential on TPU, so the online-softmax running stats (m, l) and the fp32
output accumulator live in VMEM scratch and are carried across kv blocks.
Q/K/V stream HBM→VMEM in (BLOCK_Q×D) / (BLOCK_K×D) tiles; BLOCK sizes are
multiples of 128 so the q·kᵀ and p·v contractions land on the MXU.  GQA is
expressed in the K/V index_map (head h reads kv head h // group) — no
broadcasted materialization of K/V.

Backward (Dao et al. flash-attention-2): the forward saves only O(S)
residuals — the output and the logsumexp — and the backward recomputes the
score tile p = exp(q·kᵀ·scale − lse) per block.  Two kernels, each with the
reduction axis innermost so the accumulator lives in VMEM scratch:

* ``dq``  — grid (B, H, q_blocks, kv_blocks): dq[i] = Σ_j ds_ij · k_j
* ``dkdv``— grid (B, H, kv_blocks, q_blocks): dk_j = Σ_i ds_ijᵀ · q_i,
  dv_j = Σ_i p_ijᵀ · do_i, accumulated per q-head; the GQA group-sum
  (H → K heads) happens outside the kernel.

with ds = p ⊙ (do·vᵀ − Δ) · scale and Δ = rowsum(do ⊙ out) computed once
outside the kernels.  Both backward kernels reuse the forward's
block-skipping predication (blocks strictly above the causal diagonal,
fully left of the sliding window, or entirely in padding do no MXU work),
so causal backward FLOPs also get the analytic 0.5 factor.

``flash_attention_bhsd`` carries a ``jax.custom_vjp`` wiring these
together; ``interpret=True`` runs the exact same kernel logic on CPU
(CI / gradient-parity tests).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _block_live(qi, ki, *, causal: bool, window: int, block_q: int,
                block_k: int, q_len: int, kv_len: int):
    """Predicate: does (q block qi, kv block ki) contain any unmasked pair?

    A kv block strictly above the causal diagonal (first k position > last
    q position), entirely left of the sliding window (last k position <=
    first q position - window), or fully inside padding is dead — no MXU
    work, no accumulator updates.  The grid still visits the block (TPU
    grids are dense) but the body is predicated out: for long causal
    sequences this halves kernel compute, matching the analytic 0.5 causal
    factor in core/flops.
    """
    live = (ki * block_k < kv_len) & (qi * block_q < q_len)
    if causal:
        live &= ki * block_k <= qi * block_q + block_q - 1
    if window > 0:
        live &= (ki + 1) * block_k - 1 > qi * block_q - window
    return live


def _tile_mask(qi, ki, *, causal: bool, window: int, block_q: int,
               block_k: int, kv_len: int):
    """(block_q, block_k) bool mask for the (qi, ki) tile."""
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = k_pos < kv_len                                 # padding mask
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    return ok


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #

def _fa_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, causal: bool, window: int,
                   block_q: int, block_k: int, q_len: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = _block_live(qi, ki, causal=causal, window=window,
                       block_q=block_q, block_k=block_k,
                       q_len=q_len, kv_len=kv_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(qi, ki, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, kv_len=kv_len)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l))[:, 0]


def _fwd_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
              window: int, scale: float, block_q: int, block_k: int,
              interpret: bool) -> Tuple[jax.Array, jax.Array]:
    """Runs the forward kernel.  Returns (out (B,H,S,Dv), lse (B,H,S) f32)."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    group = H // K

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k

    grid = (B, H, Sp // block_q, Tp // block_k)
    kernel = functools.partial(
        _fa_fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_len=S, kv_len=T)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, Dv),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :], lse[:, :, :S]


# --------------------------------------------------------------------------- #
# Backward
# --------------------------------------------------------------------------- #

def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, dq_scr, *, scale: float, causal: bool,
                      window: int, block_q: int, block_k: int, q_len: int,
                      kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    live = _block_live(qi, ki, causal=causal, window=window,
                       block_q=block_q, block_k=block_k,
                       q_len=q_len, kv_len=kv_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)
        do = do_ref[0, 0].astype(jnp.float32)          # (bq, dv)
        lse = lse_ref[0, 0][:, None]                   # (bq, 1)
        dlt = delta_ref[0, 0][:, None]                 # (bq, 1)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(qi, ki, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, kv_len=kv_len)
        s = jnp.where(ok, s, NEG_INF)
        p = jnp.exp(s - lse)                           # (bq, bk)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dlt) * scale                    # (bq, bk)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _fa_bwd_dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                        dk_ref, dv_ref, dk_scr, dv_scr, *, scale: float,
                        causal: bool, window: int, block_q: int,
                        block_k: int, q_len: int, kv_len: int):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    live = _block_live(qi, ki, causal=causal, window=window,
                       block_q=block_q, block_k=block_k,
                       q_len=q_len, kv_len=kv_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)
        do = do_ref[0, 0].astype(jnp.float32)          # (bq, dv)
        lse = lse_ref[0, 0][:, None]                   # (bq, 1)
        dlt = delta_ref[0, 0][:, None]                 # (bq, 1)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(qi, ki, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, kv_len=kv_len)
        s = jnp.where(ok, s, NEG_INF)
        p = jnp.exp(s - lse)                           # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dlt) * scale                    # (bq, bk)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _done():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_bhsd(q, k, v, out, lse, do, causal: bool, window: int,
              scale: float, block_q: int, block_k: int, interpret: bool):
    """FA-2 backward from O(S) residuals.  Returns (dq, dk, dv) in the
    primal dtypes."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    group = H // K

    # Δ = rowsum(do ⊙ out) — the only residual not saved by the forward;
    # O(S·D) elementwise, cheaper than a dedicated preprocess kernel.
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                   # (B,H,S)

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        # do pads with zeros so padded q rows contribute nothing to dk/dv;
        # lse pads with 0 (NOT -inf: exp(s - lse) must stay finite there).
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        do = jnp.pad(do, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)))
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k
    nq, nk = Sp // block_q, Tp // block_k

    q_spec = pl.BlockSpec((1, 1, block_q, D),
                          lambda b, h, i, j: (b, h, i, 0))
    do_spec = pl.BlockSpec((1, 1, block_q, Dv),
                           lambda b, h, i, j: (b, h, i, 0))
    row_spec = pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i))
    k_spec = pl.BlockSpec((1, 1, block_k, D),
                          lambda b, h, i, j, g=group: (b, h // g, j, 0))
    v_spec = pl.BlockSpec((1, 1, block_k, Dv),
                          lambda b, h, i, j, g=group: (b, h // g, j, 0))

    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          q_len=S, kv_len=T),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, k_spec, v_spec, do_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dkdv: grid is (B, H, kv_blocks, q_blocks) — the q reduction runs
    # innermost so dk/dv accumulate in VMEM.  BlockSpec index maps receive
    # (b, h, ki, qi): kv-indexed operands use the 3rd grid dim, q-indexed
    # operands the 4th.
    qk_spec = pl.BlockSpec((1, 1, block_q, D),
                           lambda b, h, j, i: (b, h, i, 0))
    dok_spec = pl.BlockSpec((1, 1, block_q, Dv),
                            lambda b, h, j, i: (b, h, i, 0))
    rowk_spec = pl.BlockSpec((1, 1, block_q), lambda b, h, j, i: (b, h, i))
    kk_spec = pl.BlockSpec((1, 1, block_k, D),
                           lambda b, h, j, i, g=group: (b, h // g, j, 0))
    vk_spec = pl.BlockSpec((1, 1, block_k, Dv),
                           lambda b, h, j, i, g=group: (b, h // g, j, 0))

    dk_h, dv_h = pl.pallas_call(
        functools.partial(_fa_bwd_dkdv_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          q_len=S, kv_len=T),
        grid=(B, H, nk, nq),
        in_specs=[qk_spec, kk_spec, vk_spec, dok_spec, rowk_spec, rowk_spec],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Tp, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, Dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dq = dq[:, :, :S, :]
    # GQA group-sum: q head h wrote into row h; kv head h // group owns
    # heads [h*g, (h+1)*g) — contiguous, so a reshape-sum folds the group.
    dk = dk_h[:, :, :T, :].reshape(B, K, group, T, D).sum(axis=2)
    dv = dv_h[:, :, :T, :].reshape(B, K, group, T, Dv).sum(axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# --------------------------------------------------------------------------- #
# custom_vjp wiring + public entry point
# --------------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_core(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out, _ = _fwd_bhsd(q, k, v, causal, window, scale, block_q, block_k,
                       interpret)
    return out


def _flash_core_fwd(q, k, v, causal, window, scale, block_q, block_k,
                    interpret):
    out, lse = _fwd_bhsd(q, k, v, causal, window, scale, block_q, block_k,
                         interpret)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, window, scale, block_q, block_k, interpret,
                    res, do):
    q, k, v, out, lse = res
    return _bwd_bhsd(q, k, v, out, lse, do, causal, window, scale,
                     block_q, block_k, interpret)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         scale: Optional[float] = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jax.Array:
    """q: (B,H,S,D); k/v: (B,K,T,D).  Returns (B,H,S,Dv).  Differentiable
    (fused FA-2 Pallas backward via custom_vjp)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return _flash_core(q, k, v, causal, window, float(scale),
                       block_q, block_k, interpret)
