"""jit'd public wrapper: (B,S,H,D) layout, CPU falls back to interpret mode.

Differentiable end-to-end: ``flash_attention_bhsd`` carries a custom VJP
(fused FA-2 Pallas backward), so ``attn_impl="pallas"`` is a valid training
path, not just an inference path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention in (B,S,H,D) layout with GQA support.

    On non-TPU backends the Pallas kernel body runs in interpret mode —
    bit-exact kernel logic, Python execution (CI / CPU validation path).
    The layout swaps sit outside the custom VJP, so jax.grad through this
    wrapper hits the fused Pallas backward kernels.
    """
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
