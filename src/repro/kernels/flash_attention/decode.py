"""Pallas TPU flash-decode kernel over a paged KV cache.

Decode/chunked-prefill attention for serving: each query row attends to
its sequence's cached K/V, which live in fixed-size **pages** (see
``serve.paged_cache``) rather than one dense per-sequence buffer.

* **Block-table gather** — K/V pages are selected inside the BlockSpec
  index map from a scalar-prefetched ``(B, max_blocks)`` block table
  (``PrefetchScalarGridSpec``), so the kernel streams exactly the pages a
  sequence owns straight from the pool; no dense (B, T, K, D) gather is
  materialized in HBM.
* **Split-KV partial max/sum reduction** — the flash-decoding recipe: the
  page axis is split into ``num_splits`` ranges; each range reduces its
  pages online (fp32 running max/sum in VMEM scratch, exactly the FA-2
  forward update from ``kernel.py``) and emits partial ``(m, l, acc)``;
  a tiny jnp epilogue merges the partials with the standard logsumexp
  rescale.  On TPU the split axis gives the sequential grid short
  accumulation chains; in interpret mode it exercises the same math.
* **GQA + sliding-window block-skip** — the grid runs over KV heads; each
  program handles that head's ``group = H // K`` query rows.  Pages fully
  outside the valid range (beyond ``seq_len`` or entirely left of the
  sliding window) are predicated out with the same live-block discipline
  as ``kernel.py::_block_live`` — dead pages do no MXU work.
* **Chunked prefill** — q may carry ``C`` teacher-forced query rows per
  sequence (``(B, C, H, D)``); row ``c`` sits at cache position
  ``seq_lens - 1 + c`` and attends to ``seq_lens + c`` valid positions.
  The engine scatters all C rows' K/V before calling attention, so
  same-step causality is just the per-row length mask.  All rows of a
  sequence share the page stream — one grid, ``C * group`` query rows
  per program.
* **int8 KV** — with ``k_scale``/``v_scale`` pools of shape ``(P, bs,
  K)`` the pages hold int8 values quantized per (page slot, kv head)
  vector (``kernels/quant8`` blockwise scheme, quant block = head_dim);
  the kernel dequantizes in registers right after the page load, so HBM
  traffic stays at the int8 byte count.

``seq_lens`` counts **all** valid cache positions *including* the first
query row's token (the engine scatters the new K/V at position
``seq_len - 1`` before calling attention), so the first query position is
``seq_lens - 1`` and causality degenerates to the length mask.
``interpret=True`` runs the identical kernel logic on CPU (CI parity
tests vs ``chunked.py``).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.kernel import NEG_INF

DEFAULT_PAGES_PER_SPLIT = 8


def _page_live(page, block_size: int, seq_len, *, window: int,
               chunk: int = 1):
    """Does logical ``page`` hold any position some query row may attend
    to?

    Mirrors ``kernel.py::_block_live`` for the decode case: row ``c`` of
    the chunk attends to positions ``< seq_len + c``, so the page is dead
    when it starts past the *last* row's valid length, or — with a
    sliding window — when its last position is already left of the
    *first* row's window (later rows' windows only extend further
    right)."""
    live = page * block_size < seq_len + (chunk - 1)
    if window > 0:
        live &= (page + 1) * block_size - 1 > seq_len - 1 - window
    return live


# --------------------------------------------------------------------------- #
# Reference (gather) path — also the CPU/XLA execution path for the engine
# --------------------------------------------------------------------------- #

def paged_attention_reference(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_tables: jax.Array,
                              seq_lens: jax.Array, *, window: int = 0,
                              scale: Optional[float] = None,
                              k_scale: Optional[jax.Array] = None,
                              v_scale: Optional[jax.Array] = None
                              ) -> jax.Array:
    """Dense-gather oracle for the paged layout (fp32 softmax).

    q: (B, H, D) or (B, C, H, D) teacher-forced chunk rows; k/v_pages:
    (P, bs, K, D*); block_tables: (B, NB) int32; seq_lens: (B,) int32
    valid positions incl. the first query row's token (row ``c`` of a
    chunk attends to ``seq_lens + c`` positions).  ``k_scale``/``v_scale``
    ((P, bs, K) fp32) dequantize int8 pages.  Returns q's shape with D ->
    Dv.  Rows with seq_len == 0 return garbage (masked upstream) — padded
    engine slots are never read.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, C, H, D = q.shape
    P, bs, K, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    g = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    T = block_tables.shape[1] * bs
    k = k_pages[block_tables].reshape(B, T, K, D).astype(jnp.float32)
    v = v_pages[block_tables].reshape(B, T, K, Dv).astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[block_tables].reshape(B, T, K)[..., None]
    if v_scale is not None:
        v = v * v_scale[block_tables].reshape(B, T, K)[..., None]
    qf = q.reshape(B, C, K, g, D).astype(jnp.float32)
    s = jnp.einsum("bckgd,btkd->bckgt", qf, k) * scale
    t = jnp.arange(T)[None, None, :]
    valid = seq_lens[:, None, None] + jnp.arange(C)[None, :, None]
    ok = t < valid                                       # (B, C, T)
    if window > 0:
        ok &= t > (valid - 1) - window
    s = jnp.where(ok[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bckgt,btkd->bckgd", p, v)
    out = out.reshape(B, C, H, Dv).astype(q.dtype)
    return out[:, 0] if squeeze else out


# --------------------------------------------------------------------------- #
# Pallas kernel
# --------------------------------------------------------------------------- #

def _flash_decode_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, *rest,
                         scale: float, window: int, block_size: int,
                         pages_per_split: int, chunk: int, group: int,
                         quantized: bool):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    m_ref, l_ref, acc_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    si = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = sl_ref[b]
    page = si * pages_per_split + j
    live = _page_live(page, block_size, seq_len, window=window, chunk=chunk)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (C*g, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # (bs, Dv)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        t = page * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        # per-row valid length: row r belongs to chunk index r // group
        valid = seq_len + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0) // group
        ok = t < valid
        if window > 0:
            ok &= t > valid - 1 - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                              # (C*g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pages_per_split - 1)
    def _done():
        m_ref[0, 0, 0] = m_scr[...][:, 0]
        l_ref[0, 0, 0] = l_scr[...][:, 0]
        acc_ref[0, 0, 0] = acc_scr[...]


def _decode_bkgd(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                 block_tables: jax.Array, seq_lens: jax.Array, window: int,
                 scale: float, pages_per_split: int, interpret: bool,
                 chunk: int, group: int,
                 k_scale: Optional[jax.Array], v_scale: Optional[jax.Array]
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Runs the split-KV kernel.  q: (B, K, C*g, D) with rows ordered
    chunk-major.  Returns the per-split partials (m, l, acc) of shapes
    (B,K,S,CG) / (B,K,S,CG) / (B,K,S,CG,Dv)."""
    B, K, CG, D = q.shape
    bs = k_pages.shape[1]
    Dv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    pps = min(pages_per_split, nb)
    num_splits = -(-nb // pps)
    quantized = k_scale is not None

    def page_of(si, j, bt, b):
        # clamp overhang pages of the last split onto a valid table entry;
        # they are predicated dead in the kernel (page*bs >= seq_len)
        return bt[b, jnp.minimum(si * pps + j, nb - 1)]

    grid = (B, K, num_splits, pps)
    kernel = functools.partial(
        _flash_decode_kernel, scale=scale, window=window, block_size=bs,
        pages_per_split=pps, chunk=chunk, group=group, quantized=quantized)

    in_specs = [
        pl.BlockSpec((1, 1, CG, D),
                     lambda b, h, si, j, bt, sl: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda b, h, si, j, bt, sl:
                     (page_of(si, j, bt, b), 0, h, 0)),
        pl.BlockSpec((1, bs, 1, Dv),
                     lambda b, h, si, j, bt, sl:
                     (page_of(si, j, bt, b), 0, h, 0)),
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, si, j, bt, sl:
                         (page_of(si, j, bt, b), 0, h)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, si, j, bt, sl:
                         (page_of(si, j, bt, b), 0, h)),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, CG),
                         lambda b, h, si, j, bt, sl: (b, h, si, 0)),
            pl.BlockSpec((1, 1, 1, CG),
                         lambda b, h, si, j, bt, sl: (b, h, si, 0)),
            pl.BlockSpec((1, 1, 1, CG, Dv),
                         lambda b, h, si, j, bt, sl: (b, h, si, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((CG, 1), jnp.float32),
            pltpu.VMEM((CG, 1), jnp.float32),
            pltpu.VMEM((CG, Dv), jnp.float32),
        ],
    )
    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, K, num_splits, CG), jnp.float32),
            jax.ShapeDtypeStruct((B, K, num_splits, CG), jnp.float32),
            jax.ShapeDtypeStruct((B, K, num_splits, CG, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables, seq_lens, *operands)
    return m, l, acc


@functools.partial(
    jax.jit,
    static_argnames=("window", "scale", "pages_per_split", "interpret"))
def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       block_tables: jax.Array, seq_lens: jax.Array, *,
                       window: int = 0, scale: Optional[float] = None,
                       pages_per_split: int = DEFAULT_PAGES_PER_SPLIT,
                       interpret: Optional[bool] = None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Flash-decoding over paged KV.  q: (B, H, D), or (B, C, H, D) for a
    teacher-forced prefill chunk; pages: (P, bs, K, D*); block_tables:
    (B, NB) int32 page ids; seq_lens: (B,) int32 valid positions including
    the first query row's token (row ``c`` attends to ``seq_lens + c``).
    ``k_scale``/``v_scale`` ((P, bs, K) fp32) dequantize int8 pages in
    registers.  Returns q's shape with D -> Dv."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, C, H, D = q.shape
    K = k_pages.shape[2]
    Dv = v_pages.shape[-1]
    g = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    # chunk-major rows: row c*g + gi is chunk index c of group lane gi,
    # matching the kernel's `row // group` valid-length recovery
    qg = q.reshape(B, C, K, g, D).transpose(0, 2, 1, 3, 4).reshape(
        B, K, C * g, D)
    m, l, acc = _decode_bkgd(qg, k_pages, v_pages,
                             block_tables.astype(jnp.int32),
                             seq_lens.astype(jnp.int32),
                             window, float(scale), pages_per_split,
                             interpret, C, g, k_scale, v_scale)
    # merge the split partials: standard flash-decoding logsumexp rescale.
    # all-dead splits emit (m=-inf, l=0, acc=0) and vanish here.
    g_m = jnp.max(m, axis=2)                                    # (B,K,CG)
    alpha = jnp.exp(m - g_m[:, :, None, :])                     # (B,K,S,CG)
    l_tot = jnp.sum(l * alpha, axis=2)                          # (B,K,CG)
    acc_tot = jnp.sum(acc * alpha[..., None], axis=2)           # (B,K,CG,Dv)
    out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    out = out.reshape(B, K, C, g, Dv).transpose(0, 2, 1, 3, 4).reshape(
        B, C, H, Dv).astype(q.dtype)
    return out[:, 0] if squeeze else out
