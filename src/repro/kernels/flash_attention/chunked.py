"""XLA-level flash attention: chunked online softmax with a custom VJP.

This is the *compile-analyzable* twin of the Pallas kernel: identical
algorithm (stream KV in chunks, fp32 running max/sum, O(S) residuals:
out + logsumexp), expressed in pure jnp so that (a) the multi-pod dry-run
HLO reflects flash memory behaviour on every backend and (b) CPU tests run
fast.  The backward pass recomputes per-chunk scores from (q,k,v,out,lse)
— the Dao et al. flash-attention-2 recipe.

The Pallas kernel (kernel.py) is the TPU execution path; this module is the
default for training/dry-run lowering and is validated against ref.py in
the same sweeps.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
DEFAULT_CHUNK = 512


def _mask(q_pos, k_pos, causal: bool, window: int, seq_len: int):
    ok = k_pos[None, :] < seq_len
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return ok


def _constrain5(x):
    """(B, K, g, S, *) — model-axis priority: kv-heads, then head-groups,
    then query rows.  ``constrain`` pins the FIRST dim the model extent
    divides and leaves the rest unconstrained, so every architecture gets
    its attention compute sharded 1/TP-way:

    * K % TP == 0 (MHA, wide GQA)      -> head-parallel, kv sharded
    * g % TP == 0 (llama3: 8kv x 16g)  -> group-parallel, kv replicated
    * S % TP == 0 (anything else)      -> q-row-parallel, kv replicated

    Without this GSPMD replicates heads across the model axis (measured:
    16x redundant attention flops on granite train_4k — see EXPERIMENTS.md
    §Perf #1)."""
    from repro.distributed.act_sharding import BATCH, MODEL, constrain
    return constrain(x, BATCH, MODEL, MODEL, MODEL, None)


def _constrain4(x):
    from repro.distributed.act_sharding import BATCH, MODEL, constrain
    return constrain(x, BATCH, MODEL, MODEL, MODEL)


def _constrain_kv(x):
    """(B, K, T, D) stacked-chunk kv: shard kv-heads over model when they
    divide; otherwise kv stays replicated over model (each q shard reads
    the full kv), which is the correct GQA/TP>K layout."""
    from repro.distributed.act_sharding import BATCH, MODEL, constrain
    return constrain(x, BATCH, MODEL, None, None)


def _fwd(q, k, v, causal, window, scale, chunk, true_len):
    """q: (B,K,g,S,D); k/v: (B,K,T,D) — input dtype (bf16 in production),
    f32 running stats/accumulator (flash-attention-2 mixed precision).
    Returns out (f32), (m, l)."""
    B, K, g, S, D = q.shape
    T = k.shape[2]
    nc = T // chunk
    q_pos = jnp.arange(S)

    q = _constrain5(q)
    k = _constrain_kv(k)
    v = _constrain_kv(v)
    kc = k.reshape(B, K, nc, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, K, nc, chunk, v.shape[-1]).transpose(2, 0, 1, 3, 4)

    def body(carry, inp):
        m, l, acc = carry
        ci, kci, vci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bkgsd,bktd->bkgst", q, kci,
                       preferred_element_type=jnp.float32) * scale
        s = _constrain5(s)
        ok = _mask(q_pos, k_pos, causal, window, true_len)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,bktd->bkgsd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32)
        return (_constrain4(m_new), _constrain4(l), _constrain5(acc)), None

    m0 = _constrain4(jnp.full((B, K, g, S), NEG_INF, jnp.float32))
    l0 = _constrain4(jnp.zeros((B, K, g, S), jnp.float32))
    a0 = _constrain5(jnp.zeros((B, K, g, S, v.shape[-1]), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nc), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_core(q, k, v, causal, window, scale, chunk, true_len):
    out, _ = _fwd(q, k, v, causal, window, scale, chunk, true_len)
    return out


def _core_fwd(q, k, v, causal, window, scale, chunk, true_len):
    out, lse = _fwd(q, k, v, causal, window, scale, chunk, true_len)
    return out, (q, k, v, out, lse)


def _core_bwd(causal, window, scale, chunk, true_len, res, dout):
    q, k, v, out, lse = res
    B, K, g, S, D = q.shape
    T = k.shape[2]
    nc = T // chunk
    q_pos = jnp.arange(S)
    delta = jnp.sum(dout * out, axis=-1)                   # (B,K,g,S)

    k = _constrain_kv(k)
    v = _constrain_kv(v)
    kc = k.reshape(B, K, nc, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, K, nc, chunk, v.shape[-1]).transpose(2, 0, 1, 3, 4)

    def body(dq, inp):
        ci, kci, vci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bkgsd,bktd->bkgst", q, kci,
                       preferred_element_type=jnp.float32) * scale
        s = _constrain5(s)
        ok = _mask(q_pos, k_pos, causal, window, true_len)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                    # (B,K,g,S,t) f32
        pc = p.astype(q.dtype)
        dv_c = jnp.einsum("bkgst,bkgsd->bktd", pc, dout,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bkgsd,bktd->bkgst", dout, vci,
                        preferred_element_type=jnp.float32)
        dp = _constrain5(dp)
        ds = (p * (dp - delta[..., None]) * scale).astype(q.dtype)
        dq = dq + jnp.einsum("bkgst,bktd->bkgsd", ds, kci,
                             preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bkgst,bkgsd->bktd", ds, q,
                          preferred_element_type=jnp.float32)
        return _constrain5(dq), (dk_c, dv_c)

    dq0 = _constrain5(jnp.zeros(q.shape, jnp.float32))
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (jnp.arange(nc), kc, vc))
    dk = dk_c.transpose(1, 2, 0, 3, 4).reshape(B, K, T, D)
    dv = dv_c.transpose(1, 2, 0, 3, 4).reshape(B, K, T, v.shape[-1])
    # cotangents must match primal dtypes (custom_vjp contract): the f32
    # accumulators cast back to the (bf16) input dtype here
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_chunked_core.defvjp(_core_fwd, _core_bwd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      scale: Optional[float] = None,
                      chunk: int = DEFAULT_CHUNK) -> jax.Array:
    """Flash attention, (B,S,H,D) layout, GQA via K/V head groups."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    # GQA/TP layout: if the merged head count divides the model axis but
    # neither K nor g does (e.g. granite 32 = 8kv x 4g on TP16), expand kv
    # to H heads BEFORE the (K, g) split so the K dim carries the model
    # axis.  Per-device kv SHRINKS (H/TP < K heads held), attention stays
    # head-parallel end-to-end, and no head<->seq resharding is inserted
    # (measured: granite train_4k all-gather 1.6 TiB/dev -> see §Perf #2).
    from repro.distributed.act_sharding import axis_extent
    tp = axis_extent("model")
    if tp > 1 and g > 1 and H % tp == 0 and K % tp and g % tp:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        K, g = H, 1

    c = min(chunk, T)
    pad = (-T) % c
    kk, vv = k, v
    if pad:
        kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # keep q/k/v in their input dtype (bf16 in production): the matmuls
    # accumulate in f32 via preferred_element_type and the running stats
    # are f32 — FA2 mixed precision; upcasting inputs here doubled the
    # attention HBM traffic for no accuracy gain (§Perf A3)
    qf = q.reshape(B, S, K, g, D).transpose(0, 2, 3, 1, 4)
    kf = kk.transpose(0, 2, 1, 3)
    vf = vv.transpose(0, 2, 1, 3)
    out = _chunked_core(qf, kf, vf, causal, window, float(scale), c, T)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, v.shape[-1])
    return out.astype(q.dtype)
