"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid = (batch, heads, chunks); the chunk dimension is sequential on TPU, so
the inter-chunk SSM state (head_dim × d_state, fp32) lives in VMEM scratch
and is carried across chunk iterations — the HBM-resident inputs stream in
one (chunk × head_dim) / (chunk × d_state) tile at a time.

Per chunk (Q = chunk length, all fp32 in VREGs/MXU):
  dA   = dt · A                       (Q,)       log-decay
  L    = exp(segsum(dA)) ∘ causal     (Q, Q)
  y    = ((C Bᵀ) ∘ L) (x·dt)          intra-chunk   — two MXU matmuls
       + (C ∘ exp(cumsum dA)) Sᵀ      inter-chunk   — one MXU matmul
  S'   = exp(ΣdA) · S + ((B ∘ decay)ᵀ (x·dt))ᵀ      — state update

Grouped B/C (n_groups < heads) is expressed in the BlockSpec index_map
(head h reads group h // rep), mirroring the GQA trick in flash attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, p)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q, 1)
    A = a_ref[0].astype(jnp.float32)               # scalar decay rate
    B = b_ref[0, 0].astype(jnp.float32)            # (Q, n)
    C = c_ref[0, 0].astype(jnp.float32)            # (Q, n)

    xd = x * dt                                    # discretized input
    dA = dt[:, 0] * A                              # (Q,)
    cs = jnp.cumsum(dA)                            # inclusive cumsum

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = cs[:, None] - cs[None, :]
    L = jnp.where(li >= lj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)   # (Q,Q)
    y = jax.lax.dot(scores * L, xd, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    decay_out = jnp.exp(cs)[:, None]               # (Q,1)
    state = state_scr[...]                         # (p, n)
    y = y + jax.lax.dot_general(C * decay_out, state,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S' = exp(sum dA) S + (B ∘ decay_to_end)ᵀ-weighted input
    total = cs[-1]
    decay_states = jnp.exp(total - cs)[:, None]    # (Q,1)
    state_new = state * jnp.exp(total) + jax.lax.dot_general(
        xd, B * decay_states, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (p, n)
    state_scr[...] = state_new

    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_bhcq(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int, interpret: bool = False
             ) -> jax.Array:
    """x: (b,h,s,p); dt: (b,h,s,1); A: (h,); B/C: (b,g,s,n).  s % chunk == 0."""
    b, h, s, p = x.shape
    g, n = B.shape[1], B.shape[3]
    rep = h // g
    nc = s // chunk
    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
