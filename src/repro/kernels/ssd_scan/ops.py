"""jit'd public wrapper for the SSD kernel: model layout (b,s,h,p)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_bhcq


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
        chunk_size: int, *, interpret: Optional[bool] = None) -> jax.Array:
    """SSD scan, model layout.  x: (b,s,h,p); dt: (b,s,h); B/C: (b,s,g,n)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, p = x.shape
    pad = (-s) % chunk_size
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xt = jnp.transpose(x, (0, 2, 1, 3))                      # (b,h,s,p)
    dtt = jnp.transpose(dt, (0, 2, 1))[..., None]            # (b,h,s,1)
    Bt = jnp.transpose(B, (0, 2, 1, 3))                      # (b,g,s,n)
    Ct = jnp.transpose(C, (0, 2, 1, 3))
    y = ssd_bhcq(xt, dtt, A, Bt, Ct, chunk=chunk_size, interpret=interpret)
    y = jnp.transpose(y, (0, 2, 1, 3))
    return y[:, :s] if pad else y
