"""Pure-jnp oracle for the Mamba2 SSD (state-space dual) scan.

Implements the chunked block-decomposition from the Mamba2 paper
(arXiv:2405.21060, Listing 1 "ssd_minimal_discrete"), generalized to
grouped B/C (n_groups <= n_heads).  This is the single source of truth:
the model's default (non-Pallas) path and the Pallas kernel tests both
call into it.

Shapes
------
x  : (b, s, h, p)   per-head input
dt : (b, s, h)      positive step sizes (softplus already applied)
A  : (h,)           negative per-head decay rates (A = -exp(A_log))
B  : (b, s, g, n)   input projection  (g groups, h % g == 0)
C  : (b, s, g, n)   output projection
-> y : (b, s, h, p)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def segsum(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) with out[i, j] = sum_{k=j+1..i} x[k] (i >= j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_reference(x: jax.Array, dt: jax.Array, A: jax.Array,
                  B: jax.Array, C: jax.Array, chunk_size: int,
                  initial_state: jax.Array | None = None,
                  return_final_state: bool = False):
    """Chunked SSD in fp32.  See module docstring for shapes."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    orig_s = s
    if s % chunk_size:
        # pad with dt=0 tokens: decay exp(0)=1, zero input — state-neutral
        pad = chunk_size - s % chunk_size
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    rep = h // g
    c = s // chunk_size
    Q = chunk_size

    f32 = jnp.float32
    xd = (x.astype(f32) * dt[..., None].astype(f32))           # discretized input
    dA = dt.astype(f32) * A.astype(f32)                        # (b,s,h) log decay
    Bh = jnp.repeat(B.astype(f32), rep, axis=2)                # (b,s,h,n)
    Ch = jnp.repeat(C.astype(f32), rep, axis=2)

    # chunk: (b, c, Q, ...)
    xd = xd.reshape(b, c, Q, h, p)
    dA = dA.reshape(b, c, Q, h).transpose(0, 3, 1, 2)          # (b,h,c,Q)
    Bh = Bh.reshape(b, c, Q, h, n)
    Ch = Ch.reshape(b, c, Q, h, n)

    dA_cs = jnp.cumsum(dA, axis=-1)                            # (b,h,c,Q)

    # 1. intra-chunk (block-diagonal)
    L = jnp.exp(segsum(dA))                                    # (b,h,c,Q,Q)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Ch, Bh, L, xd)

    # 2. per-chunk end states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)            # (b,h,c,Q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xd)

    # 3. inter-chunk recurrence
    if initial_state is None:
        init = jnp.zeros((b, h, p, n), f32)
    else:
        init = initial_state.astype(f32)
    chunk_decay = jnp.exp(dA_cs[..., -1])                      # (b,h,c)

    def step(carry, inp):
        st, dec = inp                                          # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit state *entering* chunk

    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (b,c,h,p,n)

    # 4. off-diagonal (cross-chunk) contribution
    state_decay_out = jnp.exp(dA_cs)                           # (b,h,c,Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, prev_states,
                       state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)[:, :orig_s].astype(x.dtype)
    if return_final_state:
        return y, final
    return y


def ssd_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array, A: jax.Array,
             B_t: jax.Array, C_t: jax.Array):
    """Single decode step.

    state: (b,h,p,n); x_t: (b,h,p); dt_t: (b,h); B_t/C_t: (b,g,n).
    Returns (y_t (b,h,p), new_state).
    """
    b, h, p, n = state.shape
    g = B_t.shape[1]
    rep = h // g
    f32 = jnp.float32
    Bh = jnp.repeat(B_t.astype(f32), rep, axis=1)              # (b,h,n)
    Ch = jnp.repeat(C_t.astype(f32), rep, axis=1)
    dA = jnp.exp(dt_t.astype(f32) * A.astype(f32))             # (b,h)
    xd = x_t.astype(f32) * dt_t[..., None].astype(f32)
    new_state = state.astype(f32) * dA[..., None, None] \
        + jnp.einsum("bhp,bhn->bhpn", xd, Bh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y.astype(x_t.dtype), new_state
