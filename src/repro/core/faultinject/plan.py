"""Deterministic fault injection for the edge fleet.

The common case on "sparingly used connected edge AI devices" is
failure: stragglers on slow radio links, devices vanishing mid-round,
flaky WAN hops, partially written or bit-rotted checkpoint shards.  A
:class:`FaultPlan` is a *seeded description* of those faults that every
consumer — the local-SGD trainer, the orchestration simulator, the
serving engine, the checkpoint heal path — draws from **statelessly**:
each draw is keyed by ``(seed, kind, entity, t)``, so the same plan
replays bit-identically no matter how many consumers share it, in what
order they ask, or whether one of them is switched off between runs.
(A shared mutable RNG would make adding one fault perturb every draw
after it; keyed streams are what make fault experiments reproducible.)

Fault kinds:

* **stragglers** — a fixed fraction of entities run every step
  ``straggler_slowdown`` times slower (persistent per entity: a phone on
  a congested uplink stays slow);
* **crash / rejoin** — an entity vanishes for ``rejoin_delay`` rounds
  and comes back (its local state is gone; consumers re-sync it);
* **link flaps** — a sync/step sees ``link_jitter_s`` extra seconds of
  wide-area latency (radio fade, WAN reroute);
* **shard corruption** — a checkpoint shard copy written at step ``t``
  by holder ``entity`` is bit-rotted (consumers must detect it by
  checksum and re-fetch from another holder).

Every injected fault lands on the :mod:`repro.obs` timeline through a
:class:`FaultInjector` as a ``fault.<kind>`` instant on the ``faults``
track (cat ``fault``, args always carrying ``entity``) plus a
``faults/<kind>`` counter — the schema ``repro.obs.validate`` checks.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.faultinject.keyed import keyed_streams

FAULT_KINDS = ("straggle", "crash", "rejoin", "link_flap", "corrupt",
               "drop_stale", "resync", "deadline", "requeue_limit",
               "heal")


def _key_int(x) -> int:
    if isinstance(x, (bool, int, np.integer)):
        return int(x) & 0xFFFFFFFF
    return zlib.crc32(str(x).encode())


def _key_col(xs) -> np.ndarray:
    """Vector of ``_key_int`` words for a batch of entities/steps."""
    arr = np.asarray(xs)
    if arr.dtype.kind in "iub":
        return (arr.astype(np.int64) & 0xFFFFFFFF).astype(np.uint32)
    if arr.ndim == 0:
        return np.uint32(_key_int(xs))
    # keep original element types: np.asarray would stringify the ints
    # of a mixed [5, "node:a"] batch and break scalar parity
    items = xs if not isinstance(xs, np.ndarray) else arr.tolist()
    return np.array([_key_int(x) for x in items], dtype=np.uint32)


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, replayable fault schedule.  All draws are stateless."""
    seed: int = 0
    # stragglers: `straggler_frac` of entities are `straggler_slowdown`
    # (uniform in [lo, hi]) times slower, persistently
    straggler_frac: float = 0.0
    straggler_slowdown: Tuple[float, float] = (4.0, 8.0)
    # crash/rejoin churn: per entity per round/step
    crash_prob: float = 0.0
    rejoin_delay: Tuple[int, int] = (1, 3)        # rounds/steps offline
    # link flaps: per entity per sync/step, adding jitter seconds
    link_flap_prob: float = 0.0
    link_jitter_s: Tuple[float, float] = (0.5, 2.0)
    # checkpoint-shard corruption: per (step, shard, holder) write
    corrupt_prob: float = 0.0

    # ------------------------------------------------------------- draws
    def _rng(self, kind: str, *keys) -> np.random.Generator:
        ints = [int(self.seed) & 0xFFFFFFFF, zlib.crc32(kind.encode())]
        ints.extend(_key_int(k) for k in keys)
        return np.random.default_rng(ints)

    def slowdown(self, entity) -> float:
        """Persistent compute slowdown factor for ``entity`` (>= 1)."""
        r = self._rng("straggle", entity)
        if r.random() >= self.straggler_frac:
            return 1.0
        lo, hi = self.straggler_slowdown
        return float(lo + (hi - lo) * r.random())

    def is_straggler(self, entity) -> bool:
        return self.slowdown(entity) > 1.0

    def crashes(self, entity, t: int) -> bool:
        """Does ``entity`` crash at round/step ``t``?"""
        if self.crash_prob <= 0.0:
            return False
        return bool(self._rng("crash", entity, t).random()
                    < self.crash_prob)

    def rejoin_after(self, entity, t: int) -> int:
        """Rounds/steps ``entity`` stays offline after crashing at ``t``."""
        lo, hi = self.rejoin_delay
        return int(self._rng("rejoin", entity, t).integers(lo, hi + 1))

    def flaps(self, entity, t: int) -> bool:
        """Does ``entity``'s link flap on sync/step ``t``?"""
        if self.link_flap_prob <= 0.0:
            return False
        return bool(self._rng("flap", entity, t).random()
                    < self.link_flap_prob)

    def jitter_s(self, entity, t: int) -> float:
        """Extra link seconds on sync/step ``t`` (0 unless flapped)."""
        if not self.flaps(entity, t):
            return 0.0
        lo, hi = self.link_jitter_s
        return float(lo + (hi - lo)
                     * self._rng("jitter", entity, t).random())

    def corrupts(self, step: int, shard: int, holder="") -> bool:
        """Is holder ``holder``'s copy of ``shard`` written at ``step``
        bit-rotted?"""
        if self.corrupt_prob <= 0.0:
            return False
        return bool(self._rng("corrupt", step, shard, holder).random()
                    < self.corrupt_prob)

    # -------------------------------------------------- batched draws
    # One vectorized keyed-stream call per fault kind over a whole fleet,
    # bit-identical lane-for-lane to the scalar draws above (gated by
    # tests/test_fleet_scale.py and benchmarks/bench_fleet_scale.py).
    # This is what lets the 10^4-10^6-device churn sweeps draw a step's
    # masks in milliseconds instead of constructing one Generator per
    # entity per step.
    def _streams(self, kind: str, *cols):
        base = [np.uint32(int(self.seed) & 0xFFFFFFFF),
                np.uint32(zlib.crc32(kind.encode()))]
        return keyed_streams(base + [_key_col(c) for c in cols])

    def slowdown_batch(self, entities: Sequence) -> np.ndarray:
        """Vector of :meth:`slowdown` over ``entities``."""
        s = self._streams("straggle", entities)
        gate = s.random()
        lo, hi = self.straggler_slowdown
        val = lo + (hi - lo) * s.random()
        return np.where(gate >= self.straggler_frac, 1.0, val)

    def crashes_batch(self, entities: Sequence, t: int) -> np.ndarray:
        """Boolean mask of :meth:`crashes` over ``entities`` at ``t``."""
        n = len(entities)
        if self.crash_prob <= 0.0:
            return np.zeros(n, dtype=bool)
        return self._streams("crash", entities, t).random() \
            < self.crash_prob

    def rejoin_after_batch(self, entities: Sequence, t: int) -> np.ndarray:
        """Vector of :meth:`rejoin_after` over ``entities`` at ``t``."""
        lo, hi = self.rejoin_delay
        return self._streams("rejoin", entities, t).integers(lo, hi + 1)

    def flaps_batch(self, entities: Sequence, t: int) -> np.ndarray:
        n = len(entities)
        if self.link_flap_prob <= 0.0:
            return np.zeros(n, dtype=bool)
        return self._streams("flap", entities, t).random() \
            < self.link_flap_prob

    def jitter_batch(self, entities: Sequence, t: int) -> np.ndarray:
        """Vector of :meth:`jitter_s` over ``entities`` at ``t``."""
        flapped = self.flaps_batch(entities, t)
        out = np.zeros(len(entities))
        if not flapped.any():
            return out
        # the jitter stream only exists for flapped lanes (the scalar
        # path opens it after the flap check) — don't pay for the rest
        idx = np.flatnonzero(flapped)
        sub = entities[idx] if isinstance(entities, np.ndarray) \
            else [entities[int(i)] for i in idx]
        lo, hi = self.link_jitter_s
        out[idx] = lo + (hi - lo) * self._streams("jitter", sub, t).random()
        return out

    def corrupts_batch(self, step: int, shards: Sequence,
                       holders: Sequence) -> np.ndarray:
        """Boolean mask of :meth:`corrupts` over (shard, holder) pairs
        written at ``step``."""
        n = len(np.atleast_1d(np.asarray(shards)))
        n = max(n, len(np.atleast_1d(np.asarray(holders, dtype=object))))
        if self.corrupt_prob <= 0.0:
            return np.zeros(n, dtype=bool)
        return self._streams("corrupt", step, shards, holders).random() \
            < self.corrupt_prob

    @property
    def active(self) -> bool:
        return (self.straggler_frac > 0 or self.crash_prob > 0
                or self.link_flap_prob > 0 or self.corrupt_prob > 0)


class FaultInjector:
    """Binds a :class:`FaultPlan` to the telemetry layer: every injected
    fault becomes a ``fault.<kind>`` trace instant (cat ``fault``, track
    ``faults``, args carrying ``entity``) plus a ``faults/<kind>``
    counter, and the injector keeps host-side totals for results."""

    def __init__(self, plan: Optional[FaultPlan], *, registry=None):
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.trace import get_tracer
        self.plan = plan if plan is not None else FaultPlan()
        self.tracer = get_tracer()
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.counts: dict = {}

    def emit(self, kind: str, entity, *, ts_s: Optional[float] = None,
             **attrs) -> None:
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.tracer.instant(f"fault.{kind}", "fault", track="faults",
                            ts_s=ts_s, entity=str(entity), **attrs)
        self.registry.counter(f"faults/{kind}").inc(1)

    # convenience pass-throughs (draw + emit happen at the call site so
    # consumers control the timestamp/attrs; these just shorten access)
    def __getattr__(self, name):
        return getattr(self.plan, name)


def corrupt_file(path, *, seed: int = 0, flips: int = 8) -> int:
    """Deterministically bit-rot ``path``: XOR ``flips`` bytes at seeded
    offsets past the first 128 bytes (so an ``.npy`` header still parses
    and the rot is only catchable by checksum, like real silent disk
    corruption).  Returns the number of bytes flipped."""
    from pathlib import Path
    p = Path(path)
    data = bytearray(p.read_bytes())
    if not data:
        return 0
    start = min(128, max(0, len(data) - 1))
    rng = np.random.default_rng([int(seed) & 0xFFFFFFFF,
                                 zlib.crc32(p.name.encode())])
    n = min(flips, len(data) - start) or 1
    offs = rng.integers(start, len(data), size=n)
    for o in offs:
        data[int(o)] ^= 0xFF
    p.write_bytes(bytes(data))
    return n
