"""Vectorized keyed RNG streams, bit-exact with ``np.random.default_rng``.

The fault plan's contract (PR 7) is *stateless keyed draws*: every
consumer opens ``np.random.default_rng([seed, crc32(kind), entity, t])``
and draws, so replays are bit-identical regardless of who asks in what
order.  That contract caps fleet size: one ``default_rng`` construction
costs ~20 µs (SeedSequence entropy pool + PCG64 seeding), so a 10⁵-device
churn step spends seconds *constructing generators*, not simulating.

This module re-implements the exact entropy pipeline as array code over
``uint32``/``uint64`` lanes — one lane per (entity, t) key — so a whole
fleet's draws are one vectorized call with **bit-identical** outputs:

* ``SeedSequence`` entropy-pool mixing (pool size 4; the hashmix /
  mix(x,y) = ``x*MIX_MULT_L - y*MIX_MULT_R`` lattice, ``XSHIFT`` 16),
* ``generate_state(4, uint64)`` (INIT_B/MULT_B cycle over the pool,
  little-endian uint32 pairs),
* PCG64 seeding (128-bit LCG: ``inc = (seq << 1) | 1``; advance, add
  initstate, advance) and the XSL-RR output function,
* ``Generator.random()`` (53-bit mantissa of ``next64``) and
  ``Generator.integers`` for 32-bit ranges (buffered Lemire rejection on
  ``next32`` halves, low half first — what small ``integers(lo, hi)``
  draws actually consume).

Parity is asserted property-style in ``tests/test_fleet_scale.py`` and
re-gated by ``benchmarks/bench_fleet_scale.py`` (0 mismatches on
overlapping entities between these lanes and per-entity ``default_rng``).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

# SeedSequence hash constants (numpy.random.bit_generator).
_XSHIFT = np.uint32(16)
_INIT_A = np.uint32(0x43B0D7E5)
_MULT_A = np.uint32(0x931E8875)
_INIT_B = np.uint32(0x8B51F9DD)
_MULT_B = np.uint32(0x58F38DED)
_MIX_MULT_L = np.uint32(0xCA01F9DD)
_MIX_MULT_R = np.uint32(0x4973F715)
_POOL_SIZE = 4

# PCG64 128-bit LCG multiplier, split into 64-bit halves.
_PCG_MULT_HI = np.uint64(0x2360ED051FC65DA4)
_PCG_MULT_LO = np.uint64(0x4385DF649FCCF645)

_U64_32 = np.uint64(32)
_U64_MASK32 = np.uint64(0xFFFFFFFF)


def _hashmix(value: np.ndarray, const: np.ndarray) -> tuple:
    """SeedSequence hashmix: returns (hashed value, advanced const)."""
    value = value ^ const
    const = const * _MULT_A
    value = value * const
    value ^= value >> _XSHIFT
    return value, const


def _mix(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    res = (x * _MIX_MULT_L) - (y * _MIX_MULT_R)
    res ^= res >> _XSHIFT
    return res


def entropy_pool(columns: Sequence[np.ndarray]) -> list:
    """The SeedSequence 4-word entropy pool, one lane per column row.

    ``columns[i]`` is entropy word ``i`` of every lane (what the scalar
    path passes as ``default_rng([w0, w1, ...])``).  Words beyond the
    pool size feed the extra-entropy mixing loop, exactly as
    ``SeedSequence.mix_entropy`` does.
    """
    cols = [np.asarray(c, dtype=np.uint32) for c in columns]
    n = cols[0].shape
    const = np.broadcast_to(_INIT_A, n).copy()
    pool = []
    for i in range(_POOL_SIZE):
        src = cols[i] if i < len(cols) else np.zeros(n, np.uint32)
        v, const = _hashmix(src, const)
        pool.append(v)
    for i_src in range(_POOL_SIZE):
        for i_dst in range(_POOL_SIZE):
            if i_src != i_dst:
                v, const = _hashmix(pool[i_src], const)
                pool[i_dst] = _mix(pool[i_dst], v)
    for i_src in range(_POOL_SIZE, len(cols)):
        for i_dst in range(_POOL_SIZE):
            v, const = _hashmix(cols[i_src], const)
            pool[i_dst] = _mix(pool[i_dst], v)
    return pool


def _generate_state64(pool: Sequence[np.ndarray]) -> list:
    """``SeedSequence.generate_state(4, uint64)`` over lanes: 8 uint32
    words drawn by cycling the pool under INIT_B/MULT_B, paired
    little-endian (even word = low half)."""
    const = np.broadcast_to(_INIT_B, pool[0].shape).copy()
    words = []
    for i in range(2 * _POOL_SIZE):
        v = pool[i % _POOL_SIZE] ^ const
        const = const * _MULT_B
        v = v * const
        v ^= v >> _XSHIFT
        words.append(v)
    return [words[2 * i].astype(np.uint64)
            | (words[2 * i + 1].astype(np.uint64) << _U64_32)
            for i in range(_POOL_SIZE)]


def _mul128(ah, al, bh, bl):
    """(ah:al) * (bh:bl) mod 2^128 via 32-bit limbs of the low product."""
    a0 = al & _U64_MASK32
    a1 = al >> _U64_32
    b0 = bl & _U64_MASK32
    b1 = bl >> _U64_32
    t = a0 * b0
    w1_lo = (a1 * b0 + (t >> _U64_32))
    w2 = w1_lo >> _U64_32
    t2 = a0 * b1 + (w1_lo & _U64_MASK32)
    hi = a1 * b1 + w2 + (t2 >> _U64_32)   # high 64 bits of al*bl
    lo = al * bl                           # wrapping low 64 bits
    hi = hi + ah * bl + al * bh            # cross terms wrap mod 2^64
    return hi, lo


def _add128(ah, al, bh, bl):
    lo = al + bl
    hi = ah + bh + (lo < al).astype(np.uint64)
    return hi, lo


class KeyedStreams:
    """One PCG64 lane per key row; every draw advances all lanes.

    Construct with :func:`keyed_streams`.  Draw order per lane matches a
    scalar ``np.random.Generator`` exactly: interleave ``random()``,
    ``next64()`` and ``integers()`` freely and each lane replays the
    scalar sequence bit-for-bit (including the next32 half-word buffer
    the bounded-integer path consumes).
    """

    def __init__(self, state64: Sequence[np.ndarray]):
        init_hi, init_lo, seq_hi, seq_lo = state64
        one = np.uint64(1)
        self._inc_hi = (seq_hi << one) | (seq_lo >> np.uint64(63))
        self._inc_lo = (seq_lo << one) | one
        # pcg_setseq seeding starts from state 0, so the first advance
        # collapses to 0 * mult + inc = inc — skip the 128-bit multiply
        self._state_hi = self._inc_hi.copy()
        self._state_lo = self._inc_lo.copy()
        self._state_hi, self._state_lo = _add128(
            self._state_hi, self._state_lo, init_hi, init_lo)
        self._advance()
        # next32 buffering (pcg64_next32): low half first, high cached
        self._buf = np.zeros_like(init_hi, dtype=np.uint32)
        self._has_buf = np.zeros(init_hi.shape, dtype=bool)

    @property
    def lanes(self) -> int:
        return int(self._state_hi.shape[0])

    def _advance(self) -> None:
        hi, lo = _mul128(self._state_hi, self._state_lo,
                         np.broadcast_to(_PCG_MULT_HI, self._state_hi.shape),
                         np.broadcast_to(_PCG_MULT_LO, self._state_hi.shape))
        self._state_hi, self._state_lo = _add128(hi, lo,
                                                 self._inc_hi, self._inc_lo)

    def next64(self) -> np.ndarray:
        """PCG64 XSL-RR output, all lanes (invalidates the 32-bit buffer
        the way a scalar generator's next64 does NOT — only use one of
        next64/next32 per logical draw, as the scalar consumers do)."""
        self._advance()
        rot = self._state_hi >> np.uint64(58)
        x = self._state_hi ^ self._state_lo
        return (x >> rot) | (x << ((-rot) & np.uint64(63)))

    def next32(self, mask=None) -> np.ndarray:
        """Buffered 32-bit halves (low first), advancing only ``mask``
        lanes when given — what bounded ``integers`` rejection consumes."""
        if mask is None:
            mask = np.ones(self._state_hi.shape, dtype=bool)
        out = np.zeros(self._state_hi.shape, dtype=np.uint32)
        take_buf = mask & self._has_buf
        out[take_buf] = self._buf[take_buf]
        self._has_buf[take_buf] = False
        fresh = mask & ~take_buf
        if fresh.any():
            # advance only the lanes that need a new 64-bit word
            idx = np.flatnonzero(fresh)
            sh, sl = self._state_hi[idx], self._state_lo[idx]
            h, lo = _mul128(sh, sl,
                            np.broadcast_to(_PCG_MULT_HI, sh.shape),
                            np.broadcast_to(_PCG_MULT_LO, sh.shape))
            h, lo = _add128(h, lo, self._inc_hi[idx], self._inc_lo[idx])
            self._state_hi[idx], self._state_lo[idx] = h, lo
            rot = h >> np.uint64(58)
            x = h ^ lo
            word = (x >> rot) | (x << ((-rot) & np.uint64(63)))
            out[idx] = (word & _U64_MASK32).astype(np.uint32)
            self._buf[idx] = (word >> _U64_32).astype(np.uint32)
            self._has_buf[idx] = True
        return out

    def random(self) -> np.ndarray:
        """``Generator.random()``: 53-bit mantissa of next64."""
        return (self.next64() >> np.uint64(11)) * (1.0 / 9007199254740992.0)

    def integers(self, low: int, high: int) -> np.ndarray:
        """``Generator.integers(low, high)`` for ranges within 32 bits:
        buffered Lemire rejection on next32 halves, per lane."""
        rng = int(high) - int(low) - 1
        if rng < 0:
            raise ValueError(f"empty range [{low}, {high})")
        out = np.full(self._state_hi.shape, int(low), dtype=np.int64)
        if rng == 0:
            return out
        if rng > 0xFFFFFFFF:
            raise NotImplementedError("only 32-bit ranges are vectorized")
        rng_excl = np.uint64(rng + 1)
        threshold = np.uint64((0x100000000 - (rng + 1)) % (rng + 1))
        m = self.next32().astype(np.uint64) * rng_excl
        retry = (m & _U64_MASK32) < threshold
        while retry.any():
            m2 = self.next32(mask=retry).astype(np.uint64) * rng_excl
            m = np.where(retry, m2, m)
            retry = retry & ((m & _U64_MASK32) < threshold)
        return out + (m >> _U64_32).astype(np.int64)


def keyed_streams(columns: Sequence) -> KeyedStreams:
    """Open one generator lane per key row.

    ``columns`` are the entropy words of every lane — lane ``i`` is
    bit-identical to ``np.random.default_rng([c[i] for c in columns])``.
    Scalars broadcast against array columns.
    """
    cols = [np.atleast_1d(np.asarray(c)) for c in columns]
    n = max(c.shape[0] for c in cols)
    cols = [np.broadcast_to(c.astype(np.uint32), (n,)) for c in cols]
    return KeyedStreams(_generate_state64(entropy_pool(cols)))
