from repro.core.faultinject.plan import (FaultInjector, FaultPlan,
                                         corrupt_file)

__all__ = ["FaultPlan", "FaultInjector", "corrupt_file"]
