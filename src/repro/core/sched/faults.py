"""Fault-tolerance strategy trade-offs (§5: checkpointing vs replication vs
recomputation pose "trade-offs between carbon footprint and recovery
latency... identifying Pareto-optimal strategies").

Model: device failures/departures are Poisson with rate λ per device; a
training run of W wall-seconds over N devices sees N·λ·W interruptions.

* checkpoint(interval I): overhead = ckpt_cost·W/I;  loss per failure = I/2
* replication(r):         overhead = (r-1)·100% compute; loss ≈ 0
* recomputation:          overhead = 0 steady-state; loss per failure =
                          full stage recompute (pipeline-depth dependent)

``pareto_frontier`` enumerates strategies and returns the non-dominated set
in (expected slowdown, carbon overhead) space.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence


@dataclass(frozen=True)
class FaultModel:
    lambda_per_device_hour: float   # departure/failure rate
    num_devices: int
    step_time_s: float
    ckpt_write_s: float             # time to write a checkpoint
    ckpt_restore_s: float
    stage_recompute_s: float        # recomputation cost per failure


@dataclass(frozen=True)
class StrategyOutcome:
    name: str
    slowdown: float                 # expected wall-clock multiplier (>=1)
    energy_overhead: float          # extra energy fraction (>=0)

    def dominates(self, other: "StrategyOutcome") -> bool:
        return (self.slowdown <= other.slowdown
                and self.energy_overhead <= other.energy_overhead
                and (self.slowdown < other.slowdown
                     or self.energy_overhead < other.energy_overhead))


def checkpoint_outcome(fm: FaultModel, interval_steps: int) -> StrategyOutcome:
    lam_s = fm.lambda_per_device_hour * fm.num_devices / 3600.0
    interval_s = interval_steps * fm.step_time_s
    write_frac = fm.ckpt_write_s / interval_s
    # expected rework per failure = half an interval + restore
    rework_per_failure = interval_s / 2.0 + fm.ckpt_restore_s
    failure_frac = lam_s * rework_per_failure
    slow = 1.0 + write_frac + failure_frac
    return StrategyOutcome(f"checkpoint@{interval_steps}", slow, slow - 1.0)


def replication_outcome(fm: FaultModel, replicas: int = 2) -> StrategyOutcome:
    # hot standby: compute duplicated, failures nearly free
    lam_s = fm.lambda_per_device_hour * fm.num_devices / 3600.0
    residual = lam_s * fm.ckpt_restore_s * 0.1
    return StrategyOutcome(f"replicate-x{replicas}", 1.0 + residual,
                           float(replicas - 1) + residual)


def recompute_outcome(fm: FaultModel) -> StrategyOutcome:
    lam_s = fm.lambda_per_device_hour * fm.num_devices / 3600.0
    failure_frac = lam_s * fm.stage_recompute_s
    slow = 1.0 + failure_frac
    return StrategyOutcome("recompute", slow, slow - 1.0)


def pareto_frontier(fm: FaultModel,
                    ckpt_intervals: Sequence[int] = (10, 50, 100, 500),
                    ) -> List[StrategyOutcome]:
    cands = [checkpoint_outcome(fm, i) for i in ckpt_intervals]
    cands.append(replication_outcome(fm))
    cands.append(recompute_outcome(fm))
    frontier = [c for c in cands
                if not any(o.dominates(c) for o in cands if o is not c)]
    return sorted(frontier, key=lambda s: s.slowdown)
