"""Fault-tolerance strategy trade-offs (§5: checkpointing vs replication vs
recomputation pose "trade-offs between carbon footprint and recovery
latency... identifying Pareto-optimal strategies").

Model: device failures/departures are Poisson with rate λ per device; a
training run of W wall-seconds over N devices sees N·λ·W interruptions.

* checkpoint(interval I): overhead = ckpt_cost·W/I;  loss per failure = I/2
* replication(r):         overhead = (r-1)·100% compute; loss ≈ 0
* recomputation:          overhead = 0 steady-state; loss per failure =
                          full stage recompute (pipeline-depth dependent)

``pareto_frontier`` enumerates strategies and returns the non-dominated set
in (expected slowdown, carbon overhead) space.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence


@dataclass(frozen=True)
class FaultModel:
    lambda_per_device_hour: float   # departure/failure rate
    num_devices: int
    step_time_s: float
    ckpt_write_s: float             # time to write a checkpoint
    ckpt_restore_s: float           # naive full-state restore
    stage_recompute_s: float        # recomputation cost per failure
    elastic_restore_s: Optional[float] = None
    # ^ placement-aware restore: only the departed node's shard moves
    # (priced from bytes via repro.checkpoint.elastic); None disables
    # the elastic-checkpoint strategies


@dataclass(frozen=True)
class StrategyOutcome:
    name: str
    slowdown: float                 # expected wall-clock multiplier (>=1)
    energy_overhead: float          # extra energy fraction (>=0)

    def dominates(self, other: "StrategyOutcome") -> bool:
        return (self.slowdown <= other.slowdown
                and self.energy_overhead <= other.energy_overhead
                and (self.slowdown < other.slowdown
                     or self.energy_overhead < other.energy_overhead))


def checkpoint_outcome(fm: FaultModel, interval_steps: int, *,
                       elastic: bool = False) -> StrategyOutcome:
    lam_s = fm.lambda_per_device_hour * fm.num_devices / 3600.0
    interval_s = interval_steps * fm.step_time_s
    write_frac = fm.ckpt_write_s / interval_s
    if elastic:
        if fm.elastic_restore_s is None:
            raise ValueError("FaultModel.elastic_restore_s unset; price it "
                             "with priced_fault_model() first")
        restore_s = fm.elastic_restore_s
        name = f"elastic-ckpt@{interval_steps}"
    else:
        restore_s = fm.ckpt_restore_s
        name = f"checkpoint@{interval_steps}"
    # expected rework per failure = half an interval + restore
    rework_per_failure = interval_s / 2.0 + restore_s
    failure_frac = lam_s * rework_per_failure
    slow = 1.0 + write_frac + failure_frac
    return StrategyOutcome(name, slow, slow - 1.0)


def replication_outcome(fm: FaultModel, replicas: int = 2) -> StrategyOutcome:
    # hot standby: compute duplicated, failures nearly free
    lam_s = fm.lambda_per_device_hour * fm.num_devices / 3600.0
    residual = lam_s * fm.ckpt_restore_s * 0.1
    return StrategyOutcome(f"replicate-x{replicas}", 1.0 + residual,
                           float(replicas - 1) + residual)


def recompute_outcome(fm: FaultModel) -> StrategyOutcome:
    lam_s = fm.lambda_per_device_hour * fm.num_devices / 3600.0
    failure_frac = lam_s * fm.stage_recompute_s
    slow = 1.0 + failure_frac
    return StrategyOutcome("recompute", slow, slow - 1.0)


def pareto_frontier(fm: FaultModel,
                    ckpt_intervals: Sequence[int] = (10, 50, 100, 500),
                    ) -> List[StrategyOutcome]:
    cands = [checkpoint_outcome(fm, i) for i in ckpt_intervals]
    if fm.elastic_restore_s is not None:
        cands += [checkpoint_outcome(fm, i, elastic=True)
                  for i in ckpt_intervals]
    cands.append(replication_outcome(fm))
    cands.append(recompute_outcome(fm))
    frontier = [c for c in cands
                if not any(o.dominates(c) for o in cands if o is not c)]
    return sorted(frontier, key=lambda s: s.slowdown)


def priced_fault_model(cfg, placement, *, lambda_per_device_hour: float,
                       step_time_s: float, stage_recompute_s: float,
                       replication: int = 1) -> FaultModel:
    """Price a FaultModel's checkpoint terms from the placement and the
    wide-area topology instead of constants.

    ``ckpt_write_s`` is one elastic write (neighbour replication + store
    upload), ``ckpt_restore_s`` the naive full-state restore every node
    of the placement would pay, and ``elastic_restore_s`` the
    placement-aware recovery after losing one device (its shard refetched
    from the surviving neighbour copies; everyone else's state is local).
    """
    from repro.checkpoint import (CheckpointSpec, recovery_cost,
                                  state_layer_bytes, write_cost)
    topo = placement.topology
    layer_b, global_b = state_layer_bytes(cfg)
    spec = CheckpointSpec.from_placement(placement, replication)
    wc = write_cost(topo, placement, spec, layer_b, global_b)
    naive = recovery_cost(topo, placement, old_spec=spec,
                          layer_bytes=layer_b, global_bytes=global_b,
                          naive=True)
    # one failure: the first stage-0 node loses its local copies
    failed = placement.pipelines[0][0].node
    survivors = spec.with_holders(
        [[n for n in hs if n != failed] for hs in spec.holders])
    el = recovery_cost(topo, placement, old_spec=survivors,
                       layer_bytes=layer_b, global_bytes=global_b)
    nodes = {sp.node for pipe in placement.pipelines for sp in pipe}
    return FaultModel(
        lambda_per_device_hour=lambda_per_device_hour,
        num_devices=len(nodes), step_time_s=step_time_s,
        ckpt_write_s=wc.time_s, ckpt_restore_s=naive.time_s,
        stage_recompute_s=stage_recompute_s,
        elastic_restore_s=el.time_s)
