"""Batched churn/fault sweep over a :class:`FleetArrays` fleet.

The discrete-event :class:`~repro.core.sched.orchestrator.Orchestrator`
replans placements and prices checkpoints — the right tool at tens of
devices.  What it cannot do is answer *fleet-scale* questions ("how does
round time behave at 10⁵ devices under 2%/round churn with 5%
stragglers, sync vs async-quorum?") because every step walks Python
objects and constructs per-entity RNGs.

:class:`FleetSim` runs that sweep as array code: per-round straggler /
crash / flap masks come from the batched keyed streams
(:meth:`FaultPlan.crashes_batch` et al.), which are **bit-compatible**
with the stateless per-entity draws, and round time aggregates through
region-level reductions (per-region maxima, then across regions).

Two engines share every reduction and differ ONLY in how fault draws
are produced:

* ``engine="scalar"``   — one ``default_rng([seed, kind, entity, t])``
  per entity per draw, the PR-7 contract verbatim (the baseline the
  speedup claims measure against);
* ``engine="vectorized"`` — one batched keyed-stream call per fault
  kind per round.

Because the keyed streams are lane-exact, the two engines produce
**bit-identical trajectories** — asserted in tests/test_fleet_scale.py
and gated in benchmarks/bench_fleet_scale.py.

Sync mode waits for every participant (the slowest straggler gates the
round); async mode closes the round at a ``quorum`` fraction of
participants (bounded-staleness local SGD), pricing the k-th order
statistic of finish times instead of the max.
"""

from __future__ import annotations

import time as _time
import zlib
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.faultinject.plan import FaultPlan
from repro.core.net.fleet_arrays import FleetArrays


def _substream(seed: int, name: str) -> np.random.Generator:
    return np.random.default_rng([int(seed) & 0xFFFFFFFF,
                                  zlib.crc32(name.encode())])


@dataclass(frozen=True)
class FleetSimConfig:
    rounds: int = 100
    seed: int = 0
    flops_per_round: float = 1e12       # per-device local work
    sync_bytes: float = 50e6            # per-device sync payload
    leave_prob: float = 0.0             # per round, per active device
    join_prob: float = 0.0              # per round, per idle device
    mode: str = "sync"                  # "sync" | "async"
    quorum: float = 0.9                 # async: round closes at this
                                        # fraction of participants
    fault_plan: Optional[FaultPlan] = None


@dataclass
class FleetSimResult:
    engine: str
    mode: str
    rounds: int
    wall_time_s: float                  # simulated clock
    step_times_s: np.ndarray            # (rounds,)
    active_counts: np.ndarray           # (rounds,)
    mean_active: float
    crashes: int
    flaps: int
    region_busy_s: Dict[str, float]     # per-region sum of device time
    elapsed_s: float                    # real wall clock of the sweep

    def trajectory_equal(self, other: "FleetSimResult") -> bool:
        """Bit-identical trajectories (the scalar/vectorized gate)."""
        return (np.array_equal(self.step_times_s, other.step_times_s)
                and np.array_equal(self.active_counts,
                                   other.active_counts)
                and self.crashes == other.crashes
                and self.flaps == other.flaps)


class FleetSim:
    """One sweep instance; call :meth:`run` once per (engine, config)."""

    def __init__(self, fleet: FleetArrays, cfg: FleetSimConfig):
        self.fleet = fleet
        self.cfg = cfg
        # per-device constants of the round model (shared by engines)
        self._base_compute = cfg.flops_per_round / fleet.eff_flops
        self._comm = (cfg.sync_bytes / fleet.acc_bw) \
            + (fleet.acc_delay + fleet.wan_delay[fleet.region_of])

    # ------------------------------------------------------------- draws
    def _slowdowns(self, engine: str) -> np.ndarray:
        plan = self.cfg.fault_plan
        n = self.fleet.num_devices
        if plan is None or plan.straggler_frac <= 0.0:
            return np.ones(n)
        if engine == "vectorized":
            return plan.slowdown_batch(np.arange(n))
        return np.array([plan.slowdown(int(i)) for i in range(n)])

    def _crashes(self, engine: str, ids: np.ndarray, t: int) -> np.ndarray:
        plan = self.cfg.fault_plan
        if plan is None or plan.crash_prob <= 0.0:
            return np.zeros(ids.shape[0], dtype=bool)
        if engine == "vectorized":
            return plan.crashes_batch(ids, t)
        return np.array([plan.crashes(int(i), t) for i in ids], dtype=bool)

    def _rejoins(self, engine: str, ids: np.ndarray, t: int) -> np.ndarray:
        plan = self.cfg.fault_plan
        if engine == "vectorized":
            return plan.rejoin_after_batch(ids, t)
        return np.array([plan.rejoin_after(int(i), t) for i in ids],
                        dtype=np.int64)

    def _jitter(self, engine: str, ids: np.ndarray, t: int) -> np.ndarray:
        plan = self.cfg.fault_plan
        if plan is None or plan.link_flap_prob <= 0.0:
            return np.zeros(ids.shape[0])
        if engine == "vectorized":
            return plan.jitter_batch(ids, t)
        return np.array([plan.jitter_s(int(i), t) for i in ids])

    # ---------------------------------------------------------- reduction
    def _round_time(self, ids: np.ndarray, finish: np.ndarray,
                    busy_acc: np.ndarray) -> float:
        """Aggregate a round: per-region maxima (and busy sums), then
        the cross-region reduction — max for sync, k-th order statistic
        of finish times for async quorum."""
        rid = self.fleet.region_of[ids]
        order = np.argsort(rid, kind="stable")
        rid_s = rid[order]
        fin_s = finish[order]
        starts = np.flatnonzero(np.r_[True, rid_s[1:] != rid_s[:-1]])
        reg_max = np.maximum.reduceat(fin_s, starts)
        np.add.at(busy_acc, rid_s[starts], np.add.reduceat(fin_s, starts))
        if self.cfg.mode == "async":
            k = max(1, int(np.ceil(self.cfg.quorum * ids.shape[0])))
            return float(np.partition(finish, k - 1)[k - 1])
        return float(reg_max.max())

    # ---------------------------------------------------------------- run
    def run(self, engine: str = "vectorized") -> FleetSimResult:
        if engine not in ("vectorized", "scalar"):
            raise ValueError(f"unknown engine {engine!r}")
        cfg = self.cfg
        fleet = self.fleet
        n = fleet.num_devices
        t_real = _time.perf_counter()
        slow = self._slowdowns(engine)
        base = self._base_compute
        comm = self._comm
        rng_leave = _substream(cfg.seed, "leave")
        rng_join = _substream(cfg.seed, "join")
        active = np.ones(n, dtype=bool)
        offline_until = np.zeros(n, dtype=np.int64)
        step_times = np.zeros(cfg.rounds)
        active_counts = np.zeros(cfg.rounds, dtype=np.int64)
        busy_acc = np.zeros(fleet.num_regions)
        crashes = 0
        flaps = 0
        wall = 0.0
        for t in range(cfg.rounds):
            # churn (both engines share these batched substream draws;
            # the engines differ only in the keyed FAULT draws)
            if cfg.leave_prob > 0.0:
                leave = rng_leave.random(n) < cfg.leave_prob
                active &= ~leave
            if cfg.join_prob > 0.0:
                join = rng_join.random(n) < cfg.join_prob
                active |= join & ~active & (t >= offline_until)
            if not active.any():
                active[0] = True
            ids = np.flatnonzero(active)
            # injected crashes: vanish before the round, rejoin later
            cr = self._crashes(engine, ids, t)
            if cr.any():
                crashed = ids[cr]
                waits = self._rejoins(engine, crashed, t)
                offline_until[crashed] = t + waits
                active[crashed] = False
                crashes += int(cr.sum())
                ids = np.flatnonzero(active)
                if ids.shape[0] == 0:
                    active[0] = True
                    ids = np.flatnonzero(active)
            # rejoin crashed devices whose wait expired
            back = (~active) & (offline_until > 0) & (t >= offline_until)
            if back.any():
                active |= back
                offline_until[back] = 0
                ids = np.flatnonzero(active)
            jit = self._jitter(engine, ids, t)
            flaps += int((jit > 0.0).sum())
            finish = (base[ids] * slow[ids] + comm[ids]) + jit
            dt = self._round_time(ids, finish, busy_acc)
            step_times[t] = dt
            active_counts[t] = ids.shape[0]
            wall += dt
        region_busy = {str(r): float(busy_acc[i])
                       for i, r in enumerate(fleet.regions)}
        return FleetSimResult(
            engine=engine, mode=cfg.mode, rounds=cfg.rounds,
            wall_time_s=wall, step_times_s=step_times,
            active_counts=active_counts,
            mean_active=float(active_counts.mean()),
            crashes=crashes, flaps=flaps,
            region_busy_s=region_busy,
            elapsed_s=_time.perf_counter() - t_real)


def churn_sweep(fleet: FleetArrays, cfg: FleetSimConfig, *,
                engine: str = "vectorized") -> FleetSimResult:
    """One-shot convenience wrapper: build a :class:`FleetSim`, run."""
    return FleetSim(fleet, cfg).run(engine)
