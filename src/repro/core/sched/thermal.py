"""Thermal-throttling model for edge devices (§5: "susceptible to thermal
throttling... sustained compute loads cause slowdowns").

First-order RC model: package temperature follows
    dT/dt = (P · R_th − (T − T_amb)) / τ
with hardware-imposed frequency scaling once T crosses the throttle point
(linear derating to ``min_perf`` at T_max).  Parameters bracket published
SoC sustained-performance measurements (passively cooled phones throttle to
~60-70% after minutes; actively cooled laptops barely throttle).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ThermalParams:
    r_th_c_per_w: float       # thermal resistance
    tau_s: float              # time constant
    t_ambient_c: float = 25.0
    t_throttle_c: float = 42.0
    t_max_c: float = 48.0
    min_perf: float = 0.55    # floor performance fraction


PHONE_THERMALS = ThermalParams(r_th_c_per_w=2.4, tau_s=90.0)
LAPTOP_THERMALS = ThermalParams(r_th_c_per_w=1.1, tau_s=240.0,
                                t_throttle_c=70.0, t_max_c=95.0,
                                min_perf=0.85)


@dataclass
class ThermalState:
    params: ThermalParams
    temp_c: float = 25.0

    def step(self, power_w: float, dt_s: float) -> float:
        """Advance the RC model; returns the performance factor in [min,1]."""
        p = self.params
        target = p.t_ambient_c + power_w * p.r_th_c_per_w
        alpha = 1.0 - pow(2.718281828, -dt_s / p.tau_s)
        self.temp_c += (target - self.temp_c) * alpha
        return self.perf_factor()

    def perf_factor(self) -> float:
        p = self.params
        if self.temp_c <= p.t_throttle_c:
            return 1.0
        if self.temp_c >= p.t_max_c:
            return p.min_perf
        frac = (self.temp_c - p.t_throttle_c) / (p.t_max_c - p.t_throttle_c)
        return 1.0 - frac * (1.0 - p.min_perf)


def sustained_perf(params: ThermalParams, power_w: float) -> float:
    """Steady-state performance factor under constant load."""
    st = ThermalState(params)
    for _ in range(int(20 * params.tau_s)):
        f = st.step(power_w * st.perf_factor(), 1.0)
    return st.perf_factor()
