"""Discrete-event orchestration simulator for edge training (§5).

Simulates a training run over a dynamic edge fleet:

* devices join/leave (Poisson churn — "dynamic device participation"),
* thermal throttling via the RC model (per-device state),
* carbon-aware admission (only devices under the gCO2e/GFLOP threshold and
  in clean-energy windows join the active set),
* fault tolerance by periodic checkpointing (rework on failure),
* per-step energy/carbon ledger (compute + stall + comm + rework).

Deterministic given the seed: every stochastic consumer draws from its
own **named substream** of ``SimConfig.seed`` (join churn, leave churn —
and fault draws, which are keyed streams inside the
:class:`~repro.core.faultinject.FaultPlan` itself), so identical configs
replay identical trajectories and toggling fault injection on cannot
perturb the churn sequence.  The simulator IS the system's orchestration
logic, exercised by tests and examples, not a visualization toy.  Time
advances step-by-step; each step reassigns the DT-FM plan if membership
changed (the paper's "preemptible execution and fast state recovery"
loop).

An optional ``SimConfig.fault_plan`` injects deterministic faults on top
of the Poisson churn: stragglers stretch the step clock, link flaps add
wide-area jitter, crashes force departures (with the usual rework +
replan + priced recovery), and checkpoint-shard corruption knocks holder
copies out of the recovery spec — a corrupted survivor then degrades to
a neighbour or WAN/store fetch in the recovery pricing instead of
crashing the run.

An optional :class:`repro.obs.HealthMonitor` closes the observability
loop (PR 9): the orchestrator feeds it the per-device compute and link
durations each step *observes* (never the plan's draws directly — the
plan stays the sim's hidden ground truth), and any device the monitor
flags — straggler or repeatedly-flapping link — is **degraded** out of
the active set through the normal churn machinery, so the rework /
replan / priced-recovery pipeline prices the eviction exactly like an
organic departure.  Because the synchronous pipeline is gated by its
slowest member, evicting a detected straggler is a throughput decision
the fleet could never make by reading the plan it does not have.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.checkpoint import (CheckpointSpec, recovery_cost,
                              state_layer_bytes, write_cost)
from repro.core.carbon.accounting import CarbonLedger
from repro.core.carbon.intensity import IntensityTrace
from repro.core.faultinject import FaultInjector, FaultPlan
from repro.core.net import Topology
from repro.core.placement import search_placement
from repro.core.planner import dtfm
from repro.obs.trace import get_tracer
from repro.core.sched.carbon_aware import FleetDevice, carbon_rate
from repro.core.sched.thermal import ThermalState
from repro.models.config import ModelConfig


def _substream(seed: int, name: str) -> np.random.Generator:
    """Named RNG substream of the sim seed (same keyed-stream idiom as
    :mod:`repro.core.faultinject`): consumers cannot perturb each other."""
    return np.random.default_rng([int(seed) & 0xFFFFFFFF,
                                  zlib.crc32(name.encode())])


@dataclass
class SimConfig:
    total_steps: int = 200
    batch: int = 16
    seq_len: int = 512
    microbatches: int = 32
    checkpoint_interval: int = 50
    ckpt_replication: int = 1        # §5 neighbour shard copies per write
    naive_restore: bool = False      # price recovery as full-state store
                                     # fetches (the placement-blind
                                     # baseline bench_elastic beats)
    churn_leave_per_hour: float = 0.2      # per active device
    churn_join_per_hour: float = 0.5       # per idle candidate
    carbon_threshold_g_per_gflop: float = float("inf")
    start_hour_utc: float = 9.0
    seed: int = 0
    fault_plan: Optional[FaultPlan] = None   # deterministic injected
                                             # faults on top of churn


@dataclass
class SimResult:
    steps_done: int
    wall_time_s: float
    energy_wh: float
    carbon_kg: float
    rework_steps: int
    membership_changes: int
    mean_active_devices: float
    throughput_steps_per_hour: float
    trace: List[Dict] = field(default_factory=list)
    comm_s_total: float = 0.0
    comm_energy_wh: float = 0.0
    topology_rebuilds: int = 0
    wan_bytes_total: float = 0.0
    last_placement: str = ""
    # elastic-state accounting (bytes priced through core.net, not the
    # old ckpt_write_s/ckpt_restore_s constants) — what lets
    # benchmarks/sched_carbon attribute recovery carbon separately
    ckpt_writes: int = 0
    ckpt_write_s_total: float = 0.0
    ckpt_bytes_written: float = 0.0
    ckpt_bytes_by_region: Dict[str, float] = field(default_factory=dict)
    restores: int = 0
    restore_s_total: float = 0.0
    restore_bytes_moved: float = 0.0
    restore_wan_bytes: float = 0.0
    restore_bytes_by_region: Dict[str, float] = field(default_factory=dict)
    recovery_energy_wh: float = 0.0     # radio energy of writes+restores
    # fault-injection accounting (empty without a fault_plan)
    fault_counts: Dict[str, int] = field(default_factory=dict)
    crashes: int = 0
    corrupted_shard_copies: int = 0
    # health-driven response accounting (empty without a HealthMonitor)
    health_evictions: int = 0
    health_summary: Optional[Dict] = None


class Orchestrator:
    def __init__(self, cfg: ModelConfig, fleet: Sequence[FleetDevice],
                 sim: SimConfig, *, health=None):
        self.cfg = cfg
        self.fleet = list(fleet)
        self.sim = sim
        # PR 9: detection-driven degradation.  ``health`` is a
        # repro.obs.HealthMonitor fed ONLY observed durations; devices
        # it flags land in ``degraded`` and stay out of admission until
        # the detector clears them.
        self.health = health
        self.degraded: Set[int] = set()
        # named substreams: join draws never perturb leave draws (and
        # neither shifts when the keyed-stream fault plan is toggled)
        self.rng_join = _substream(sim.seed, "join")
        self.rng_leave = _substream(sim.seed, "leave")
        self.thermals = {d.device_id: ThermalState(d.thermal_params())
                         for d in self.fleet}
        self.active: List[FleetDevice] = []
        self.ledger = CarbonLedger()
        self.traces: Dict[str, IntensityTrace] = {}
        self.topology: Optional[Topology] = None
        self.topology_rebuilds = 0
        self.injector = FaultInjector(sim.fault_plan) \
            if sim.fault_plan is not None and sim.fault_plan.active \
            else None
        self._offline_until: Dict[int, int] = {}   # device -> rejoin step
        # persistent straggler factors, filled through the batched keyed
        # draws (bit-identical to plan.slowdown per entity) so a step
        # over a large active set costs one vectorized call, not one
        # Generator construction per device
        self._slowdown: Dict[int, float] = {}
        self._step = 0

    def _rebuild_topology(self) -> Topology:
        """Wide-area graph over the current active set; called on every
        membership change (the paper's preemptible-execution loop)."""
        self.topology = Topology.from_fleet(self.active)
        self.topology_rebuilds += 1
        return self.topology

    # ------------------------------------------------------------ membership
    def _admit(self, hour: float) -> int:
        """Carbon-aware admission; returns number of membership changes."""
        changes = 0
        active_ids = {d.device_id for d in self.active}
        for d in self.fleet:
            rate, _ = carbon_rate(d, hour, self.traces)
            ok = d.charging and rate <= self.sim.carbon_threshold_g_per_gflop
            if ok and d.device_id in self.degraded:
                # health-degraded: out until the detector clears it (an
                # evicted device produces no new observations, so in
                # practice degradation is sticky — by design)
                if self.health is not None \
                        and not self.health.is_straggler(d.device_id) \
                        and str(d.device_id) \
                        not in self.health.degraded_links():
                    self.degraded.discard(d.device_id)
                else:
                    ok = False
            if ok and d.device_id in self._offline_until:
                # crashed device: stays out until its rejoin step
                ok = self._step >= self._offline_until[d.device_id]
                if ok:
                    del self._offline_until[d.device_id]
                    if self.injector is not None:
                        self.injector.emit("rejoin", d.device_id,
                                           ts_s=self._t, step=self._step)
            if ok and d.device_id not in active_ids:
                # idle candidate joins with prob churn_join per hour
                if self.rng_join.random() < self.sim.churn_join_per_hour \
                        / 3600.0 * self._dt or not self.active:
                    self.active.append(d)
                    changes += 1
            elif not ok and d.device_id in active_ids:
                self.active = [a for a in self.active
                               if a.device_id != d.device_id]
                changes += 1
        return changes

    def _depart(self) -> int:
        leave_p = self.sim.churn_leave_per_hour / 3600.0 * self._dt
        stay = []
        changes = 0
        crash_mask = self.injector.plan.crashes_batch(
            [d.device_id for d in self.active], self._step) \
            if self.injector is not None else None
        for k, d in enumerate(self.active):
            crashed = crash_mask is not None and bool(crash_mask[k])
            if crashed and len(self.active) > 1:
                # injected crash: device vanishes mid-step and stays
                # offline for its plan-drawn rejoin delay; the usual
                # departure machinery (rework, replan, priced recovery)
                # handles the fallout
                wait = self.injector.plan.rejoin_after(d.device_id,
                                                       self._step)
                self._offline_until[d.device_id] = self._step + wait
                self.injector.emit("crash", d.device_id, ts_s=self._t,
                                   step=self._step, rejoin_steps=wait)
                changes += 1
            elif self.rng_leave.random() < leave_p \
                    and len(self.active) > 1:
                changes += 1
            else:
                stay.append(d)
        self.active = stay
        return changes

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        sim, cfg = self.sim, self.cfg
        # fleet events land on the tracer with EXPLICIT simulated-clock
        # timestamps (seconds from run start) on the "fleet" track —
        # churn, replans, restores and checkpoint writes share one
        # Perfetto timeline with their byte/energy attributions
        tr = get_tracer()
        t = 0.0
        steps = 0
        rework = 0
        changes = 0
        energy_wh = 0.0
        comm_s_total = 0.0
        comm_energy_wh = 0.0
        wan_bytes_total = 0.0
        last_strategy = ""
        active_sum = 0.0
        iterations = 0
        last_ckpt_step = 0
        self._dt = 1.0
        self._t = 0.0
        self._step = 0
        trace: List[Dict] = []
        inj = self.injector
        straggle_announced: Set[int] = set()
        # holder copies knocked out by injected shard corruption; the
        # next recovery prices around them ((shard, node) pairs)
        corrupt_copies: Set[Tuple[int, str]] = set()

        # elastic state: where shard copies currently sit (live placement
        # nodes; checkpoint writes add §5 neighbour replication), and the
        # per-layer / placement-independent byte split the recovery
        # pricing slices by
        layer_b, global_b = state_layer_bytes(cfg)
        state_spec: Optional[CheckpointSpec] = None
        ckpt_writes = 0
        ckpt_write_s_total = 0.0
        ckpt_bytes_written = 0.0
        ckpt_by_region: Dict[str, float] = {}
        restores = 0
        restore_s_total = 0.0
        restore_bytes_moved = 0.0
        restore_wan = 0.0
        restore_by_region: Dict[str, float] = {}
        recovery_energy_wh = 0.0
        health_evictions = 0

        def _merge(dst: Dict[str, float], src: Dict[str, float]) -> None:
            for k, v in src.items():
                dst[k] = dst.get(k, 0.0) + v

        # initial admission
        hour = sim.start_hour_utc
        self._dt = 3600.0
        changes += self._admit(hour)
        if not self.active:
            self.active = [self.fleet[0]]
        topo = self._rebuild_topology()
        plan = None

        while steps < sim.total_steps:
            hour = (sim.start_hour_utc + t / 3600.0) % 24.0
            self._t, self._step = t, steps
            members_before = {d.device_id for d in self.active}

            if plan is None:
                # membership changed (or first step): rebuild the
                # wide-area topology and replan through the shared
                # placement API — the search keeps each pipeline's
                # regions contiguous so stage-boundary activations ride
                # intra-region links instead of the ad-hoc active-list
                # order the seed used (collective= explicit so search
                # and accounting price the same model)
                placement = search_placement(
                    cfg, [d.spec for d in self.active],
                    topology=topo,
                    nodes=[str(d.device_id) for d in self.active],
                    batch=sim.batch, seq_len=sim.seq_len,
                    microbatches=sim.microbatches, collective="ring")
                if state_spec is not None:
                    # the new placement must be fed the training state:
                    # price the bytes ACTUALLY missing (survivors keep
                    # their shards; joiners fetch their layer ranges
                    # from the nearest holder) through the wide-area
                    # model — this replaces the old ckpt_restore_s
                    # constant
                    rec_spec = state_spec
                    if corrupt_copies and rec_spec.holders:
                        # injected bit-rot knocked holder copies out:
                        # the self-healing restore re-fetches from the
                        # surviving holders — possibly the WAN/store
                        # when a shard lost every copy — instead of
                        # crashing on the corrupt survivor
                        rec_spec = CheckpointSpec(
                            rec_spec.num_layers, rec_spec.boundaries,
                            rec_spec.replication,
                            tuple(tuple(h for h in hs
                                        if (i, h) not in corrupt_copies)
                                  for i, hs in
                                  enumerate(rec_spec.holders)))
                    rc = recovery_cost(topo, placement,
                                       old_spec=rec_spec,
                                       layer_bytes=layer_b,
                                       global_bytes=global_b,
                                       naive=sim.naive_restore)
                    if corrupt_copies:
                        healed = len({s for s, _ in corrupt_copies})
                        if inj is not None:
                            inj.emit("heal", "fleet", ts_s=t,
                                     step=steps, shards=healed,
                                     bytes=rc.bytes_moved)
                        corrupt_copies.clear()
                    tr.complete("restore", ts_s=t, dur_s=rc.time_s,
                                cat="sched", track="fleet",
                                bytes_moved=rc.bytes_moved,
                                wan_bytes=rc.wan_bytes,
                                energy_wh=rc.energy_wh, step=steps)
                    t += rc.time_s
                    restores += 1
                    restore_s_total += rc.time_s
                    restore_bytes_moved += rc.bytes_moved
                    restore_wan += rc.wan_bytes
                    _merge(restore_by_region, rc.per_region_bytes)
                    energy_wh += rc.energy_wh
                    recovery_energy_wh += rc.energy_wh
                    ci_now = self.traces.setdefault(
                        self.active[0].region,
                        IntensityTrace(self.active[0].region)).at_hour(hour)
                    self.ledger.add_operational_wh(
                        f"restore{steps}", rc.energy_wh, intensity=ci_now)
                # the live state now sits on the new placement's nodes
                state_spec = CheckpointSpec.from_placement(placement, 0)
                plan = dtfm.plan_placement(
                    cfg, placement,
                    batch=sim.batch, seq_len=sim.seq_len,
                    microbatches=sim.microbatches, collective="ring")
                last_strategy = placement.strategy
                tr.instant("replan", "sched", track="fleet", ts_s=t,
                           step=steps, strategy=placement.strategy,
                           active=len(self.active))
            # scale COMPUTE time by the thermal derate of the slowest
            # member; comm time is not derated (the radio, not the SoC,
            # is the bottleneck)
            derate = min(self.thermals[d.device_id].perf_factor()
                         for d in self.active)
            compute_s = plan.step_time_s - plan.comm_s_per_step
            comm_s = plan.comm_s_per_step
            slow = 1.0
            dev_slow: Dict[int, float] = {}
            dev_jit: Dict[int, float] = {}
            if inj is not None:
                # the synchronous pipeline is gated by its slowest
                # member: the worst straggler stretches compute, and
                # each flapped link adds serial jitter to the ring sync.
                # Both masks come from the batched keyed streams — one
                # vectorized draw over the active set, lane-identical to
                # the per-entity scalar draws
                ids = [d.device_id for d in self.active]
                missing = [i for i in ids if i not in self._slowdown]
                if missing:
                    self._slowdown.update(zip(
                        missing,
                        (float(v) for v in
                         inj.plan.slowdown_batch(missing))))
                jit = inj.plan.jitter_batch(ids, steps)
                for k, d in enumerate(self.active):
                    s_d = self._slowdown[d.device_id]
                    if s_d > 1.0 and d.device_id not in straggle_announced:
                        straggle_announced.add(d.device_id)
                        inj.emit("straggle", d.device_id, ts_s=t,
                                 slowdown=round(s_d, 3))
                    slow = max(slow, s_d)
                    dev_slow[d.device_id] = s_d
                    j = float(jit[k])
                    if j > 0.0:
                        inj.emit("link_flap", d.device_id, ts_s=t,
                                 step=steps, jitter_s=round(j, 3))
                        comm_s += j
                        dev_jit[d.device_id] = j
            step_s = compute_s * slow / max(derate, 1e-6) + comm_s
            if self.health is not None:
                # feed the monitor what a per-device span would measure:
                # that device's compute time under its own slowdown /
                # derate, and its share of the sync plus its link jitter
                for d in self.active:
                    self.health.observe_step(
                        d.device_id,
                        compute_s * dev_slow.get(d.device_id, 1.0)
                        / max(derate, 1e-6), ts_s=t)
                    self.health.observe_link(
                        d.device_id,
                        plan.comm_s_per_step
                        + dev_jit.get(d.device_id, 0.0), ts_s=t)
            self._dt = step_s

            # advance thermals under load
            for d in self.active:
                self.thermals[d.device_id].step(d.spec.power_active_w, step_s)
            for d in self.fleet:
                if d.device_id not in {a.device_id for a in self.active}:
                    self.thermals[d.device_id].step(0.5, step_s)

            # energy + carbon for this step (comm energy un-derated,
            # matching the wall-time split above)
            e_comm_wh = plan.comm_energy_wh_per_step
            e_wh = (plan.total_energy_wh_per_step - e_comm_wh) \
                / max(derate, 1e-6) + e_comm_wh
            energy_wh += e_wh
            comm_s_total += plan.comm_s_per_step
            comm_energy_wh += e_comm_wh
            wan_bytes_total += plan.wan_bytes_per_step
            ci = self.traces.setdefault(
                self.active[0].region,
                IntensityTrace(self.active[0].region)).at_hour(hour)
            self.ledger.add_operational_wh(f"step{steps}", e_wh,
                                           intensity=ci)

            # checkpoint overhead: local snapshots are free; the network
            # pays for §5 neighbour replication plus the durable store
            # upload, priced over the current topology
            if steps - last_ckpt_step >= sim.checkpoint_interval:
                ck_spec = CheckpointSpec.from_placement(
                    placement, sim.ckpt_replication)
                wc = write_cost(topo, placement, ck_spec, layer_b, global_b)
                tr.complete("ckpt_write", ts_s=t, dur_s=wc.time_s,
                            cat="sched", track="fleet", step=steps,
                            bytes_moved=wc.bytes_moved,
                            energy_wh=wc.energy_wh)
                t += wc.time_s
                ckpt_writes += 1
                ckpt_write_s_total += wc.time_s
                ckpt_bytes_written += wc.bytes_moved
                _merge(ckpt_by_region, wc.per_region_bytes)
                energy_wh += wc.energy_wh
                recovery_energy_wh += wc.energy_wh
                self.ledger.add_operational_wh(f"ckpt{steps}", wc.energy_wh,
                                               intensity=ci)
                state_spec = ck_spec
                last_ckpt_step = steps
                if inj is not None and inj.plan.corrupt_prob > 0:
                    # silent bit-rot on freshly written holder copies:
                    # drawn per (step, shard, holder) so the same plan
                    # rots the same copies every replay
                    corrupt_copies.clear()
                    for s_i, hs in enumerate(ck_spec.holders):
                        for h in hs:
                            if inj.plan.corrupts(steps, s_i, h):
                                corrupt_copies.add((s_i, h))
                                inj.emit("corrupt", h, ts_s=t,
                                         step=steps, shard=s_i)

            # health-driven degradation: evict any member the monitor
            # has flagged (detected straggler or repeatedly-flapping
            # link) — the departure flows through the same rework /
            # replan / priced-recovery machinery as organic churn
            evicted: List[int] = []
            if self.health is not None:
                bad = {int(e) for e in self.health.stragglers()
                       if e.lstrip("-").isdigit()}
                bad |= {int(e) for e in self.health.degraded_links()
                        if e.lstrip("-").isdigit()}
                for d in list(self.active):
                    if d.device_id in bad and len(self.active) > 1:
                        self.active = [a for a in self.active
                                       if a.device_id != d.device_id]
                        self.degraded.add(d.device_id)
                        evicted.append(d.device_id)
                if evicted:
                    health_evictions += len(evicted)
                    tr.instant("degrade", "sched", track="fleet",
                               ts_s=t, step=steps,
                               devices=sorted(evicted), reason="health")

            # churn
            changes_now = len(evicted) + self._depart() + self._admit(hour)
            if not self.active:
                # carbon/charging eviction can empty the fleet (unlike
                # _depart, _admit has no min-1 floor): keep the seed
                # device so the next plan/derate have a member
                self.active = [self.fleet[0]]
                changes_now += 1
            changes += changes_now
            members_now = {d.device_id for d in self.active}
            if changes_now:
                tr.instant("churn", "sched", track="fleet", ts_s=t,
                           step=steps, changes=changes_now,
                           joined=sorted(members_now - members_before),
                           left=sorted(members_before - members_now),
                           active=len(self.active))
            if members_before - members_now:
                # a member LEFT (joins don't lose state): recompute the
                # lost steps — charged as extra wall time and energy,
                # not by rewinding the step counter (a rewind livelocks
                # under sustained churn: expected progress hits zero
                # before the next checkpoint).  The state-movement cost
                # of the restore itself is priced at the replan below,
                # from the bytes the new placement is actually missing.
                lost = min(steps - last_ckpt_step,
                           sim.checkpoint_interval) // 2
                rework += lost
                tr.complete("rework", ts_s=t, dur_s=lost * step_s,
                            cat="sched", track="fleet", step=steps,
                            lost_steps=lost)
                t += lost * step_s
                energy_wh += lost * e_wh
                comm_s_total += lost * plan.comm_s_per_step
                comm_energy_wh += lost * e_comm_wh
                wan_bytes_total += lost * plan.wan_bytes_per_step
                self.ledger.add_operational_wh(f"rework{steps}",
                                               lost * e_wh, intensity=ci)
            if changes_now and members_now != members_before:
                # any membership change: rebuild the wide-area topology
                # and replan against it (after the rework accounting,
                # which prices the plan that just executed)
                topo = self._rebuild_topology()
                plan = None

            if tr.enabled:
                tr.complete("step", ts_s=t, dur_s=step_s, cat="sched",
                            track="fleet/steps", step=steps,
                            active=len(self.active),
                            derate=round(derate, 4), energy_wh=e_wh)
                tr.counter("fleet.active", len(self.active), ts_s=t)
            t += step_s
            steps += 1
            active_sum += len(self.active)
            iterations += 1
            if steps % 20 == 0:
                trace.append({"step": steps, "t_s": round(t, 1),
                              "active": len(self.active),
                              "derate": round(derate, 3),
                              "ci": round(ci, 3)})

        return SimResult(
            steps_done=steps,
            wall_time_s=t,
            energy_wh=energy_wh,
            carbon_kg=self.ledger.operational_kg,
            rework_steps=rework,
            membership_changes=changes,
            mean_active_devices=active_sum / max(iterations, 1),
            throughput_steps_per_hour=steps / (t / 3600.0) if t else 0.0,
            trace=trace,
            comm_s_total=comm_s_total,
            comm_energy_wh=comm_energy_wh,
            topology_rebuilds=self.topology_rebuilds,
            wan_bytes_total=wan_bytes_total,
            last_placement=last_strategy,
            ckpt_writes=ckpt_writes,
            ckpt_write_s_total=ckpt_write_s_total,
            ckpt_bytes_written=ckpt_bytes_written,
            ckpt_bytes_by_region=ckpt_by_region,
            restores=restores,
            restore_s_total=restore_s_total,
            restore_bytes_moved=restore_bytes_moved,
            restore_wan_bytes=restore_wan,
            restore_bytes_by_region=restore_by_region,
            recovery_energy_wh=recovery_energy_wh,
            fault_counts=dict(inj.counts) if inj is not None else {},
            crashes=inj.counts.get("crash", 0) if inj is not None else 0,
            corrupted_shard_copies=inj.counts.get("corrupt", 0)
            if inj is not None else 0,
            health_evictions=health_evictions,
            health_summary=self.health.summary()
            if self.health is not None else None,
        )


def make_fleet(spec_counts: Dict[str, int], *, regions=("europe",),
               seed: int = 0) -> List[FleetDevice]:
    from repro.core.energy.devices import CATALOG
    rng = np.random.default_rng(seed)
    fleet = []
    i = 0
    for name, count in spec_counts.items():
        for _ in range(count):
            fleet.append(FleetDevice(
                spec=CATALOG[name],
                region=regions[i % len(regions)],
                tz_offset=float(rng.integers(-6, 7)),
                charging=bool(rng.random() < 0.8),
                device_id=i))
            i += 1
    return fleet
