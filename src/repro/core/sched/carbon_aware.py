"""Carbon-aware device selection (§5: "thermal- and carbon-aware device
selection", "reward participation in low-carbon energy windows").

Each candidate device is priced in gCO2e per useful GFLOP:

    marginal carbon rate = (P_active · CI_region(t)) / (peak · MFU · perf(T))
    [+ embodied surcharge if participation shortens device lifetime]

The scheduler greedily picks the cheapest-carbon devices until the fleet
meets a throughput target, preferring devices currently in a clean-energy
window and derating thermally-hot devices — directly operationalizing the
paper's two §5 bullets.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.carbon.intensity import IntensityTrace
from repro.core.energy.devices import DeviceSpec
from repro.core.sched.thermal import (LAPTOP_THERMALS, PHONE_THERMALS,
                                      ThermalParams, ThermalState,
                                      sustained_perf)


@dataclass
class FleetDevice:
    spec: DeviceSpec
    region: str = "europe"
    tz_offset: float = 0.0
    charging: bool = True
    wear_surcharge: float = 0.0      # extra embodied gCO2e/h if wear matters
    thermal: Optional[ThermalParams] = None
    device_id: int = 0

    def thermal_params(self) -> ThermalParams:
        if self.thermal is not None:
            return self.thermal
        return PHONE_THERMALS if self.spec.kind == "smartphone" \
            else LAPTOP_THERMALS


@dataclass(frozen=True)
class Selection:
    device_id: int
    gco2e_per_gflop: float
    effective_flops: float


def carbon_rate(dev: FleetDevice, hour_utc: float,
                trace_cache: Dict[str, IntensityTrace]) -> Tuple[float, float]:
    """(gCO2e per GFLOP of useful work, sustained effective FLOP/s)."""
    trace = trace_cache.setdefault(dev.region, IntensityTrace(dev.region))
    ci = trace.at_hour(hour_utc, dev.tz_offset)          # kg/kWh
    perf = sustained_perf(dev.thermal_params(), dev.spec.power_active_w)
    eff = dev.spec.effective_flops * perf
    kg_per_s = dev.spec.power_active_w / 1000.0 / 3600.0 * ci
    g_per_gflop = kg_per_s * 1000.0 / (eff / 1e9) + dev.wear_surcharge
    return g_per_gflop, eff


def select_fleet(candidates: Sequence[FleetDevice], *,
                 target_flops: float, hour_utc: float = 12.0,
                 require_charging: bool = True) -> List[Selection]:
    """Greedy min-carbon selection meeting a throughput target."""
    cache: Dict[str, IntensityTrace] = {}
    priced: List[Selection] = []
    for d in candidates:
        if require_charging and not d.charging:
            continue
        rate, eff = carbon_rate(d, hour_utc, cache)
        priced.append(Selection(d.device_id, rate, eff))
    priced.sort(key=lambda s: s.gco2e_per_gflop)
    out: List[Selection] = []
    acc = 0.0
    for s in priced:
        if acc >= target_flops:
            break
        out.append(s)
        acc += s.effective_flops
    return out


def fleet_carbon_rate(selection: Sequence[Selection]) -> float:
    """Aggregate gCO2e/GFLOP of a selected fleet (throughput-weighted)."""
    tot_f = sum(s.effective_flops for s in selection)
    if tot_f == 0:
        return 0.0
    return sum(s.gco2e_per_gflop * s.effective_flops
               for s in selection) / tot_f
