"""The PlacementSpec: device → topology node → stage, per DP replica.

The spec is the *single* plan→place→execute contract:

* ``pipelines[r][i]`` is replica ``r``'s stage ``i``: the device spec,
  its node id in the wide-area topology, and the contiguous layer range
  the stage owns.  Every replica shares the **same** layer boundaries
  (the executor runs one schedule; DP gradient sync matches layer shards
  across replicas) but may sit on entirely different devices/regions.
* Boundaries are **non-uniform**: a laptop stage may own 5 layers while
  the smartphone next to it owns 2 — the executor pads stages to the
  longest one and masks the phantom scan steps.
* ``dp_group(i)`` — the nodes holding stage ``i`` across replicas — is
  the gradient-sync group the collective cost models price, and
  ``region_groups()`` is how local-SGD maps its replicas onto regions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.energy.devices import DeviceSpec
from repro.core.net import Topology


@dataclass(frozen=True)
class StagePlacement:
    """One pipeline stage of one replica, pinned to a topology node."""
    device: DeviceSpec
    node: str                       # topology node id
    layers: range                   # contiguous [start, stop)


@dataclass
class PlacementSpec:
    """Full fleet placement: ``pipelines[replica][stage]``."""
    model: str
    num_layers: int
    pipelines: List[List[StagePlacement]]
    topology: Topology
    strategy: str = "ordered"       # provenance: ordered | round_robin |
                                    # topology_aware | ...
    idle_nodes: List[str] = field(default_factory=list)   # devices the
                                    # placement left out (fleet > dp * S)
    dp_sync_nodes: List[List[str]] = field(default_factory=list)
    # ^ optional per-stage-slot override of the gradient-sync groups
    # (legacy dp_regions semantics: sync is priced from different
    # regions than the pipelines compute in); empty -> groups are the
    # pipeline nodes themselves
    search_stats: Dict[str, float] = field(default_factory=dict)
    # ^ provenance from the search that produced this spec: candidates
    # considered / priced / pruned (memoized or proxy-ranked away),
    # baseline prices, wall time

    # ------------------------------------------------------------- shape
    @property
    def data_parallel(self) -> int:
        return len(self.pipelines)

    @property
    def num_stages(self) -> int:
        return len(self.pipelines[0])

    @property
    def stages(self) -> List[StagePlacement]:
        """Replica 0's pipeline (the reference for uniform-fleet plans)."""
        return self.pipelines[0]

    @property
    def boundaries(self) -> List[int]:
        """Layer boundaries, length num_stages + 1: [0, ..., num_layers]."""
        return [s.layers.start for s in self.pipelines[0]] \
            + [self.num_layers]

    @property
    def layer_counts(self) -> List[int]:
        return [len(s.layers) for s in self.pipelines[0]]

    @property
    def max_stage_layers(self) -> int:
        return max(self.layer_counts)

    # ------------------------------------------------------------ groups
    def dp_group(self, stage: int) -> List[str]:
        """Nodes holding ``stage`` across replicas — the grad-sync group."""
        if self.dp_sync_nodes:
            return list(self.dp_sync_nodes[stage])
        return [pipe[stage].node for pipe in self.pipelines]

    def dp_groups(self) -> List[List[str]]:
        return [self.dp_group(i) for i in range(self.num_stages)]

    def replica_regions(self, replica: int) -> List[str]:
        """Regions replica ``replica``'s stages occupy (stage order)."""
        return [self.topology.device_region[s.node]
                for s in self.pipelines[replica]]

    def region_groups(self) -> Dict[str, List[int]]:
        """region → replicas whose stage-0 device sits there (local-SGD's
        replica→region mapping for hierarchical sync)."""
        groups: Dict[str, List[int]] = {}
        for r, pipe in enumerate(self.pipelines):
            groups.setdefault(
                self.topology.device_region[pipe[0].node], []).append(r)
        return groups

    def canonical_key(self) -> tuple:
        """Hashable identity of the *placement itself* — per-replica
        (node, layer-range) tuples plus any sync-group overrides.  Two
        candidate specs with the same key price identically, which is
        what the search memoizes on (different orderings frequently
        carve into the same grid)."""
        return (
            tuple(tuple((s.node, s.layers.start, s.layers.stop)
                        for s in pipe) for pipe in self.pipelines),
            tuple(tuple(g) for g in self.dp_sync_nodes),
        )

    def cross_region_edges(self) -> int:
        """Stage boundaries whose two devices sit in different regions,
        summed over replicas — each one puts activations on the WAN."""
        n = 0
        reg = self.topology.device_region
        for pipe in self.pipelines:
            for a, b in zip(pipe[:-1], pipe[1:]):
                if reg[a.node] != reg[b.node]:
                    n += 1
        return n

    # ---------------------------------------------------------- checking
    def validate(self) -> "PlacementSpec":
        """Raise ValueError unless the spec is a well-formed placement."""
        if not self.pipelines or not self.pipelines[0]:
            raise ValueError("placement has no pipeline stages")
        S = self.num_stages
        ref = [(s.layers.start, s.layers.stop) for s in self.pipelines[0]]
        for r, pipe in enumerate(self.pipelines):
            if len(pipe) != S:
                raise ValueError(
                    f"replica {r} has {len(pipe)} stages, replica 0 has {S}")
            spans = [(s.layers.start, s.layers.stop) for s in pipe]
            if spans != ref:
                raise ValueError(
                    f"replica {r} layer boundaries {spans} differ from "
                    f"replica 0's {ref}; DP shards would not line up")
            for s in pipe:
                if s.node not in self.topology.device_region:
                    raise ValueError(
                        f"stage node {s.node!r} is not in the topology")
                if len(s.layers) == 0:
                    raise ValueError(
                        f"replica {r} has an empty stage at {s.layers}; "
                        "drop idle devices instead")
        cover = [x for st, sp in ref for x in range(st, sp)]
        if cover != list(range(self.num_layers)):
            raise ValueError(
                f"stage layers {ref} do not tile 0..{self.num_layers} "
                "contiguously")
        nodes = [s.node for pipe in self.pipelines for s in pipe]
        if len(set(nodes)) != len(nodes):
            raise ValueError("a topology node holds more than one stage")
        if self.dp_sync_nodes:
            if len(self.dp_sync_nodes) != S:
                raise ValueError(
                    f"dp_sync_nodes covers {len(self.dp_sync_nodes)} "
                    f"stage slots, placement has {S}")
            for i, group in enumerate(self.dp_sync_nodes):
                if len(group) != self.data_parallel:
                    raise ValueError(
                        f"dp_sync_nodes[{i}] has {len(group)} nodes for "
                        f"{self.data_parallel} replicas")
                for n in group:
                    if n not in self.topology.device_region:
                        raise ValueError(
                            f"sync node {n!r} is not in the topology")
        return self

    def describe(self) -> str:
        reg = self.topology.device_region
        lines = [f"placement[{self.strategy}] {self.model}: "
                 f"{self.data_parallel} replicas x {self.num_stages} "
                 f"stages, boundaries {self.boundaries}"]
        for r, pipe in enumerate(self.pipelines):
            parts = [f"L{s.layers.start}-{s.layers.stop}:"
                     f"{s.device.name}@{reg[s.node]}" for s in pipe]
            lines.append(f"  r{r}: " + "  ".join(parts))
        if self.idle_nodes:
            lines.append(f"  idle: {', '.join(self.idle_nodes)}")
        return "\n".join(lines)
