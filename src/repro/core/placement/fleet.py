"""Hierarchical placement search over :class:`FleetArrays`.

``search_placement`` prices every candidate with the full DT-FM cost
model over the dict topology — fine at tens of devices, hopeless at
10⁵.  This module makes fleet-scale search tractable in two moves:

1. **Vectorized exact pricing** (:func:`price_fleet_grid`): a candidate
   is a ``(dp, S)`` grid of fleet rows; makespan, stage-boundary
   activations, and DP gradient sync (via
   :func:`~repro.core.net.collectives.batched_sync_cost`) are priced as
   array ops with loops only over *stages*, bit-identical to
   ``dtfm.plan_placement`` on the equivalent ``PlacementSpec``.
2. **Hierarchical candidate ranking** (:func:`search_placement_fleet`):
   candidates are first scored on O(regions) summaries — used-device
   bottleneck FLOP/s from per-region prefix minima, cross-region edge
   counts from region block boundaries — and only the top few survivors
   (plus the round-robin baseline and caller order, always) get the
   exact device-level pricing.  Search cost scales with the number of
   regions, not the number of devices.

The exact pricing key matches the scalar search: minimize
``(step_time_s, wan_bytes_per_step, cross_region_edges)``.
"""

from __future__ import annotations

import itertools
import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import flops as F
from repro.core.net.collectives import batched_sync_cost
from repro.core.net.fleet_arrays import FleetArrays
from repro.core.placement.search import balanced_boundaries
from repro.models.config import ModelConfig


@dataclass
class FleetPlacement:
    """A fleet-rows placement: ``grid[replica, stage]`` is a row into the
    priced :class:`FleetArrays`.  ``to_spec`` materializes the equivalent
    :class:`~repro.core.placement.PlacementSpec` (for parity tests and
    for handing the winner to the executor path)."""
    fleet: FleetArrays
    grid: np.ndarray                      # (dp, S) int64 fleet rows
    boundaries: List[int]                 # length S+1
    idle: np.ndarray                      # fleet rows left out
    strategy: str
    step_time_s: float
    wan_bytes_per_step: float
    wire_bytes_per_step: float
    cross_region_edges: int
    search_stats: Dict[str, float] = field(default_factory=dict)

    @property
    def data_parallel(self) -> int:
        return int(self.grid.shape[0])

    @property
    def num_stages(self) -> int:
        return int(self.grid.shape[1])

    def price_key(self) -> Tuple[float, float, int]:
        return (self.step_time_s, self.wan_bytes_per_step,
                self.cross_region_edges)

    def to_spec(self, cfg: ModelConfig, topology=None):
        from repro.core.placement.spec import (PlacementSpec,
                                               StagePlacement)
        from repro.core.net.fleet_arrays import _spec_for_row
        topo = topology if topology is not None \
            else self.fleet.to_topology()
        b = self.boundaries
        pipelines = []
        for row in self.grid:
            pipelines.append([
                StagePlacement(_spec_for_row(self.fleet, int(r)),
                               str(self.fleet.node_names[int(r)]),
                               range(b[i], b[i + 1]))
                for i, r in enumerate(row)])
        spec = PlacementSpec(cfg.name, cfg.num_layers, pipelines, topo,
                             strategy=self.strategy,
                             idle_nodes=[str(self.fleet.node_names[i])
                                         for i in self.idle])
        spec.search_stats = dict(self.search_stats)
        return spec.validate()


def price_fleet_grid(fleet: FleetArrays, cfg: ModelConfig,
                     grid: np.ndarray, *, batch: int, seq_len: int,
                     microbatches: int = 8, train: bool = True,
                     collective: str = "hierarchical", compress=None,
                     sync_interval: int = 1,
                     idle: Optional[np.ndarray] = None,
                     strategy: str = "grid") -> FleetPlacement:
    """Exact DT-FM pricing of a ``(dp, S)`` fleet-row grid.

    Replays ``dtfm.plan_placement``'s op sequence with loops only over
    stage slots: same balanced boundaries (empty slots dropped to idle),
    same GPipe makespan, same per-replica boundary folds, same batched
    collective sync — bit-identical ``step_time_s`` / ``wan_bytes`` /
    ``cross_region_edges``.
    """
    grid = np.asarray(grid, dtype=np.int64)
    dp, _ = grid.shape
    if dp > batch:
        raise ValueError(f"data_parallel={dp} exceeds batch={batch}")
    eff_all = fleet.eff_flops[grid]
    weights = [float(w) for w in np.minimum.reduce(eff_all, axis=0)]
    bounds = balanced_boundaries(cfg.num_layers, weights)
    lens = np.diff(np.asarray(bounds, dtype=np.int64))
    kept = lens > 0
    dropped = grid[:, ~kept].ravel()
    idle = dropped if idle is None \
        else np.concatenate([np.asarray(idle, np.int64), dropped])
    grid = grid[:, kept]
    lens_k = lens[kept]
    S = grid.shape[1]
    bounds = [0] + list(np.cumsum(lens_k).astype(int))

    total_flops = F.train_flops(cfg, batch // dp, seq_len, remat=False) \
        if train else F.fwd_flops(cfg, batch // dp, seq_len)
    per_layer = total_flops / cfg.num_layers
    mb = microbatches
    t_mb = ((per_layer * lens_k) / mb) / fleet.eff_flops[grid]
    makespan = (mb + S - 1) * float(t_mb.max())

    # stage-boundary activations: per-replica sequential fold over stage
    # pairs, slowest replica gates; wire/wan accumulate scalar-order
    act_bytes = (batch // dp) * seq_len * cfg.d_model * 2
    directions = 2 if train else 1
    nbytes_mb = act_bytes / mb
    rid = fleet.region_of[grid].astype(np.int64)
    da = fleet.acc_delay[grid]
    abw = fleet.acc_bw[grid]
    wd = fleet.wan_delay[rid]
    wb = fleet.wan_bw[rid]
    t_rep = np.zeros(dp)
    for i in range(S - 1):
        cross = rid[:, i] != rid[:, i + 1]
        delay = np.where(cross,
                         ((da[:, i] + wd[:, i]) + wd[:, i + 1])
                         + da[:, i + 1],
                         da[:, i] + da[:, i + 1])
        bw = np.where(cross,
                      np.minimum(np.minimum(abw[:, i], wb[:, i]),
                                 np.minimum(wb[:, i + 1], abw[:, i + 1])),
                      np.minimum(abw[:, i], abw[:, i + 1]))
        t_rep = t_rep + (directions * mb) * (delay + nbytes_mb / bw)
    boundary_s = float(t_rep.max()) if S > 1 and dp else 0.0
    boundary_s = max(0.0, boundary_s)
    cross_all = rid[:, :-1] != rid[:, 1:]
    cross_edges = int(cross_all.sum())
    v = float(directions * act_bytes)
    n_pairs = dp * (S - 1)
    boundary_wire = float(np.cumsum(np.full(n_pairs, v))[-1]) \
        if n_pairs else 0.0
    wan_add = np.where(cross_all.ravel(), v, 0.0)
    boundary_wan = float(np.cumsum(wan_add)[-1]) if n_pairs else 0.0

    # DP gradient sync: one batched collective call prices all S slots
    dp_sync_s = 0.0
    dp_wire = 0.0
    dp_wan = 0.0
    if train and dp > 1:
        n_elems_total = F.param_bytes(cfg, 1)
        shards = [int(n_elems_total * int(l) / cfg.num_layers)
                  for l in lens_k]
        c = batched_sync_cost(
            fleet, grid.T.ravel(), np.repeat(np.arange(S), dp),
            np.asarray(shards), algorithm=collective, compress=compress,
            dtype_bytes=2, sync_interval=sync_interval)
        for i in range(S):       # scalar per-slot folds, slot order
            dp_sync_s = max(dp_sync_s, float(c.time_s[i]))
            dp_wire += float(c.wire_bytes[i])
            dp_wan += float(c.wan_bytes[i])
    comm_s = boundary_s + dp_sync_s

    return FleetPlacement(
        fleet=fleet, grid=grid, boundaries=bounds, idle=idle,
        strategy=strategy,
        step_time_s=makespan + comm_s,
        wan_bytes_per_step=boundary_wan + dp_wan,
        wire_bytes_per_step=boundary_wire + dp_wire,
        cross_region_edges=cross_edges)


# ------------------------------------------------------------------ search

def _region_tables(fleet: FleetArrays):
    """Per-region device rows sorted fast-first (the scalar search's
    within-region order: (-effective_flops, node_name))."""
    tables = {}
    for g in range(fleet.num_regions):
        rows = np.flatnonzero(fleet.region_of == g)
        if rows.shape[0] == 0:
            continue
        order = np.lexsort((fleet.node_names[rows],
                            -fleet.eff_flops[rows]))
        rows = rows[order]
        tables[g] = (rows, np.minimum.accumulate(fleet.eff_flops[rows]))
    return tables


def _proxy_score(fleet: FleetArrays, perm: Sequence[int], tables,
                 dp: int, cfg: ModelConfig, batch: int, seq_len: int,
                 microbatches: int) -> Tuple[float, int]:
    """O(regions) candidate score: estimated gated stage time from the
    used-device bottleneck FLOP/s + cross-region edge count from region
    block boundaries.  Ranks candidates only — winners are re-priced
    exactly, and the round-robin/caller layouts are always re-priced —
    so a coarse proxy costs recall, never correctness."""
    counts = [tables[g][0].shape[0] for g in perm]
    n = sum(counts)
    S = n // dp
    used = S * dp
    if S == 0:
        return (np.inf, 0)
    # bottleneck = min over regions of each region's used-prefix min
    remaining = used
    bottleneck = np.inf
    starts = []
    pos = 0
    for g, c in zip(perm, counts):
        take = min(c, remaining)
        if take > 0:
            bottleneck = min(bottleneck, float(tables[g][1][take - 1]))
        starts.append(pos)
        pos += c
        remaining -= take
        if remaining <= 0:
            break
    # cross edges: region block starts falling strictly inside a replica
    # row of the contiguous carve (row r spans [r*S, (r+1)*S))
    blocks = np.asarray(starts[1:], dtype=np.int64)
    blocks = blocks[blocks < used]
    interior = blocks[blocks % S != 0].shape[0]
    total_flops = F.train_flops(cfg, batch // dp, seq_len, remat=False)
    t_stage = (total_flops / S) / microbatches / bottleneck
    est = (microbatches + S - 1) * t_stage
    return (est, interior)


def search_placement_fleet(fleet: FleetArrays, cfg: ModelConfig, *,
                           data_parallel: int, batch: int, seq_len: int,
                           microbatches: int = 8, train: bool = True,
                           collective: str = "hierarchical",
                           compress=None, sync_interval: int = 1,
                           refine_top_k: int = 3) -> FleetPlacement:
    """Two-stage topology-aware search over a fleet of any size.

    Stage 1 ranks every region-contiguous candidate ordering on region
    summaries (O(R) each); stage 2 exactly prices the ``refine_top_k``
    survivors plus the round-robin baseline and caller order, returning
    the cheapest by ``(step_time, wan_bytes, cross_region_edges)``.
    ``search_stats`` records how many candidates the ranking pruned.
    """
    t0 = _time.perf_counter()
    dp = data_parallel
    N = fleet.num_devices
    if N < dp:
        raise ValueError(f"{N} devices cannot host {dp} pipelines")
    tables = _region_tables(fleet)
    regions = sorted(tables)
    if len(regions) <= 4:
        perms = list(itertools.permutations(regions))
    else:
        cap = {g: float(fleet.eff_flops[tables[g][0]].sum())
               for g in regions}
        perms = [tuple(sorted(regions, key=lambda g: -cap[g])),
                 tuple(regions)]

    scored = sorted(
        (( _proxy_score(fleet, perm, tables, dp, cfg, batch, seq_len,
                        microbatches), perm) for perm in perms),
        key=lambda t: t[0])
    survivors = [perm for _, perm in scored[:max(1, refine_top_k)]]
    pruned = len(perms) - len(survivors)

    def carve(order: np.ndarray, contiguous: bool) -> Tuple[np.ndarray,
                                                            np.ndarray]:
        S = order.shape[0] // dp
        used, rest = order[:S * dp], order[S * dp:]
        g = used.reshape(dp, S) if contiguous \
            else used.reshape(S, dp).T
        return g, rest

    candidates: List[FleetPlacement] = []

    def price(order, contiguous, tag):
        g, rest = carve(np.asarray(order, np.int64), contiguous)
        candidates.append(price_fleet_grid(
            fleet, cfg, g, batch=batch, seq_len=seq_len,
            microbatches=microbatches, train=train,
            collective=collective, compress=compress,
            sync_interval=sync_interval, idle=rest, strategy=tag))

    price(np.arange(N), False, "round_robin")      # baseline, always
    price(np.arange(N), True, "caller")            # caller order, always
    for perm in survivors:
        order = np.concatenate([tables[g][0] for g in perm])
        names = ">".join(str(fleet.regions[g]) for g in perm)
        price(order, True, f"regions:{names}")

    best = min(candidates, key=FleetPlacement.price_key)
    rr = candidates[0]
    best.strategy = f"topology_aware({best.strategy})"
    best.search_stats = {
        "candidates_total": len(perms) + 2,
        "candidates_priced": len(candidates),
        "candidates_pruned": pruned,
        "round_robin_step_time_s": rr.step_time_s,
        "round_robin_wan_bytes": rr.wan_bytes_per_step,
        "search_wall_s": _time.perf_counter() - t0,
    }
    return best
