"""Topology-aware placement: the contract between planning and execution.

A :class:`PlacementSpec` says, for every data-parallel replica, which
device holds which pipeline stage, where that device sits in the
wide-area :class:`~repro.core.net.Topology`, and which **non-uniform**
contiguous layer range each stage owns.  The DT-FM planner *searches*
over placements and prices them (:mod:`repro.core.planner.dtfm`), the
shard_map pipeline executes exactly the spec's stage boundaries
(:mod:`repro.distributed.pipeline`), the orchestrator replans through
the same search on churn, and local-SGD maps replicas onto the spec's
region groups — one plan, priced and run.
"""

from repro.core.placement.spec import PlacementSpec, StagePlacement
from repro.core.placement.search import (balanced_boundaries,
                                         ordered_placement,
                                         round_robin_placement,
                                         search_placement)
from repro.core.placement.fleet import (FleetPlacement, price_fleet_grid,
                                        search_placement_fleet)

__all__ = [
    "PlacementSpec", "StagePlacement",
    "balanced_boundaries", "ordered_placement", "round_robin_placement",
    "search_placement",
    "FleetPlacement", "price_fleet_grid", "search_placement_fleet",
]
