"""Placement construction and topology-aware search.

Three ways to turn a fleet into a :class:`PlacementSpec`:

* :func:`ordered_placement` — caller order, one pipeline of the given
  devices with ``data_parallel`` analytic clone replicas (the legacy
  :func:`repro.core.planner.dtfm.plan` contract, kept as the
  backward-compatible path).
* :func:`round_robin_placement` — the naive fleet carve-up: device ``j``
  goes to replica ``j % dp``, stage ``j // dp``, regions ignored.  This
  is the baseline topology-aware search must beat.
* :func:`search_placement` — enumerate region-aware candidate layouts
  (regions kept contiguous along each pipeline so stage-boundary
  activations ride intra-region links; replicas carved region-first so
  DP sync crosses the WAN O(regions) times; fast devices aligned across
  replicas so the slot minimum gates least), price every candidate with
  the DT-FM cost model, and return the cheapest.  The round-robin and
  caller-order layouts are always in the candidate set, so the search
  never returns something worse than either.

Layer boundaries are **non-uniform**: proportional to the slowest
replica's effective FLOP/s in each stage slot
(:func:`balanced_boundaries`), which balances per-stage time under
heterogeneous compute.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.energy.devices import DeviceSpec
from repro.core.net import Topology
from repro.core.placement.spec import PlacementSpec, StagePlacement
from repro.models.config import ModelConfig

# (device, node) pairs arranged as grid[replica][stage_slot]
_Grid = List[List[Tuple[DeviceSpec, str]]]


def balanced_boundaries(num_layers: int, weights: Sequence[float]
                        ) -> List[int]:
    """Contiguous boundaries (len ``len(weights)+1``) ∝ per-slot weight.

    Monotone and clamped to [prev, L]: more slots than layers yields
    EMPTY slots rather than phantom layers (the caller drops them).
    """
    total = sum(weights)
    bounds = [0]
    acc = 0.0
    for w in weights[:-1]:
        acc += w
        bounds.append(min(max(round(num_layers * acc / total),
                              bounds[-1]), num_layers))
    bounds.append(num_layers)
    return bounds


def _spec_from_grid(cfg: ModelConfig, grid: _Grid, topology: Topology,
                    strategy: str, idle: Optional[List[str]] = None,
                    dp_sync_nodes: Optional[List[List[str]]] = None
                    ) -> PlacementSpec:
    """Shared boundaries over the grid, empty slots dropped everywhere."""
    slots = len(grid[0])
    # the slowest replica in a slot gates synchronous DP: weight by min
    weights = [min(grid[r][i][0].effective_flops
                   for r in range(len(grid))) for i in range(slots)]
    bounds = balanced_boundaries(cfg.num_layers, weights)
    idle = list(idle or [])
    kept = [i for i in range(slots) if bounds[i + 1] > bounds[i]]
    pipelines: List[List[StagePlacement]] = []
    for row in grid:
        pipe = []
        for i, (dev, node) in enumerate(row):
            rng = range(bounds[i], bounds[i + 1])
            if len(rng) == 0:
                if node not in idle:
                    idle.append(node)       # idle device: no pipeline stage
                continue
            pipe.append(StagePlacement(dev, node, rng))
        pipelines.append(pipe)
    sync = [dp_sync_nodes[i] for i in kept] if dp_sync_nodes else []
    return PlacementSpec(cfg.name, cfg.num_layers, pipelines, topology,
                         strategy=strategy, idle_nodes=idle,
                         dp_sync_nodes=sync).validate()


# --------------------------------------------------------------------------- #
# Legacy single-pipeline path (dtfm.plan's contract)
# --------------------------------------------------------------------------- #

def _extend_for_dp(topology: Topology, devices: Sequence[DeviceSpec],
                   nodes: Sequence[str], data_parallel: int,
                   dp_regions: Optional[Sequence[str]]
                   ) -> Tuple[Topology, _Grid, List[List[str]]]:
    """ONE extended topology holding every replica's clone nodes — this
    replaces the old per-stage ``Topology.from_specs`` clone graphs with
    a single reuse of the existing nodes and links.

    Each clone pipeline mirrors the REAL nodes' regions, so boundary
    activations are priced over the same intra/cross-region structure
    the caller's topology describes.  ``dp_regions`` keeps its legacy
    meaning — it spreads the *gradient-sync* replicas across regions —
    via per-slot sync clone nodes (replica ``r`` syncing from
    ``dp_regions[r % len(dp_regions)]``) returned as the
    ``dp_sync_nodes`` override.
    """
    ext = Topology(links=dict(topology.links),
                   device_region=dict(topology.device_region),
                   device_spec=dict(topology.device_spec),
                   params=topology.params)
    grid: _Grid = []
    for r in range(data_parallel):
        row = []
        for dev, node in zip(devices, nodes):
            cid = f"dp{r}:{node}"
            ext.add_device(cid, topology.device_region[node], dev,
                           bw_Bps=topology.access_bw_Bps(node))
            row.append((dev, cid))
        grid.append(row)
    sync_nodes: List[List[str]] = []
    if dp_regions:
        for i, (dev, node) in enumerate(zip(devices, nodes)):
            group = []
            for r in range(data_parallel):
                sid = f"dpsync{r}:{node}"
                ext.add_device(sid, dp_regions[r % len(dp_regions)], dev,
                               bw_Bps=topology.access_bw_Bps(node))
                group.append(sid)
            sync_nodes.append(group)
    return ext, grid, sync_nodes


def ordered_placement(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
                      topology: Optional[Topology] = None,
                      nodes: Optional[Sequence[str]] = None,
                      data_parallel: int = 1,
                      dp_regions: Optional[Sequence[str]] = None,
                      strategy: str = "ordered") -> PlacementSpec:
    """Caller-order pipeline of ``devices``; ``data_parallel`` clones.

    ``topology``/``nodes`` place the devices in an existing wide-area
    graph; omitted, a single-region topology is synthesized.  With
    ``data_parallel > 1`` each replica is an analytic clone pipeline,
    grouped into ``dp_regions`` for gradient-sync pricing.
    """
    if topology is None:
        topology = Topology.from_specs(devices)
        nodes = [str(i) for i in range(len(devices))]
    if nodes is None:
        raise ValueError("an explicit topology needs nodes= mapping each "
                         "device to its topology node id")
    if data_parallel == 1:
        grid: _Grid = [list(zip(devices, nodes))]
        topo = topology
        sync: List[List[str]] = []
    else:
        topo, grid, sync = _extend_for_dp(topology, devices, nodes,
                                          data_parallel, dp_regions)
    return _spec_from_grid(cfg, grid, topo, strategy,
                           dp_sync_nodes=sync or None)


# --------------------------------------------------------------------------- #
# Fleet carve-ups: round-robin baseline + topology-aware search
# --------------------------------------------------------------------------- #

def _carve(devices: Sequence[DeviceSpec], nodes: Sequence[str],
           order: Sequence[int], data_parallel: int, contiguous: bool
           ) -> Tuple[_Grid, List[str]]:
    """Split ``order`` (indices into devices) into dp pipelines of equal
    length; the remainder idles.  ``contiguous``: pipeline r is a block
    of S consecutive entries; else round-robin (entry j → pipeline
    j % dp)."""
    S = len(order) // data_parallel
    used = order[:S * data_parallel]
    idle = [nodes[i] for i in order[S * data_parallel:]]
    grid: _Grid = []
    for r in range(data_parallel):
        if contiguous:
            idx = used[r * S:(r + 1) * S]
        else:
            idx = used[r::data_parallel]
        grid.append([(devices[i], nodes[i]) for i in idx])
    return grid, idle


def round_robin_placement(cfg: ModelConfig, devices: Sequence[DeviceSpec],
                          *, topology: Topology, nodes: Sequence[str],
                          data_parallel: int = 1) -> PlacementSpec:
    """The naive baseline: caller order, device ``j`` → replica
    ``j % dp``, stage ``j // dp`` — blind to regions.  Depending on how
    the arrival order interleaves regions, that puts stage boundaries on
    the WAN, or (when dp happens to match the interleave stride) lands
    every DP gradient-sync group across regions instead; either way it
    pays WAN costs the search can avoid or trade off deliberately."""
    if len(devices) < data_parallel:
        raise ValueError(f"{len(devices)} devices cannot host "
                         f"{data_parallel} pipelines")
    grid, idle = _carve(devices, nodes, list(range(len(devices))),
                        data_parallel, contiguous=False)
    return _spec_from_grid(cfg, grid, topology, "round_robin", idle)


def _candidate_orders(devices: Sequence[DeviceSpec], nodes: Sequence[str],
                      topology: Topology) -> List[Tuple[str, List[int]]]:
    """Device orderings to evaluate: caller order + region-contiguous
    orders (fast devices first within a region, regions permuted)."""
    cands: List[Tuple[str, List[int]]] = [
        ("caller", list(range(len(devices))))]
    by_region: Dict[str, List[int]] = {}
    for i, n in enumerate(nodes):
        by_region.setdefault(topology.device_region[n], []).append(i)
    for ids in by_region.values():
        ids.sort(key=lambda i: (-devices[i].effective_flops, nodes[i]))
    regions = sorted(by_region)
    if len(regions) <= 4:
        perms = list(itertools.permutations(regions))
    else:
        # too many to enumerate: biggest-capacity-first + name order
        cap = {g: sum(devices[i].effective_flops for i in by_region[g])
               for g in regions}
        perms = [tuple(sorted(regions, key=lambda g: -cap[g])),
                 tuple(regions)]
    for perm in perms:
        order = [i for g in perm for i in by_region[g]]
        cands.append((f"regions:{'>'.join(perm)}", order))
    return cands


def search_placement(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
                     topology: Topology, nodes: Sequence[str],
                     data_parallel: int = 1, batch: int, seq_len: int,
                     microbatches: int = 8, train: bool = True,
                     collective: str = "hierarchical",
                     compress=None, sync_interval: int = 1
                     ) -> PlacementSpec:
    """Topology-aware placement: price candidate layouts with the DT-FM
    cost model and return the cheapest (step time, then WAN bytes).

    The round-robin and caller-order layouts are always candidates, so
    the result never prices worse than either on the same fleet.

    Pricing is memoized on each candidate's
    :meth:`~repro.core.placement.spec.PlacementSpec.canonical_key`:
    different orderings frequently carve into the same (node, layers)
    grid, and re-running the DT-FM model on them is pure waste.  The
    winning spec's ``search_stats`` reports ``candidates_pruned`` (the
    memo hits) alongside the totals.
    """
    import time as _time
    from repro.core.planner import dtfm       # deferred: dtfm imports us
    t0 = _time.perf_counter()
    if len(devices) != len(nodes):
        raise ValueError(f"{len(devices)} devices vs {len(nodes)} nodes")
    if len(devices) < data_parallel:
        raise ValueError(f"{len(devices)} devices cannot host "
                         f"{data_parallel} pipelines")

    specs: List[PlacementSpec] = [
        round_robin_placement(cfg, devices, topology=topology, nodes=nodes,
                              data_parallel=data_parallel)]
    for tag, order in _candidate_orders(devices, nodes, topology):
        grid, idle = _carve(devices, nodes, order, data_parallel,
                            contiguous=True)
        specs.append(_spec_from_grid(cfg, grid, topology, tag, idle))

    memo: Dict[tuple, tuple] = {}

    def price(spec: PlacementSpec):
        key = spec.canonical_key()
        if key not in memo:
            p = dtfm.plan_placement(cfg, spec, batch=batch,
                                    seq_len=seq_len,
                                    microbatches=microbatches,
                                    train=train, collective=collective,
                                    compress=compress,
                                    sync_interval=sync_interval)
            memo[key] = (p.step_time_s, p.wan_bytes_per_step,
                         spec.cross_region_edges())
        return memo[key]

    best = min(specs, key=price)
    best.strategy = f"topology_aware({best.strategy})"
    best.search_stats = {
        "candidates_total": len(specs),
        "candidates_priced": len(memo),
        "candidates_pruned": len(specs) - len(memo),
        "search_wall_s": _time.perf_counter() - t0,
    }
    return best
