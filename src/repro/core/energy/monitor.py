"""Component-level energy monitoring (§5: "holistic, reliable and efficient
energy consumption monitoring").

The paper's proposed direction: accurate component-level energy MODELS that
infer fine-grained consumption, with coarse-grained measurements used for
periodic CALIBRATION.  Implemented here:

* ``ComponentModel`` — per-op energy from first principles: compute J/FLOP,
  memory J/byte, network J/byte (the 0.001 kWh/GB WAN / 0.02 kWh/GB local
  figures from §5 are the defaults),
* ``EnergyMonitor``  — accumulates per-component estimates per training
  step and recalibrates a global scale factor whenever a coarse measurement
  (e.g. a battery/wall-meter reading) arrives — the calibration loop the
  paper asks for.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs.trace import get_tracer

# §5: WAN transmission ~0.001 kWh/GB; "local computation >= 0.02 kWh/GB"
# (the paper's per-GB *processing* figure, used for comm-vs-compute
# trade-offs, NOT a memory-access energy).  Memory access itself is
# ~100-150 pJ/byte on LPDDR5/HBM — the component model uses that.
WAN_J_PER_BYTE = 0.001 * 3.6e6 / 1e9        # 3.6e-6 J/B
LOCAL_PROCESS_J_PER_BYTE = 0.02 * 3.6e6 / 1e9
MEM_J_PER_BYTE = 1.2e-10                    # ~120 pJ/byte (LPDDR5 class)


@dataclass(frozen=True)
class ComponentModel:
    compute_j_per_flop: float          # P_active / effective_flops
    hbm_j_per_byte: float = MEM_J_PER_BYTE
    net_j_per_byte: float = WAN_J_PER_BYTE
    static_w: float = 0.5              # always-on rail

    @classmethod
    def for_device(cls, device) -> "ComponentModel":
        return cls(compute_j_per_flop=device.power_active_w
                   / device.effective_flops,
                   static_w=device.power_idle_w)


@dataclass
class StepSample:
    flops: float
    hbm_bytes: float
    net_bytes: float
    duration_s: float


@dataclass
class EnergyMonitor:
    model: ComponentModel
    scale: float = 1.0                 # calibration factor
    samples: List[StepSample] = field(default_factory=list)
    estimates_j: List[float] = field(default_factory=list)
    # unscaled per-step estimates: ``estimates_j[i] == raw_j[i] * scale``
    # holds at all times (calibrate rescales every entry), so totals and
    # breakdowns never mix pre-/post-calibration scales
    raw_j: List[float] = field(default_factory=list)

    def record_step(self, *, flops: float, hbm_bytes: float = 0.0,
                    net_bytes: float = 0.0, duration_s: float = 0.0
                    ) -> float:
        """Returns the (calibrated) energy estimate for this step, J."""
        m = self.model
        raw = (flops * m.compute_j_per_flop
               + hbm_bytes * m.hbm_j_per_byte
               + net_bytes * m.net_j_per_byte
               + duration_s * m.static_w)
        e = raw * self.scale
        self.samples.append(StepSample(flops, hbm_bytes, net_bytes,
                                       duration_s))
        self.raw_j.append(raw)
        self.estimates_j.append(e)
        # attach the attribution to whatever phase span is open (trainer
        # step, engine step, sync round) — J lands on the timeline
        get_tracer().annotate(energy_j=e)
        return e

    def calibrate(self, measured_j: float, window: int = 0) -> float:
        """Align the model to a coarse measurement (battery/wall meter)
        over the last ``window`` steps (0 = all), then rescale EVERY
        recorded estimate to the new scale so ``total_j`` /
        ``breakdown_j`` stay on one consistent scale.  The new scale
        divides the window's *unscaled* raw estimates (each entry's raw
        is its estimate divided by the scale in effect when it was
        recorded), so repeated calibrations don't compound.  Returns the
        new scale factor."""
        raw = self.raw_j[-window:] if window else self.raw_j
        if not raw or sum(raw) == 0:
            return self.scale
        self.scale = measured_j / sum(raw)
        self.estimates_j = [r * self.scale for r in self.raw_j]
        return self.scale

    def reset(self) -> None:
        """Drop recorded samples/estimates; calibration scale persists."""
        self.samples.clear()
        self.estimates_j.clear()
        self.raw_j.clear()

    @property
    def total_j(self) -> float:
        return sum(self.estimates_j)

    @property
    def total_wh(self) -> float:
        return self.total_j / 3600.0

    def breakdown_j(self) -> Dict[str, float]:
        m = self.model
        out = {"compute": 0.0, "memory": 0.0, "network": 0.0, "static": 0.0}
        for s in self.samples:
            out["compute"] += s.flops * m.compute_j_per_flop * self.scale
            out["memory"] += s.hbm_bytes * m.hbm_j_per_byte * self.scale
            out["network"] += s.net_bytes * m.net_j_per_byte * self.scale
            out["static"] += s.duration_s * m.static_w * self.scale
        return out
