"""Device catalog — the analytic edge/cloud substrate (DESIGN.md §2).

Every constant cites its source.  MFU values are calibrated ONCE against the
paper's Table 1 wall-times (OPT-125m, 100 steps, batch 16, seq 512) and then
held fixed for every other reproduction (Tables 2, Figs 3-5) — the same
discipline the paper applies.

Calibration arithmetic (Table 1):
  model flops  = 6 · 125.2e6 · (16·512·100)  =  6.16e14
  smartphone   : 3510 s  ->  1.76e11 FLOP/s effective
  laptop       : 480 s   ->  1.28e12 FLOP/s effective
  cloud GPU    : 250 s   ->  2.46e12 FLOP/s effective
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    kind: str                      # smartphone | laptop | cloud_gpu | tpu
    peak_flops: float              # fp16/bf16 peak, FLOP/s
    mfu: float                     # calibrated model-flops utilization
    power_active_w: float          # package power under training load
    power_idle_w: float            # baseline draw while waiting/stalled
    power_comm_w: float            # network module power (WiFi ~0.5 W [82])
    mem_gb: float
    net_bw_Bps: float              # symmetric network bandwidth, bytes/s
    embodied_kgco2e: float         # manufacturing+transport+EoL
    lifetime_years: float          # replacement cycle
    hbm_bw_Bps: float = 0.0        # accelerator memory bandwidth
    link_bw_Bps: float = 0.0       # interconnect per link (cloud)
    power_typical_w: float = 0.0   # draw under *typical user* load (Fig. 4);
                                   # 0 -> falls back to power_active_w
    source: str = ""

    @property
    def typical_power_w(self) -> float:
        return self.power_typical_w or self.power_active_w

    @property
    def effective_flops(self) -> float:
        return self.peak_flops * self.mfu


# ----------------------------------------------------------------------- #
# The paper's three measured devices (Table 1) + Fig. 4/5 carbon devices
# ----------------------------------------------------------------------- #

SMARTPHONE_SD888 = DeviceSpec(
    name="smartphone-sd888", kind="smartphone",
    peak_flops=1.5e12,             # Adreno 660 fp16 ~1.49 TFLOPS (vendor)
    mfu=0.117,                     # calibrated: 1.76e11 / 1.5e12
    power_active_w=10.0,           # paper Table 1
    power_idle_w=0.2,              # race-to-sleep between microbatches
    power_comm_w=0.5,              # WiFi module [82]
    mem_gb=8.0,
    net_bw_Bps=10e6,               # paper §4.2: 10 MB/s symmetric
    embodied_kgco2e=56.0,          # iPhone 15 Pro PER [10]: ~66 kg, >80% emb.
    lifetime_years=3.0,
    power_typical_w=3.0,           # interactive use (web/video), not training
    source="paper Table 1 + Apple PER [10] + [82]",
)

LAPTOP_M2PRO = DeviceSpec(
    name="laptop-m2pro", kind="laptop",
    peak_flops=6.8e12,             # M2 Pro 19-core GPU fp16 ~6.8 TFLOPS
    mfu=0.189,                     # calibrated: 1.28e12 / 6.8e12
    power_active_w=15.0,           # paper Table 1
    power_idle_w=3.0,
    power_comm_w=0.5,
    mem_gb=16.0,
    net_bw_Bps=10e6,
    embodied_kgco2e=223.0,         # 16" MacBook Pro PER [9]: 290 kg, ~77% emb.
    lifetime_years=3.0,
    source="paper Table 1 + Apple PER [9]",
)

CLOUD_A5000 = DeviceSpec(
    name="cloud-a5000", kind="cloud_gpu",
    peak_flops=27.8e12,            # A5000 fp16 tensor (dense)
    mfu=0.0886,                    # calibrated: 2.46e12 / 27.8e12
    power_active_w=220.0,          # paper Table 1
    power_idle_w=52.0,
    power_comm_w=0.0,              # NIC power folded into server overhead
    mem_gb=24.0,
    net_bw_Bps=3.125e9,            # 25 GbE
    embodied_kgco2e=150.0,         # MLCO2-style server/8 share
    lifetime_years=3.0,
    hbm_bw_Bps=768e9,
    link_bw_Bps=8e9,
    source="paper Table 1 + MLCO2 [53]",
)

CLOUD_H100 = DeviceSpec(
    name="cloud-h100", kind="cloud_gpu",
    peak_flops=267e12,             # paper §4.2 quotes 267 TFLOPS FP16
    mfu=0.35,                      # typical large-scale training MFU
    power_active_w=700.0,
    power_idle_w=100.0,
    power_comm_w=0.0,
    mem_gb=80.0,
    net_bw_Bps=50e9,
    embodied_kgco2e=960.0,         # 1/8 of a ~7.7 t GPU server [67]
    lifetime_years=3.0,
    hbm_bw_Bps=3.35e12,
    link_bw_Bps=450e9,
    source="paper §4.2 (Figs 4-5) + [67]",
)

TPU_V5E = DeviceSpec(
    name="tpu-v5e", kind="tpu",
    peak_flops=197e12,             # bf16 (assignment constants)
    mfu=0.5,
    power_active_w=170.0,          # chip+share of host, typical
    power_idle_w=60.0,
    power_comm_w=0.0,
    mem_gb=16.0,
    net_bw_Bps=50e9,
    embodied_kgco2e=700.0,
    lifetime_years=3.0,
    hbm_bw_Bps=819e9,              # assignment constants
    link_bw_Bps=50e9,              # ICI per link
    source="assignment hardware constants",
)

CATALOG: Dict[str, DeviceSpec] = {d.name: d for d in [
    SMARTPHONE_SD888, LAPTOP_M2PRO, CLOUD_A5000, CLOUD_H100, TPU_V5E]}


def get_device(name: str) -> DeviceSpec:
    return CATALOG[name]


def train_time_s(device: DeviceSpec, flops: float) -> float:
    return flops / device.effective_flops


def train_energy_wh(device: DeviceSpec, flops: float) -> float:
    """Single-device training energy (paper Table 1 reproduction)."""
    return device.power_active_w * train_time_s(device, flops) / 3600.0
