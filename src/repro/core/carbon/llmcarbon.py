"""LLMCarbon-style end-to-end footprint estimates (paper Fig. 2).

For each foundation model: training compute (PFLOP/s-days) from 6·N·D and
the resulting tCO2e on an H100-class cluster, following the MLCO2/LLMCarbon
methodology the paper uses where official numbers are unavailable.
Models/data from the papers cited in Fig. 2 [18, 22, 65, 69, 70, 84].
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.carbon.accounting import DATACENTER_PUE
from repro.core.carbon.intensity import paper_average_intensity
from repro.core.energy.devices import CLOUD_H100


@dataclass(frozen=True)
class TrainedModel:
    name: str
    params: float                  # N
    tokens: float                  # D
    mmlu: Optional[float] = None   # post-training accuracy (Fig. 2a)
    reported_tco2e: Optional[float] = None   # from the model's own paper
    mfu: Optional[float] = None    # training MFU disclosed by the model paper
    grid_intensity: Optional[float] = None   # kgCO2e/kWh disclosed by paper
    source: str = ""


# Fig. 2 model range (public numbers)
FIG2_MODELS = [
    TrainedModel("xlm-r", 0.55e9, 6.3e12, mmlu=0.28, source="XLM-R"),
    TrainedModel("gpt-3", 175e9, 300e9, mmlu=0.439,
                 reported_tco2e=552.0, source="[18] + Patterson et al."),
    TrainedModel("gopher", 280e9, 300e9, mmlu=0.60,
                 reported_tco2e=380.0, source="[69]"),
    TrainedModel("chinchilla", 70e9, 1.4e12, mmlu=0.675, source="[40]"),
    TrainedModel("palm", 540e9, 780e9, mmlu=0.693,
                 reported_tco2e=271.4, mfu=0.462, grid_intensity=0.079,
                 source="[22]: 46.2% MFU, Oklahoma DC clean grid"),
    TrainedModel("llama2-70b", 70e9, 2e12, mmlu=0.689,
                 reported_tco2e=291.4, source="[84]"),
    TrainedModel("gpt-4", 1.8e12, 13e12, mmlu=0.864, source="[65] (est.)"),
]


def train_flops(m: TrainedModel) -> float:
    return 6.0 * m.params * m.tokens


def pflops_day(m: TrainedModel) -> float:
    """Fig. 2a x-axis: PFLOP/s needed to finish training in one day."""
    return train_flops(m) / 86_400.0 / 1e15


def estimated_tco2e(m: TrainedModel, *, mfu: Optional[float] = None,
                    intensity: Optional[float] = None,
                    include_embodied: bool = True) -> float:
    """LLMCarbon-style estimate on an H100 cluster.

    Uses the model paper's own disclosed MFU / grid intensity where
    available (LLMCarbon's convention), catalog defaults otherwise.
    """
    if mfu is None:
        mfu = m.mfu if m.mfu is not None else CLOUD_H100.mfu
    if intensity is None and m.grid_intensity is not None:
        intensity = m.grid_intensity
    ci = paper_average_intensity() if intensity is None else intensity
    gpu_seconds = train_flops(m) / (CLOUD_H100.peak_flops * mfu)
    kwh = gpu_seconds * CLOUD_H100.power_active_w / 3600.0 / 1000.0
    operational = kwh * DATACENTER_PUE * ci
    embodied = 0.0
    if include_embodied:
        gpu_years = gpu_seconds / (3600 * 24 * 365)
        embodied = CLOUD_H100.embodied_kgco2e \
            * gpu_years / CLOUD_H100.lifetime_years
    return (operational + embodied) / 1000.0


def footprint(m: TrainedModel) -> float:
    """Reported number when the model's paper provides one, else estimate."""
    return m.reported_tco2e if m.reported_tco2e else estimated_tco2e(m)


def fig2_table() -> Dict[str, Dict[str, float]]:
    out = {}
    for m in FIG2_MODELS:
        out[m.name] = {
            "params_B": m.params / 1e9,
            "tokens_B": m.tokens / 1e9,
            "pflops_day": pflops_day(m),
            "mmlu": m.mmlu or 0.0,
            "tco2e": footprint(m),
        }
    return out
