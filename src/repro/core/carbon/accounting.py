"""CO2e accounting: embodied amortization + operational energy (§2.2).

``CarbonLedger`` tracks both components for a device or fleet exactly as the
paper decomposes them:

* embodied: manufacturing/transport/EoL, amortized over the device lifetime
  — incurred by ownership, NOT by our workload (the offloading argument's
  crux: using idle devices adds only operational carbon),
* operational: kWh x grid intensity x PUE.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.carbon.intensity import paper_average_intensity
from repro.core.energy.devices import DeviceSpec

EDGE_PUE = 1.0          # no cooling infrastructure at the edge
DATACENTER_PUE = 1.1    # modern hyperscale PUE (paper cites [1, 67])


@dataclass
class CarbonEntry:
    label: str
    embodied_kg: float = 0.0
    operational_kg: float = 0.0

    @property
    def total_kg(self) -> float:
        return self.embodied_kg + self.operational_kg


@dataclass
class CarbonLedger:
    intensity_kg_per_kwh: float = field(default_factory=paper_average_intensity)
    entries: List[CarbonEntry] = field(default_factory=list)

    def add_embodied(self, label: str, device: DeviceSpec,
                     share_of_lifetime: float = 1.0, count: int = 1
                     ) -> CarbonEntry:
        e = CarbonEntry(label,
                        embodied_kg=device.embodied_kgco2e
                        * share_of_lifetime * count)
        self.entries.append(e)
        return e

    def add_operational_kwh(self, label: str, kwh: float,
                            pue: float = EDGE_PUE,
                            intensity: Optional[float] = None) -> CarbonEntry:
        ci = self.intensity_kg_per_kwh if intensity is None else intensity
        e = CarbonEntry(label, operational_kg=kwh * pue * ci)
        self.entries.append(e)
        # gCO2e rides the enclosing span (if any) onto the timeline
        from repro.obs.trace import get_tracer
        get_tracer().annotate(carbon_g=e.operational_kg * 1000.0)
        return e

    def add_operational_wh(self, label: str, wh: float,
                           pue: float = EDGE_PUE,
                           intensity: Optional[float] = None) -> CarbonEntry:
        return self.add_operational_kwh(label, wh / 1000.0, pue, intensity)

    # ------------------------------------------------------------- totals
    @property
    def embodied_kg(self) -> float:
        return sum(e.embodied_kg for e in self.entries)

    @property
    def operational_kg(self) -> float:
        return sum(e.operational_kg for e in self.entries)

    @property
    def total_kg(self) -> float:
        return self.embodied_kg + self.operational_kg

    def breakdown(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for e in self.entries:
            d = out.setdefault(e.label, {"embodied_kg": 0.0,
                                         "operational_kg": 0.0})
            d["embodied_kg"] += e.embodied_kg
            d["operational_kg"] += e.operational_kg
        return out


def device_operational_kwh(device: DeviceSpec, hours_active_per_day: float,
                           years: float, *, baseline_hours: float = 0.0
                           ) -> float:
    """kWh over ``years`` of use: active training hours + baseline use."""
    days = years * 365.0
    return days * (hours_active_per_day * device.power_active_w
                   + baseline_hours * device.power_idle_w) / 1000.0
