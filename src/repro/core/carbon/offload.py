"""Cloud->edge offloading carbon analysis (paper Figs. 4 and 5, §4.2).

Three-step argument, made executable:

1. per-device 3-year footprint breakdown (embodied vs operational),
2. edge-device count for compute equivalence with one cloud GPU
   (peak-FLOPS matching at 8 h/day participation, the paper's convention),
3. net carbon delta of offloading: the cloud GPU's FULL footprint is saved;
   the edge fleet adds only the *marginal operational* carbon (compute +
   communication) because embodied + baseline-use carbon is sunk by
   ownership.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.carbon.accounting import (DATACENTER_PUE, EDGE_PUE,
                                          CarbonLedger)
from repro.core.carbon.intensity import paper_average_intensity
from repro.core.energy.devices import (CLOUD_H100, DeviceSpec, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888)

HOURS_PER_DAY = 8.0            # paper: 8 h daily while charging [8, 11, 67]
YEARS = 3.0                    # replacement cycle across the board


@dataclass(frozen=True)
class DeviceFootprint:
    name: str
    embodied_kg: float
    operational_kg: float

    @property
    def total_kg(self) -> float:
        return self.embodied_kg + self.operational_kg

    @property
    def embodied_pct(self) -> float:
        return 100.0 * self.embodied_kg / self.total_kg


def baseline_footprint(device: DeviceSpec, *, years: float = YEARS,
                       use_hours_per_day: float = 4.5) -> DeviceFootprint:
    """Fig. 4: ownership footprint — embodied + typical-use operational.

    Typical use: smartphones 3-6 h/day [12] -> 4.5 h at *typical-use* power
    (interactive load, not training load; idle draw the rest of the day);
    cloud GPU runs 24/7 at datacenter PUE (its *purpose* is continuous
    service).
    """
    ci = paper_average_intensity()
    if device.kind == "cloud_gpu":
        kwh = device.power_active_w * 24 * 365 * years / 1000.0
        op = kwh * DATACENTER_PUE * ci
    else:
        kwh = (device.typical_power_w * use_hours_per_day
               + device.power_idle_w * (24 - use_hours_per_day)) \
            * 365 * years / 1000.0
        op = kwh * EDGE_PUE * ci
    return DeviceFootprint(device.name, device.embodied_kgco2e, op)


def equivalent_count(edge: DeviceSpec, cloud: DeviceSpec = CLOUD_H100,
                     hours_per_day: float = HOURS_PER_DAY) -> int:
    """Edge devices needed to match the cloud GPU's FLOP budget at
    ``hours_per_day`` participation (peak-FLOPS equivalence, paper Fig. 5).
    """
    cloud_flop_day = cloud.peak_flops * 24 * 3600
    edge_flop_day = edge.peak_flops * hours_per_day * 3600
    return max(1, round(cloud_flop_day / edge_flop_day))


def comm_energy_kwh_per_device(edge: DeviceSpec, *, model_bytes: float,
                               activation_bytes_per_step: float,
                               steps_per_day: float, years: float = YEARS
                               ) -> float:
    """WiFi communication energy for daily participation ([82] power model).

    Volume per step follows the idealized method (paper footnote 1):
    gradients once + layer activations once, amortized over the fleet.
    """
    bytes_per_day = steps_per_day * (model_bytes + activation_bytes_per_step)
    seconds = bytes_per_day / edge.net_bw_Bps
    return edge.power_comm_w * seconds / 3600.0 / 1000.0 * 365 * years


# The paper's Fig. 5 device counts (69 phones / 15 laptops per H100).  These
# rest on optimistic per-device FLOPS (the text quotes M2-Ultra's 53 TFLOPS
# for the "laptop"); matching real SD888/M2-Pro peaks would need 534/118
# devices.  We report BOTH (see benchmarks/fig5_offload.py + EXPERIMENTS.md).
PAPER_FIG5_COUNTS = {"smartphone-sd888": 69, "laptop-m2pro": 15}


def offload_analysis(edge: DeviceSpec, cloud: DeviceSpec = CLOUD_H100, *,
                     hours_per_day: float = HOURS_PER_DAY,
                     years: float = YEARS,
                     comm_kwh_per_device: float = 0.0,
                     device_count: int = 0,
                     use_paper_counts: bool = False) -> Dict[str, float]:
    """Fig. 5: net carbon of replacing one cloud GPU with an edge fleet."""
    ci = paper_average_intensity()
    if device_count:
        n = device_count
    elif use_paper_counts and edge.name in PAPER_FIG5_COUNTS:
        n = PAPER_FIG5_COUNTS[edge.name]
    else:
        n = equivalent_count(edge, cloud, hours_per_day)

    cloud_fp = baseline_footprint(cloud, years=years)

    # marginal edge operational carbon: extra active hours while charging
    extra_kwh = edge.power_active_w * hours_per_day * 365 * years / 1000.0
    marginal_op = n * extra_kwh * EDGE_PUE * ci
    comm = n * comm_kwh_per_device * EDGE_PUE * ci

    return {
        "device_count": n,
        "cloud_total_kg": cloud_fp.total_kg,
        "edge_marginal_compute_kg": marginal_op,
        "edge_marginal_comm_kg": comm,
        "edge_marginal_total_kg": marginal_op + comm,
        "net_reduction_x": cloud_fp.total_kg / (marginal_op + comm)
        if (marginal_op + comm) > 0 else float("inf"),
        "net_reduction_x_no_comm": cloud_fp.total_kg / marginal_op,
    }


def fig4_table() -> Dict[str, DeviceFootprint]:
    return {d.name: baseline_footprint(d)
            for d in (SMARTPHONE_SD888, LAPTOP_M2PRO, CLOUD_H100)}
