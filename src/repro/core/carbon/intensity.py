"""Grid carbon intensity (kgCO2e/kWh) — data per carbonfootprint.com [20].

The paper's Figs 4-5 use the average across North America and Europe for
2021-23.  A small per-region table and a diurnal solar-availability proxy
support the carbon-aware scheduler (§5: "charging during cleaner energy
hours").
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

# yearly averages, kgCO2e/kWh [20]
INTENSITY_BY_REGION: Dict[str, Dict[int, float]] = {
    "north_america": {2021: 0.38, 2022: 0.37, 2023: 0.36},
    "europe": {2021: 0.28, 2022: 0.30, 2023: 0.26},
    "nordics": {2021: 0.03, 2022: 0.03, 2023: 0.03},
    "east_asia": {2021: 0.55, 2022: 0.54, 2023: 0.53},
    "india": {2021: 0.71, 2022: 0.71, 2023: 0.70},
}

PAPER_YEARS = (2021, 2022, 2023)


def paper_average_intensity() -> float:
    """Mean over NA+EU, 2021-23 — the Figs 4-5 convention."""
    vals = [INTENSITY_BY_REGION[r][y]
            for r in ("north_america", "europe") for y in PAPER_YEARS]
    return sum(vals) / len(vals)


@dataclass(frozen=True)
class IntensityTrace:
    """Diurnal intensity model: base grid CI modulated by solar availability
    (clean window around local noon).  Supports §5 carbon-aware scheduling."""

    region: str = "europe"
    year: int = 2023
    solar_fraction: float = 0.35    # max midday CI reduction

    def at_hour(self, hour_utc: float, tz_offset: float = 0.0) -> float:
        base = INTENSITY_BY_REGION[self.region][self.year]
        local = (hour_utc + tz_offset) % 24.0
        # clean window 8:00-18:00 peaking at 13:00
        solar = max(0.0, math.cos((local - 13.0) / 5.5 * math.pi / 2))
        return base * (1.0 - self.solar_fraction * solar)

    def daily_mean(self, tz_offset: float = 0.0) -> float:
        return sum(self.at_hour(h, tz_offset) for h in range(24)) / 24.0
