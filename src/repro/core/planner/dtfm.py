"""DT-FM-style data+pipeline-parallel planner for heterogeneous edge fleets.

The paper's Table 2 uses DT-FM [98] (Yuan et al., NeurIPS'22): the model is
cut into pipeline stages held by different devices; multiple pipelines run
data-parallel.  This planner:

* assigns contiguous layer ranges to devices balancing *time per
  microbatch* across heterogeneous members (compute-capability-weighted),
* computes the GPipe schedule makespan (bubble-aware),
* prices communication through the wide-area :class:`Topology` and its
  collective cost models (:mod:`repro.core.net`): stage-boundary
  activations travel point-to-point along the device→region→backbone
  hierarchy, data-parallel gradient sync runs the chosen collective
  (ring / tree / hierarchical / gossip) over optionally-compressed
  wire bytes, amortized over the local-SGD ``sync_interval``,
* returns per-device energy (active/stall/comm, comm priced per-link)
  — what Table 2 reports.

When no topology is supplied one is synthesized from the devices' own
``net_bw_Bps`` in a single region — which degenerates to (a refinement
of) the seed's flat min-bandwidth model, so homogeneous single-region
plans stay comparable.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import flops as F
from repro.core.energy.devices import DeviceSpec
from repro.core.net import Topology, sync_cost
from repro.models.config import ModelConfig
from repro.optim.compress import CompressConfig


@dataclass(frozen=True)
class StageAssignment:
    device: DeviceSpec
    layers: range
    flops_per_microbatch: float
    time_per_microbatch_s: float
    node: str = ""                    # topology node id


def _stage_key(s: "StageAssignment") -> str:
    """Key tying a stage to its energy / comm-busy ledger entries."""
    return f"{s.device.name}@L{s.layers.start}-{s.layers.stop}"


@dataclass
class DTFMPlan:
    model: str
    stages: List[StageAssignment]
    data_parallel: int
    microbatches: int
    step_time_s: float
    bubble_fraction: float
    comm_s_per_step: float
    energy_wh_per_step: Dict[str, float] = field(default_factory=dict)
    boundary_s_per_step: float = 0.0
    dp_sync_s_per_step: float = 0.0
    wire_bytes_per_step: float = 0.0
    comm_busy_s: Dict[str, float] = field(default_factory=dict)

    @property
    def total_energy_wh_per_step(self) -> float:
        return sum(self.energy_wh_per_step.values())

    @property
    def comm_energy_wh_per_step(self) -> float:
        """Network-module energy: per-stage link busy time x comm power."""
        return sum(s.device.power_comm_w * self.comm_busy_s.get(
                       _stage_key(s), 0.0)
                   for s in self.stages) * self.data_parallel / 3600.0


def partition_layers(cfg: ModelConfig, devices: Sequence[DeviceSpec]
                     ) -> List[range]:
    """Contiguous layer split ∝ device effective FLOP/s (heterogeneity-aware)."""
    L = cfg.num_layers
    weights = [d.effective_flops for d in devices]
    total = sum(weights)
    bounds = [0]
    acc = 0.0
    for w in weights[:-1]:
        acc += w
        # monotone and clamped to [prev, L]: fleets larger than the layer
        # count yield EMPTY stages (idle devices) rather than phantom
        # layers (hypothesis-found: 15 devices x 12 layers overflowed)
        bounds.append(min(max(round(L * acc / total), bounds[-1]), L))
    bounds.append(L)
    return [range(bounds[i], bounds[i + 1]) for i in range(len(devices))]


def plan(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
         batch: int, seq_len: int, microbatches: int = 8,
         data_parallel: int = 1, train: bool = True,
         topology: Optional[Topology] = None,
         nodes: Optional[Sequence[str]] = None,
         collective: str = "ring",
         compress: Optional[CompressConfig] = None,
         sync_interval: int = 1,
         dp_regions: Optional[Sequence[str]] = None) -> DTFMPlan:
    """Plan one pipeline of ``devices`` with ``data_parallel`` replicas.

    ``topology``/``nodes`` place each device in the wide-area graph
    (``nodes[i]`` is ``devices[i]``'s node id); omitted, a single-region
    topology is synthesized.  ``dp_regions`` optionally spreads the
    data-parallel replicas across regions (length ``data_parallel``)
    when pricing gradient sync.  ``sync_interval`` is the local-update
    K: gradient sync happens once every K steps.
    """
    if data_parallel < 1:
        raise ValueError(f"data_parallel={data_parallel} must be >= 1")
    if data_parallel > batch:
        raise ValueError(
            f"data_parallel={data_parallel} exceeds batch={batch}: "
            "each replica would get a zero-sized microbatch")
    if topology is None:
        if nodes is not None:
            raise ValueError("nodes= only makes sense with an explicit "
                             "topology=; the synthesized topology would "
                             "silently ignore it")
        topology = Topology.from_specs(devices)
        nodes = [str(i) for i in range(len(devices))]
    elif nodes is None:
        # positional fallback would silently price links for the wrong
        # device whenever caller order differs from topology insertion
        # order — require the mapping
        raise ValueError(
            "an explicit topology needs nodes= mapping each device to "
            "its topology node id")
    if len(nodes) < len(devices):
        raise ValueError(
            f"nodes places only {len(nodes)} devices but the pipeline "
            f"has {len(devices)}")

    splits = partition_layers(cfg, devices)
    total_flops = F.train_flops(cfg, batch // data_parallel, seq_len,
                                remat=False) if train \
        else F.fwd_flops(cfg, batch // data_parallel, seq_len)
    per_layer = total_flops / cfg.num_layers
    mb = microbatches

    stages = []
    for dev, rng, node in zip(devices, splits, nodes):
        if len(rng) == 0:
            continue                      # idle device: no pipeline stage
        fl = per_layer * len(rng) / mb
        stages.append(StageAssignment(dev, rng, fl,
                                      fl / dev.effective_flops, node))

    # GPipe makespan: (mb + S - 1) * slowest stage time
    S = len(stages)
    t_stage = max(s.time_per_microbatch_s for s in stages)
    makespan = (mb + S - 1) * t_stage
    bubble = (S - 1) / (mb + S - 1)

    skey = _stage_key
    comm_busy: Dict[str, float] = {skey(s): 0.0 for s in stages}
    boundary_wire = 0.0               # per pipeline replica
    dp_wire = 0.0                     # already totalled over the dp group

    # stage-boundary activations, fwd (+ bwd for training), per microbatch
    # chunk over the hierarchical path between the two stage devices
    act_bytes = (batch // data_parallel) * seq_len * cfg.d_model * 2
    directions = 2 if train else 1
    boundary_s = 0.0
    for a, b in zip(stages[:-1], stages[1:]):
        mb_bytes = act_bytes / mb
        t_pair = directions * mb * topology.p2p_time_s(mb_bytes,
                                                       a.node, b.node)
        boundary_s += t_pair
        comm_busy[skey(a)] += t_pair
        comm_busy[skey(b)] += t_pair
        boundary_wire += directions * act_bytes

    # DP gradient sync: each stage's grad shard all-reduces across the
    # data_parallel replicas of that stage (concurrent across stages —
    # disjoint links — so the slowest stage gates), amortized over the
    # local-update interval
    dp_sync_s = 0.0
    if train and data_parallel > 1:
        n_elems_total = F.param_bytes(cfg, 1)
        for s in stages:
            shard = int(n_elems_total * len(s.layers) / cfg.num_layers)
            clone_topo = Topology.from_specs(
                [s.device] * data_parallel, regions=dp_regions,
                params=topology.params)
            c = sync_cost(clone_topo, clone_topo.devices, shard,
                          algorithm=collective, compress=compress,
                          dtype_bytes=2, sync_interval=sync_interval)
            dp_sync_s = max(dp_sync_s, c.time_s)
            comm_busy[skey(s)] += c.per_device_busy_s.get("0", 0.0)
            dp_wire += c.wire_bytes
    comm_s = boundary_s + dp_sync_s

    # energy: active while computing own microbatches, idle during bubble
    # and comm, network module during this stage's own transfers
    energy: Dict[str, float] = {}
    for s in stages:
        active_s = s.time_per_microbatch_s * mb
        stall_s = max(0.0, makespan - active_s)
        e = (s.device.power_active_w * active_s
             + s.device.power_idle_w * stall_s
             + s.device.power_comm_w * comm_busy[skey(s)])
        energy[skey(s)] = energy.get(skey(s), 0.0) \
            + e * data_parallel / 3600.0

    return DTFMPlan(cfg.name, stages, data_parallel, mb,
                    makespan + comm_s, bubble, comm_s, energy,
                    boundary_s_per_step=boundary_s,
                    dp_sync_s_per_step=dp_sync_s,
                    wire_bytes_per_step=boundary_wire * data_parallel
                    + dp_wire,
                    comm_busy_s=comm_busy)


def min_bw_comm_s(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
                  batch: int, seq_len: int, data_parallel: int = 1,
                  train: bool = True) -> float:
    """The seed's flat min-bandwidth communication model, kept as the
    baseline the benchmarks compare the topology-aware pricing against."""
    splits = partition_layers(cfg, devices)
    S = sum(1 for r in splits if len(r))
    act_bytes = (batch // data_parallel) * seq_len * cfg.d_model * 2
    boundary_bytes = (2 if train else 1) * (S - 1) * act_bytes
    grad_bytes = F.param_bytes(cfg, 2) if (train and data_parallel > 1) \
        else 0.0
    bw = min(d.net_bw_Bps for d in devices)
    return boundary_bytes / bw + grad_bytes / bw


def table2_energy(cfg: ModelConfig, device: DeviceSpec, count: int, *,
                  batch: int = 16, seq_len: int = 512, steps: int = 100,
                  microbatches: int = 32) -> Dict[str, float]:
    """Homogeneous-fleet energy for the paper's Table 2 setting."""
    p = plan(cfg, [device] * count, batch=batch, seq_len=seq_len,
             microbatches=microbatches)
    return {
        "devices": count,
        "step_time_s": p.step_time_s,
        "bubble_fraction": p.bubble_fraction,
        "energy_wh": p.total_energy_wh_per_step * steps,
        "comm_s_per_step": p.comm_s_per_step,
    }
