"""DT-FM-style data+pipeline-parallel planner for heterogeneous edge fleets.

The paper's Table 2 uses DT-FM [98] (Yuan et al., NeurIPS'22): the model is
cut into pipeline stages held by different devices; multiple pipelines run
data-parallel.  The planner prices a :class:`~repro.core.placement.
PlacementSpec` — the shared plan→place→execute contract:

* each replica's stages own **non-uniform** contiguous layer ranges,
  balanced so per-stage time matches under heterogeneous compute,
* the GPipe schedule makespan is bubble-aware ((mb+S-1) ticks gated by
  the slowest stage of the slowest replica),
* communication is priced through the wide-area :class:`Topology`:
  stage-boundary activations travel point-to-point along each replica's
  own device→region→backbone path (cross-region hops are WAN bytes),
  and data-parallel gradient sync runs the chosen collective over each
  stage slot's replica group — intra-region first when the placement
  grouped replicas per region — amortized over the local-SGD
  ``sync_interval``,
* per-device energy (active/stall/comm, comm priced per-link) is what
  Table 2 reports.

:func:`plan` keeps the legacy contract (one device list in caller order,
``data_parallel`` analytic clone replicas); :func:`plan_placement`
prices any :class:`PlacementSpec`, including the topology-aware ones
:func:`repro.core.placement.search_placement` emits.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import flops as F
from repro.core.energy.devices import DeviceSpec
from repro.core.net import Topology, sync_cost
from repro.core.placement import (PlacementSpec, balanced_boundaries,
                                  ordered_placement)
from repro.models.config import ModelConfig
from repro.optim.compress import CompressConfig


@dataclass(frozen=True)
class StageAssignment:
    device: DeviceSpec
    layers: range
    flops_per_microbatch: float
    time_per_microbatch_s: float
    node: str = ""                    # topology node id


def _stage_key(s) -> str:
    """Key tying a stage to its energy-ledger entries."""
    return f"{s.device.name}@L{s.layers.start}-{s.layers.stop}"


@dataclass
class DTFMPlan:
    model: str
    stages: List[StageAssignment]     # replica 0 (reference pipeline)
    data_parallel: int
    microbatches: int
    step_time_s: float
    bubble_fraction: float
    comm_s_per_step: float
    energy_wh_per_step: Dict[str, float] = field(default_factory=dict)
    boundary_s_per_step: float = 0.0
    dp_sync_s_per_step: float = 0.0
    wire_bytes_per_step: float = 0.0
    comm_busy_s: Dict[str, float] = field(default_factory=dict)  # by node
    wan_bytes_per_step: float = 0.0   # subset of wire crossing regions
    comm_energy_wh_per_step: float = 0.0
    placement: Optional[PlacementSpec] = None

    @property
    def total_energy_wh_per_step(self) -> float:
        return sum(self.energy_wh_per_step.values())


def partition_layers(cfg: ModelConfig, devices: Sequence[DeviceSpec]
                     ) -> List[range]:
    """Contiguous layer split ∝ device effective FLOP/s (heterogeneity-aware)."""
    bounds = balanced_boundaries(cfg.num_layers,
                                 [d.effective_flops for d in devices])
    return [range(bounds[i], bounds[i + 1]) for i in range(len(devices))]


def plan_placement(cfg: ModelConfig, spec: PlacementSpec, *,
                   batch: int, seq_len: int, microbatches: int = 8,
                   train: bool = True, collective: str = "ring",
                   compress: Optional[CompressConfig] = None,
                   sync_interval: int = 1) -> DTFMPlan:
    """Price a placement: makespan + boundary comm + DP sync + energy.

    This is the cost model :func:`repro.core.placement.search_placement`
    minimizes and the one whose stage boundaries the shard_map pipeline
    executes — the plan you price is the plan you run.
    """
    spec.validate()
    dp = spec.data_parallel
    if dp > batch:
        raise ValueError(
            f"data_parallel={dp} exceeds batch={batch}: "
            "each replica would get a zero-sized microbatch")
    topo = spec.topology
    total_flops = F.train_flops(cfg, batch // dp, seq_len,
                                remat=False) if train \
        else F.fwd_flops(cfg, batch // dp, seq_len)
    per_layer = total_flops / cfg.num_layers
    mb = microbatches
    S = spec.num_stages

    def t_mb(sp) -> float:
        return per_layer * len(sp.layers) / mb / sp.device.effective_flops

    stages = [StageAssignment(sp.device, sp.layers,
                              per_layer * len(sp.layers) / mb,
                              t_mb(sp), sp.node) for sp in spec.stages]

    # GPipe makespan: (mb + S - 1) ticks gated by the slowest stage of
    # the slowest replica (synchronous data parallelism)
    t_stage = max(t_mb(sp) for pipe in spec.pipelines for sp in pipe)
    makespan = (mb + S - 1) * t_stage
    bubble = (S - 1) / (mb + S - 1)

    region = topo.device_region
    comm_busy: Dict[str, float] = {sp.node: 0.0
                                   for pipe in spec.pipelines for sp in pipe}
    for group in spec.dp_sync_nodes:      # sync-group overrides (dp_regions)
        for n in group:
            comm_busy.setdefault(n, 0.0)

    # stage-boundary activations, fwd (+ bwd for training), per microbatch
    # chunk over each replica's own hierarchical path; replicas run
    # concurrently (disjoint links), so the slowest replica gates time
    # while wire/WAN bytes sum over all of them
    act_bytes = (batch // dp) * seq_len * cfg.d_model * 2
    directions = 2 if train else 1
    boundary_s = 0.0
    boundary_wire = 0.0
    boundary_wan = 0.0
    for pipe in spec.pipelines:
        t_rep = 0.0
        for a, b in zip(pipe[:-1], pipe[1:]):
            t_pair = directions * mb * topo.p2p_time_s(act_bytes / mb,
                                                       a.node, b.node)
            t_rep += t_pair
            comm_busy[a.node] += t_pair
            comm_busy[b.node] += t_pair
            boundary_wire += directions * act_bytes
            if region[a.node] != region[b.node]:
                boundary_wan += directions * act_bytes
        boundary_s = max(boundary_s, t_rep)

    # DP gradient sync: each stage slot's grad shard all-reduces across
    # that slot's replica group (concurrent across slots — disjoint
    # links — so the slowest slot gates), amortized over the
    # local-update interval
    dp_sync_s = 0.0
    dp_wire = 0.0
    dp_wan = 0.0
    if train and dp > 1:
        n_elems_total = F.param_bytes(cfg, 1)
        for i in range(S):
            group = spec.dp_group(i)
            shard = int(n_elems_total
                        * len(spec.pipelines[0][i].layers) / cfg.num_layers)
            c = sync_cost(topo, group, shard, algorithm=collective,
                          compress=compress, dtype_bytes=2,
                          sync_interval=sync_interval)
            dp_sync_s = max(dp_sync_s, c.time_s)
            for n in group:
                comm_busy[n] += c.per_device_busy_s.get(n, 0.0)
            dp_wire += c.wire_bytes
            dp_wan += c.wan_bytes
    comm_s = boundary_s + dp_sync_s

    # energy: active while computing own microbatches, idle during bubble
    # and comm, network module during this device's own transfers
    energy: Dict[str, float] = {}
    comm_energy_wh = 0.0
    pipe_nodes = set()
    for pipe in spec.pipelines:
        for sp in pipe:
            pipe_nodes.add(sp.node)
            active_s = t_mb(sp) * mb
            stall_s = max(0.0, makespan - active_s)
            e_comm = sp.device.power_comm_w * comm_busy[sp.node]
            e = (sp.device.power_active_w * active_s
                 + sp.device.power_idle_w * stall_s
                 + e_comm)
            key = _stage_key(sp)
            energy[key] = energy.get(key, 0.0) + e / 3600.0
            comm_energy_wh += e_comm / 3600.0
    for n, busy in comm_busy.items():
        # dp_sync_nodes overrides sync from regions the pipelines don't
        # compute in; their radio time is the stage device's (same spec)
        if n in pipe_nodes or busy == 0.0:
            continue
        e_comm = topo.device_spec[n].power_comm_w * busy
        energy[f"sync:{n}"] = energy.get(f"sync:{n}", 0.0) + e_comm / 3600.0
        comm_energy_wh += e_comm / 3600.0

    return DTFMPlan(cfg.name, stages, dp, mb,
                    makespan + comm_s, bubble, comm_s, energy,
                    boundary_s_per_step=boundary_s,
                    dp_sync_s_per_step=dp_sync_s,
                    wire_bytes_per_step=boundary_wire + dp_wire,
                    comm_busy_s=comm_busy,
                    wan_bytes_per_step=boundary_wan + dp_wan,
                    comm_energy_wh_per_step=comm_energy_wh,
                    placement=spec)


def plan(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
         batch: int, seq_len: int, microbatches: int = 8,
         data_parallel: int = 1, train: bool = True,
         topology: Optional[Topology] = None,
         nodes: Optional[Sequence[str]] = None,
         collective: str = "ring",
         compress: Optional[CompressConfig] = None,
         sync_interval: int = 1,
         dp_regions: Optional[Sequence[str]] = None) -> DTFMPlan:
    """Plan one pipeline of ``devices`` with ``data_parallel`` replicas.

    Legacy caller-order contract: builds an
    :func:`~repro.core.placement.ordered_placement` (synthesizing a
    single-region topology when none is given; ``dp_regions`` spreads
    the clone replicas across regions) and prices it with
    :func:`plan_placement`.
    """
    if data_parallel < 1:
        raise ValueError(f"data_parallel={data_parallel} must be >= 1")
    if data_parallel > batch:
        raise ValueError(
            f"data_parallel={data_parallel} exceeds batch={batch}: "
            "each replica would get a zero-sized microbatch")
    if topology is None:
        if nodes is not None:
            raise ValueError("nodes= only makes sense with an explicit "
                             "topology=; the synthesized topology would "
                             "silently ignore it")
    elif nodes is None:
        # positional fallback would silently price links for the wrong
        # device whenever caller order differs from topology insertion
        # order — require the mapping
        raise ValueError(
            "an explicit topology needs nodes= mapping each device to "
            "its topology node id")
    elif len(nodes) < len(devices):
        raise ValueError(
            f"nodes places only {len(nodes)} devices but the pipeline "
            f"has {len(devices)}")
    spec = ordered_placement(cfg, devices, topology=topology, nodes=nodes,
                             data_parallel=data_parallel,
                             dp_regions=dp_regions)
    return plan_placement(cfg, spec, batch=batch, seq_len=seq_len,
                          microbatches=microbatches, train=train,
                          collective=collective, compress=compress,
                          sync_interval=sync_interval)


def min_bw_comm_s(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
                  batch: int, seq_len: int, data_parallel: int = 1,
                  train: bool = True) -> float:
    """The seed's flat min-bandwidth communication model, kept as the
    baseline the benchmarks compare the topology-aware pricing against."""
    splits = partition_layers(cfg, devices)
    S = sum(1 for r in splits if len(r))
    act_bytes = (batch // data_parallel) * seq_len * cfg.d_model * 2
    boundary_bytes = (2 if train else 1) * (S - 1) * act_bytes
    grad_bytes = F.param_bytes(cfg, 2) if (train and data_parallel > 1) \
        else 0.0
    bw = min(d.net_bw_Bps for d in devices)
    return boundary_bytes / bw + grad_bytes / bw


def table2_energy(cfg: ModelConfig, device: DeviceSpec, count: int, *,
                  batch: int = 16, seq_len: int = 512, steps: int = 100,
                  microbatches: int = 32) -> Dict[str, float]:
    """Homogeneous-fleet energy for the paper's Table 2 setting."""
    p = plan(cfg, [device] * count, batch=batch, seq_len=seq_len,
             microbatches=microbatches)
    return {
        "devices": count,
        "step_time_s": p.step_time_s,
        "bubble_fraction": p.bubble_fraction,
        "energy_wh": p.total_energy_wh_per_step * steps,
        "comm_s_per_step": p.comm_s_per_step,
    }
