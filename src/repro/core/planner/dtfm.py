"""DT-FM-style data+pipeline-parallel planner for heterogeneous edge fleets.

The paper's Table 2 uses DT-FM [98] (Yuan et al., NeurIPS'22): the model is
cut into pipeline stages held by different devices; multiple pipelines run
data-parallel.  This planner:

* assigns contiguous layer ranges to devices balancing *time per
  microbatch* across heterogeneous members (compute-capability-weighted),
* computes the GPipe schedule makespan (bubble-aware),
* prices communication: activations across stage boundaries + gradient
  sync across data-parallel replicas,
* returns per-device energy (active/stall/comm) — what Table 2 reports.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import flops as F
from repro.core.energy.devices import DeviceSpec
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class StageAssignment:
    device: DeviceSpec
    layers: range
    flops_per_microbatch: float
    time_per_microbatch_s: float


@dataclass
class DTFMPlan:
    model: str
    stages: List[StageAssignment]
    data_parallel: int
    microbatches: int
    step_time_s: float
    bubble_fraction: float
    comm_s_per_step: float
    energy_wh_per_step: Dict[str, float] = field(default_factory=dict)

    @property
    def total_energy_wh_per_step(self) -> float:
        return sum(self.energy_wh_per_step.values())


def partition_layers(cfg: ModelConfig, devices: Sequence[DeviceSpec]
                     ) -> List[range]:
    """Contiguous layer split ∝ device effective FLOP/s (heterogeneity-aware)."""
    L = cfg.num_layers
    weights = [d.effective_flops for d in devices]
    total = sum(weights)
    bounds = [0]
    acc = 0.0
    for w in weights[:-1]:
        acc += w
        # monotone and clamped to [prev, L]: fleets larger than the layer
        # count yield EMPTY stages (idle devices) rather than phantom
        # layers (hypothesis-found: 15 devices x 12 layers overflowed)
        bounds.append(min(max(round(L * acc / total), bounds[-1]), L))
    bounds.append(L)
    return [range(bounds[i], bounds[i + 1]) for i in range(len(devices))]


def plan(cfg: ModelConfig, devices: Sequence[DeviceSpec], *,
         batch: int, seq_len: int, microbatches: int = 8,
         data_parallel: int = 1, train: bool = True) -> DTFMPlan:
    splits = partition_layers(cfg, devices)
    total_flops = F.train_flops(cfg, batch // data_parallel, seq_len,
                                remat=False) if train \
        else F.fwd_flops(cfg, batch // data_parallel, seq_len)
    per_layer = total_flops / cfg.num_layers
    mb = microbatches

    stages = []
    for dev, rng in zip(devices, splits):
        if len(rng) == 0:
            continue                      # idle device: no pipeline stage
        fl = per_layer * len(rng) / mb
        stages.append(StageAssignment(dev, rng, fl,
                                      fl / dev.effective_flops))

    # GPipe makespan: (mb + S - 1) * slowest stage time
    S = len(stages)
    t_stage = max(s.time_per_microbatch_s for s in stages)
    makespan = (mb + S - 1) * t_stage
    bubble = (S - 1) / (mb + S - 1)

    # communication: stage-boundary activations (fwd + bwd) + DP grad sync
    act_bytes = (batch // data_parallel) * seq_len * cfg.d_model * 2
    boundary_bytes = 2 * (S - 1) * act_bytes if train \
        else (S - 1) * act_bytes
    grad_bytes = F.param_bytes(cfg, 2) if (train and data_parallel > 1) \
        else 0.0
    bw = min(d.net_bw_Bps for d in devices)
    comm_s = boundary_bytes / bw + grad_bytes / bw

    # energy: active while computing own microbatches, idle during bubble
    # and comm, WiFi module during transfers
    energy: Dict[str, float] = {}
    for s in stages:
        active_s = s.time_per_microbatch_s * mb
        stall_s = max(0.0, makespan - active_s)
        # each stage touches its two boundaries, not the full pipeline volume
        e = (s.device.power_active_w * active_s
             + s.device.power_idle_w * stall_s
             + s.device.power_comm_w * comm_s * (2.0 / S if S > 1 else 1.0))
        energy[f"{s.device.name}@L{s.layers.start}-{s.layers.stop}"] = \
            energy.get(f"{s.device.name}@L{s.layers.start}-{s.layers.stop}",
                       0.0) + e * data_parallel / 3600.0

    return DTFMPlan(cfg.name, stages, data_parallel, mb,
                    makespan + comm_s, bubble, comm_s, energy)


def table2_energy(cfg: ModelConfig, device: DeviceSpec, count: int, *,
                  batch: int = 16, seq_len: int = 512, steps: int = 100,
                  microbatches: int = 32) -> Dict[str, float]:
    """Homogeneous-fleet energy for the paper's Table 2 setting."""
    p = plan(cfg, [device] * count, batch=batch, seq_len=seq_len,
             microbatches=microbatches)
    return {
        "devices": count,
        "step_time_s": p.step_time_s,
        "bubble_fraction": p.bubble_fraction,
        "energy_wh": p.total_energy_wh_per_step * steps,
        "comm_s_per_step": p.comm_s_per_step,
    }
