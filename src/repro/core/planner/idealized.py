"""The paper's idealized distributed-training method (§4.2, footnote 1).

Training is modeled as a DAG of operators distributed by a controller:

* total data transmitted per batch = model size + Σ per-layer intermediate
  results (each transmitted once; gradients aggregated locally, no p2p
  broadcast),
* compute is perfectly divisible across devices (factor out the specifics
  of any real partitioning method),
* per-device energy = active power x compute time + comm-module power x
  comm time + idle power x stall time.

Used for Fig. 3 (cloud vs edge energy across OPT sizes) exactly as the
paper specifies, and as the lower-bound reference the DT-FM planner is
compared against.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core import flops as F
from repro.core.energy.devices import DeviceSpec
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class IdealizedPlan:
    model: str
    device: str
    num_devices: int
    compute_s: float
    comm_s: float
    energy_wh: float
    comm_energy_wh: float
    total_energy_wh: float


def devices_required(cfg: ModelConfig, device: DeviceSpec,
                     *, bytes_per_param: float = 16.0) -> int:
    """Devices needed to hold params + optimizer states (fp32 Adam: 16 B)."""
    need = cfg.param_count() * bytes_per_param
    per_dev = device.mem_gb * (2 ** 30) * 0.7       # 70% usable
    return max(1, -(-int(need) // int(per_dev)))


def plan(cfg: ModelConfig, device: DeviceSpec, *, batch: int, seq_len: int,
         steps: int, num_devices: int = 0) -> IdealizedPlan:
    n = num_devices or devices_required(cfg, device)
    total_flops = F.train_flops(cfg, batch, seq_len, remat=False) * steps

    # perfectly divided compute
    compute_s = total_flops / (n * device.effective_flops)

    # idealized communication volume per batch (footnote 1): each device
    # transmits ITS OWN parameters' gradients and ITS OWN layers'
    # intermediates, once, in parallel over its own link — the total volume
    # (model + Σ intermediates) is spread across the fleet, so per-device
    # transfer time divides by n.
    if n > 1:
        vol_per_step = F.param_bytes(cfg, 2) \
            + F.activation_bytes(cfg, batch, seq_len, 2)
        comm_s = vol_per_step * steps / (n * device.net_bw_Bps)
    else:
        comm_s = 0.0

    compute_wh = n * device.power_active_w * compute_s / 3600.0
    comm_wh = n * device.power_comm_w * comm_s / 3600.0
    return IdealizedPlan(cfg.name, device.name, n, compute_s, comm_s,
                         compute_wh, comm_wh, compute_wh + comm_wh)


def fig3_energy(cfg: ModelConfig, device: DeviceSpec, *, batch: int = 16,
                seq_len: int = 512, steps: int = 100) -> Dict[str, float]:
    p = plan(cfg, device, batch=batch, seq_len=seq_len, steps=steps)
    return {"devices": p.num_devices, "energy_wh": p.total_energy_wh,
            "compute_wh": p.energy_wh, "comm_wh": p.comm_energy_wh}
