"""Analytic FLOP / byte models — single source of truth for the paper's
energy analyses (§4.2) and the roofline compute/memory terms.

Conventions (stated in EXPERIMENTS.md):

* train step     : 6·N·D  (+ attention term 12·L·S²·H·hd·(1/2 causal) x3)
* prefill        : 2·N·D  (+ attention term x1)
* decode (1 tok) : 2·N_active·B (+ cache-read attention term)

N counts *active* parameters for MoE (the 6·N_active·D convention).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.models.config import ModelConfig


def _attn_flops_per_seq(cfg: ModelConfig, S: int, causal: bool = True) -> float:
    """QK^T + PV flops for one sequence, all attention layers."""
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_kind(i) == "attn")
    if cfg.attention == "mla":
        qk_dim = cfg.mla.qk_head_dim
        v_dim = cfg.mla.v_head_dim
    else:
        qk_dim = v_dim = cfg.resolved_head_dim
    H = cfg.num_heads
    per_layer = 2.0 * S * S * H * (qk_dim + v_dim)
    if cfg.sliding_window and S > cfg.sliding_window:
        per_layer *= cfg.sliding_window / S          # SWA cuts the window
    elif causal:
        per_layer *= 0.5
    return n_attn * per_layer


def _ssd_flops_per_seq(cfg: ModelConfig, S: int) -> float:
    """SSD chunked-scan flops for one sequence, all SSM layers."""
    if not cfg.ssm.enabled:
        return 0.0
    n_ssm = sum(1 for i in range(cfg.num_layers)
                if cfg.layer_kind(i) == "ssm")
    ssm = cfg.ssm
    d_in = ssm.d_inner(cfg.d_model)
    n = ssm.d_state
    Q = ssm.chunk_size
    # per chunk ~ 2(Q²n·g→heads + Q²p + 2Qpn) per head-dim partition; use
    # the dominant terms: CBᵀ (Q²n), L·X (Q²p), state in/out (2Qpn)
    h = ssm.num_heads(cfg.d_model)
    p = ssm.head_dim
    per_head_chunk = 2.0 * (Q * Q * n + Q * Q * p + 2 * Q * p * n)
    return n_ssm * h * (S / Q) * per_head_chunk


def fwd_flops(cfg: ModelConfig, batch: int, seq_len: int) -> float:
    n_active = cfg.active_param_count()
    tokens = batch * seq_len
    return (2.0 * n_active * tokens
            + batch * _attn_flops_per_seq(cfg, seq_len)
            + batch * _ssd_flops_per_seq(cfg, seq_len))


def train_flops(cfg: ModelConfig, batch: int, seq_len: int,
                remat: bool = True) -> float:
    """fwd + bwd (2x fwd) [+ recompute fwd if remat]."""
    mult = 4.0 if remat else 3.0
    return mult * fwd_flops(cfg, batch, seq_len) / 1.0


def decode_flops(cfg: ModelConfig, batch: int, cache_len: int) -> float:
    n_active = cfg.active_param_count()
    # attention: q·Kᵀ + p·V over the cache (linear in cache_len)
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_kind(i) == "attn")
    if cfg.attention == "mla":
        per_tok_attn = 2.0 * cache_len * cfg.num_heads * (
            cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * 2
    else:
        hd = cfg.resolved_head_dim
        w = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
            else cache_len
        per_tok_attn = 2.0 * w * cfg.num_heads * 2 * hd
    ssm_step = 0.0
    if cfg.ssm.enabled:
        n_ssm = sum(1 for i in range(cfg.num_layers)
                    if cfg.layer_kind(i) == "ssm")
        ssm_step = n_ssm * 6.0 * cfg.ssm.num_heads(cfg.d_model) \
            * cfg.ssm.head_dim * cfg.ssm.d_state
    return batch * (2.0 * n_active + n_attn * per_tok_attn + ssm_step)


def param_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    return cfg.param_count() * dtype_bytes


def train_state_bytes(cfg: ModelConfig, param_dtype: int = 2,
                      moment_dtype: int = 4) -> float:
    """weights + grads + two Adam moments."""
    n = cfg.param_count()
    return n * (param_dtype + 4 + 2 * moment_dtype)


def kv_cache_bytes(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype_bytes: int = 2) -> float:
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_kind(i) == "attn")
    if cfg.attention == "mla":
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    else:
        per_tok = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
        if cfg.sliding_window:
            cache_len = min(cache_len, cfg.sliding_window)
    total = n_attn * batch * cache_len * per_tok * dtype_bytes
    if cfg.ssm.enabled:
        ssm = cfg.ssm
        n_ssm = sum(1 for i in range(cfg.num_layers)
                    if cfg.layer_kind(i) == "ssm")
        total += n_ssm * batch * 4 * (
            ssm.num_heads(cfg.d_model) * ssm.head_dim * ssm.d_state)
    return total


def activation_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                     dtype_bytes: int = 2) -> float:
    """Layer-boundary activations (what the idealized DAG method transmits)."""
    return cfg.num_layers * batch * seq_len * cfg.d_model * dtype_bytes


def decode_hbm_bytes(cfg: ModelConfig, batch: int, cache_len: int,
                     dtype_bytes: int = 2) -> float:
    """Weights read once + cache read once per decode step."""
    return (cfg.active_param_count() * dtype_bytes
            + kv_cache_bytes(cfg, batch, cache_len, dtype_bytes))


def summary(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, float]:
    return {
        "params": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "train_flops": train_flops(cfg, batch, seq_len),
        "fwd_flops": fwd_flops(cfg, batch, seq_len),
        "param_bytes_bf16": param_bytes(cfg),
        "train_state_bytes": train_state_bytes(cfg),
    }
