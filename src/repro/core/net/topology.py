"""Wide-area network topology for edge fleets.

The fleets this repo plans over talk across *heterogeneous wide-area
links*, not a datacenter fabric: a smartphone on WiFi behind a home
router, a laptop on campus ethernet, a cloud GPU on 25 GbE — all in
different regions joined by a backbone.  A single ``min(net_bw_Bps)``
scalar (the seed planner's model) cannot express why hierarchical
collectives or local-update training help, because it prices an
intra-region hop and a trans-continental hop identically.

This module models the fleet as a three-level hierarchy:

    device --access link--> region router --WAN link--> backbone

Every edge is a :class:`Link` with its own bandwidth, propagation
latency, and jitter (the p95-p50 spread that a straggler-synchronous
collective actually waits for).  Routing is hierarchical and
deterministic: two devices in the same region meet at their region
router; across regions the path transits the backbone.  The analytic
collective cost models in :mod:`repro.core.net.collectives` consume
these paths.

Defaults follow the paper's §4.2 edge setting (10 MB/s symmetric device
links) with WAN numbers typical of inter-region internet paths.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.energy.devices import DeviceSpec

# Typical wide-area constants (per-flow; see e.g. M-Lab / RIPE Atlas
# inter-region medians).  All overridable in NetParams.
DEFAULT_ACCESS_LATENCY_S = 0.005     # device <-> region router (WiFi/LAN)
DEFAULT_ACCESS_JITTER_S = 0.002
DEFAULT_WAN_BW_BPS = 37.5e6          # per-flow inter-region: 300 Mb/s
DEFAULT_WAN_LATENCY_S = 0.050        # one-way inter-region propagation
DEFAULT_WAN_JITTER_S = 0.010

BACKBONE = "backbone"


@dataclass(frozen=True)
class Link:
    """One directed network edge (modelled symmetric unless stated)."""
    bw_Bps: float
    latency_s: float = 0.0
    jitter_s: float = 0.0

    @property
    def delay_s(self) -> float:
        """Effective per-transfer fixed cost: propagation + jitter margin."""
        return self.latency_s + self.jitter_s

    def transfer_s(self, nbytes: float) -> float:
        return self.delay_s + nbytes / self.bw_Bps


@dataclass(frozen=True)
class NetParams:
    """Knobs for the synthesized hierarchy (access/WAN defaults above)."""
    access_latency_s: float = DEFAULT_ACCESS_LATENCY_S
    access_jitter_s: float = DEFAULT_ACCESS_JITTER_S
    wan_bw_Bps: float = DEFAULT_WAN_BW_BPS
    wan_latency_s: float = DEFAULT_WAN_LATENCY_S
    wan_jitter_s: float = DEFAULT_WAN_JITTER_S


@dataclass
class Topology:
    """Hierarchical device→region→backbone graph with per-link costs.

    Node ids: devices are arbitrary strings (``str(device_id)``), region
    routers are ``region:<name>``, the backbone is ``backbone``.
    """
    links: Dict[Tuple[str, str], Link] = field(default_factory=dict)
    device_region: Dict[str, str] = field(default_factory=dict)
    device_spec: Dict[str, DeviceSpec] = field(default_factory=dict)
    params: NetParams = field(default_factory=NetParams)
    # region -> member devices, maintained incrementally: ``regions`` /
    # ``devices_in_region`` used to rescan every device per call, which
    # the orchestrator replan loop hit once per region per churn event
    _region_devices: Dict[str, List[str]] = field(default_factory=dict,
                                                  repr=False)

    def __post_init__(self) -> None:
        # constructed-from-dicts path (e.g. search's _extend_for_dp):
        # build the index from whatever device_region already holds
        self._region_devices = {}
        for d, r in self.device_region.items():
            self._region_devices.setdefault(r, []).append(d)

    # -------------------------------------------------------------- building
    @staticmethod
    def _region_node(region: str) -> str:
        return f"region:{region}"

    def add_device(self, dev_id: str, region: str, spec: DeviceSpec, *,
                   bw_Bps: Optional[float] = None) -> None:
        p = self.params
        r = self._region_node(region)
        if (r, BACKBONE) not in self.links:
            wan = Link(p.wan_bw_Bps, p.wan_latency_s, p.wan_jitter_s)
            self.links[(r, BACKBONE)] = wan
            self.links[(BACKBONE, r)] = wan
        access = Link(bw_Bps if bw_Bps is not None else spec.net_bw_Bps,
                      p.access_latency_s, p.access_jitter_s)
        self.links[(dev_id, r)] = access
        self.links[(r, dev_id)] = access
        if dev_id in self.device_region:
            old = self.device_region[dev_id]
            if old != region:
                self._region_devices[old].remove(dev_id)
                self._region_devices.setdefault(region, []).append(dev_id)
        else:
            self._region_devices.setdefault(region, []).append(dev_id)
        self.device_region[dev_id] = region
        self.device_spec[dev_id] = spec

    @classmethod
    def from_fleet(cls, fleet: Sequence, *,
                   params: Optional[NetParams] = None) -> "Topology":
        """Build from ``FleetDevice``s (uses .device_id/.region/.spec)."""
        topo = cls(params=params or NetParams())
        for d in fleet:
            topo.add_device(str(d.device_id), d.region, d.spec)
        return topo

    @classmethod
    def from_specs(cls, devices: Sequence[DeviceSpec], *,
                   regions: Optional[Sequence[str]] = None,
                   params: Optional[NetParams] = None) -> "Topology":
        """Build from bare DeviceSpecs; single region unless given."""
        topo = cls(params=params or NetParams())
        for i, spec in enumerate(devices):
            region = regions[i % len(regions)] if regions else "local"
            topo.add_device(str(i), region, spec)
        return topo

    # -------------------------------------------------------------- queries
    @property
    def devices(self) -> List[str]:
        return list(self.device_region)

    @property
    def regions(self) -> List[str]:
        """Regions in first-device-seen order (O(R), not a device scan)."""
        return [r for r, ds in self._region_devices.items() if ds]

    def devices_in_region(self, region: str) -> List[str]:
        return list(self._region_devices.get(region, ()))

    def path(self, a: str, b: str) -> List[Link]:
        """Hierarchical route: same-region via router, else via backbone."""
        if a == b:
            return []
        ra = self._region_node(self.device_region[a])
        rb = self._region_node(self.device_region[b])
        if ra == rb:
            hops = [(a, ra), (ra, b)]
        else:
            hops = [(a, ra), (ra, BACKBONE), (BACKBONE, rb), (rb, b)]
        return [self.links[h] for h in hops]

    def path_bw_Bps(self, a: str, b: str) -> float:
        return min(l.bw_Bps for l in self.path(a, b))

    def path_delay_s(self, a: str, b: str) -> float:
        return sum(l.delay_s for l in self.path(a, b))

    def p2p_time_s(self, nbytes: float, a: str, b: str) -> float:
        """Store-and-forward approximated as bottleneck + total delay."""
        if a == b:
            return 0.0
        return self.path_delay_s(a, b) + nbytes / self.path_bw_Bps(a, b)

    def access_bw_Bps(self, dev: str) -> float:
        return self.links[(dev, self._region_node(self.device_region[dev]))] \
            .bw_Bps

    def group_bottleneck_bw_Bps(self, group: Sequence[str]) -> float:
        """Slowest pairwise path bandwidth within a participant group."""
        bws = [self.access_bw_Bps(d) for d in group]
        if len({self.device_region[d] for d in group}) > 1:
            bws.append(self.params.wan_bw_Bps)
        return min(bws)

    def group_max_delay_s(self, group: Sequence[str]) -> float:
        """Worst one-hop neighbour delay a ring/tree step can see."""
        best = 0.0
        for i, a in enumerate(group):
            for b in group[i + 1:]:
                best = max(best, self.path_delay_s(a, b))
        return best
