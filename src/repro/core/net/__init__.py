"""Wide-area network model: topology graph + collective cost models."""

from repro.core.net.collectives import (COLLECTIVES,
                                        BatchedCollectiveCost,
                                        CollectiveCost,
                                        batched_collective_cost,
                                        batched_sync_cost,
                                        collective_cost, gossip_average,
                                        hierarchical_allreduce,
                                        ring_allgather, ring_allreduce,
                                        sync_cost, tree_allreduce)
from repro.core.net.fleet_arrays import FleetArrays, synthetic_fleet
from repro.core.net.topology import (BACKBONE, Link, NetParams, Topology)

__all__ = [
    "BACKBONE", "Link", "NetParams", "Topology",
    "COLLECTIVES", "CollectiveCost", "collective_cost",
    "ring_allreduce", "tree_allreduce", "hierarchical_allreduce",
    "gossip_average", "ring_allgather", "sync_cost",
    "FleetArrays", "synthetic_fleet",
    "BatchedCollectiveCost", "batched_collective_cost",
    "batched_sync_cost",
]
