"""Analytic cost models for collectives over a wide-area :class:`Topology`.

Each model answers, for a participant group and a per-device payload of
``nbytes``: how long does the collective take, how many bytes cross the
wire in total, how many of those cross the WAN, and how long is each
device's radio busy (which is what its ``power_comm_w`` multiplies).

The algorithms:

* ``ring``        — bandwidth-optimal flat ring allreduce
                    (reduce-scatter + allgather, Patarasuk & Yuan).
* ``tree``        — binomial-tree reduce + broadcast: latency-optimal,
                    2x the bytes of ring at the bottleneck.
* ``hierarchical``— intra-region ring, inter-region ring over the region
                    leaders, intra-region broadcast — crosses the WAN
                    O(R) times instead of O(N) (DT-FM / Gaia style).
* ``gossip``      — randomized pairwise averaging; approximate consensus
                    in O(log N) rounds, no global barrier.
* ``allgather``   — ring allgather of per-device shards.

Every transfer is priced ``delay + bytes/bw`` on the bottleneck link of
its path, with concurrent same-step transfers overlapped (the slowest
one gates the step) — the standard alpha-beta model lifted onto the
hierarchical topology.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.net.topology import Topology


@dataclass(frozen=True)
class CollectiveCost:
    algorithm: str
    participants: int
    time_s: float
    wire_bytes: float                  # total bytes over all links
    wan_bytes: float                   # subset crossing inter-region links
    per_device_busy_s: Dict[str, float] = field(default_factory=dict)
    per_device_bytes: Dict[str, float] = field(default_factory=dict)


def _by_region(topo: Topology, group: Sequence[str]) -> List[str]:
    """Ring order minimizing WAN crossings: contiguous region blocks."""
    return sorted(group, key=lambda d: (topo.device_region[d], d))


def _region_blocks(topo: Topology, group: Sequence[str]) -> Dict[str, List[str]]:
    blocks: Dict[str, List[str]] = {}
    for d in group:
        blocks.setdefault(topo.device_region[d], []).append(d)
    return blocks


def ring_allreduce(topo: Topology, group: Sequence[str], nbytes: float
                   ) -> CollectiveCost:
    """Flat ring: 2(N-1) steps of nbytes/N chunks.

    time = 2(N-1)/N * nbytes / bottleneck_bw + 2(N-1) * step_delay
    """
    group = _by_region(topo, group)
    n = len(group)
    if n <= 1:
        return CollectiveCost("ring", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    chunk = nbytes / n
    bw = topo.group_bottleneck_bw_Bps(group)
    # every step some neighbour pair spans the worst path in the ring
    delay = max(topo.path_delay_s(group[i], group[(i + 1) % n])
                for i in range(n))
    steps = 2 * (n - 1)
    time = steps * (chunk / bw + delay)
    busy = {d: steps * chunk / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: steps * chunk for d in group}
    regions = len(_region_blocks(topo, group))
    wan = steps * regions * chunk if regions > 1 else 0.0
    return CollectiveCost("ring", n, time, steps * chunk * n, wan,
                          busy, per_dev)


def tree_allreduce(topo: Topology, group: Sequence[str], nbytes: float
                   ) -> CollectiveCost:
    """Binomial reduce-to-root + broadcast: 2*ceil(log2 N) full-payload
    rounds — fewer latency terms than ring, more bottleneck bytes."""
    group = _by_region(topo, group)
    n = len(group)
    if n <= 1:
        return CollectiveCost("tree", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    rounds = 2 * math.ceil(math.log2(n))
    bw = topo.group_bottleneck_bw_Bps(group)
    delay = topo.group_max_delay_s(group)
    time = rounds * (nbytes / bw + delay)
    # each non-root sends the vector up once and receives it down once
    wire = 2 * (n - 1) * nbytes
    busy = {d: 2 * nbytes / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: 2 * nbytes for d in group}
    regions = len(_region_blocks(topo, group))
    wan = 2 * (regions - 1) * nbytes if regions > 1 else 0.0
    return CollectiveCost("tree", n, time, wire, wan, busy, per_dev)


def hierarchical_allreduce(topo: Topology, group: Sequence[str],
                           nbytes: float) -> CollectiveCost:
    """Three-phase hierarchical allreduce (Horovod/Gaia style):

    1. intra-region ring reduce-scatter — each device ends with a
       region-reduced shard,
    2. cross-region ring allreduce of the shards — the region's
       aggregate flow is carried collectively by its members, so each
       region uplink moves 2(R-1)/R * nbytes instead of sitting inside
       every one of the flat ring's 2(N-1) steps,
    3. intra-region ring allgather of the now-global shards.

    Per-device access-link traffic stays at the ring-optimal
    ~2(n_r-1)/n_r * nbytes, while WAN traffic and WAN latency hits drop
    from O(N) to O(R).
    """
    blocks = _region_blocks(topo, group)
    regions = sorted(blocks)
    R = len(regions)
    if R <= 1:
        return ring_allreduce(topo, group, nbytes)

    busy = {d: 0.0 for d in group}
    per_dev = {d: 0.0 for d in group}
    wire = 0.0

    # phases 1 + 3: concurrent intra-region reduce-scatter + allgather,
    # together one full ring allreduce worth of intra traffic
    t_intra = 0.0
    for region in regions:
        members = blocks[region]
        c = ring_allreduce(topo, members, nbytes)
        t_intra = max(t_intra, c.time_s)
        wire += c.wire_bytes
        for d in members:
            busy[d] += c.per_device_busy_s.get(d, 0.0)
            per_dev[d] += c.per_device_bytes.get(d, 0.0)

    # phase 2: ring over regions; each step moves nbytes/R per region,
    # split across that region's members' access links and funnelled
    # through the shared region uplink
    leaders = [blocks[r][0] for r in regions]
    wan_delay = max(topo.path_delay_s(leaders[i], leaders[(i + 1) % R])
                    for i in range(R))
    chunk = nbytes / R
    steps = 2 * (R - 1)
    t_wan = 0.0
    for region in regions:
        members = blocks[region]
        acc = min(topo.access_bw_Bps(d) for d in members)
        per_member = chunk / len(members)
        t_wan = max(t_wan, max(chunk / topo.params.wan_bw_Bps,
                               per_member / acc))
        for d in members:
            busy[d] += steps * per_member / topo.access_bw_Bps(d)
            per_dev[d] += steps * per_member
    t_inter = steps * (t_wan + wan_delay)
    wan = steps * chunk * R            # every region uplink, both phases
    wire += wan

    return CollectiveCost("hierarchical", len(group),
                          t_intra + t_inter, wire, wan, busy, per_dev)


def gossip_average(topo: Topology, group: Sequence[str], nbytes: float, *,
                   rounds: Optional[int] = None) -> CollectiveCost:
    """Randomized pairwise averaging (approximate — no exact allreduce):
    each round every device exchanges its full payload with one peer."""
    n = len(group)
    if n <= 1:
        return CollectiveCost("gossip", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    rounds = rounds if rounds is not None else math.ceil(math.log2(n))
    bw = topo.group_bottleneck_bw_Bps(group)
    delay = topo.group_max_delay_s(group)
    time = rounds * (nbytes / bw + delay)
    wire = rounds * n * nbytes
    regions = len(_region_blocks(topo, group))
    # expected fraction of random pairs that cross a region boundary
    wan = wire * (1.0 - 1.0 / regions) if regions > 1 else 0.0
    busy = {d: rounds * nbytes / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: rounds * nbytes for d in group}
    return CollectiveCost("gossip", n, time, wire, wan, busy, per_dev)


def ring_allgather(topo: Topology, group: Sequence[str], shard_bytes: float
                   ) -> CollectiveCost:
    """Ring allgather: N-1 steps, each forwarding one device's shard."""
    group = _by_region(topo, group)
    n = len(group)
    if n <= 1:
        return CollectiveCost("allgather", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    bw = topo.group_bottleneck_bw_Bps(group)
    delay = max(topo.path_delay_s(group[i], group[(i + 1) % n])
                for i in range(n))
    steps = n - 1
    time = steps * (shard_bytes / bw + delay)
    busy = {d: steps * shard_bytes / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: steps * shard_bytes for d in group}
    regions = len(_region_blocks(topo, group))
    wan = steps * regions * shard_bytes if regions > 1 else 0.0
    return CollectiveCost("allgather", n, time, steps * shard_bytes * n,
                          wan, busy, per_dev)


COLLECTIVES: Dict[str, Callable[..., CollectiveCost]] = {
    "ring": ring_allreduce,
    "tree": tree_allreduce,
    "hierarchical": hierarchical_allreduce,
    "gossip": gossip_average,
    "allgather": ring_allgather,
}


def collective_cost(topo: Topology, group: Sequence[str], nbytes: float,
                    algorithm: str = "ring") -> CollectiveCost:
    try:
        fn = COLLECTIVES[algorithm]
    except KeyError:
        raise ValueError(f"unknown collective {algorithm!r}; "
                         f"have {sorted(COLLECTIVES)}") from None
    return fn(topo, group, nbytes)


# --------------------------------------------------------------------------
# Batched kernels over FleetArrays: price EVERY group of a placement (or a
# whole sweep of candidate groups) in one vectorized call, bit-identical to
# the scalar models above.  The scalar models are sequences of IEEE-754 ops
# (Python left folds, `max` chains, `min` of lists); the array versions
# replay the exact same op sequence per group lane:
#
#   * segment min/max via ufunc.reduceat  — safe, order-independent;
#   * sequential += folds (hierarchical wire) as short loops over REGION
#     slots, never np.add.reduce (pairwise summation would change bits);
#   * the O(n²) pairwise group_max_delay of tree collapses to O(n): block
#     top-2 access delays (same-region pairs, one symmetric add) plus an
#     exclusive block-prefix max of x_i = da_i + w_i (cross pairs), valid
#     because double rounding is monotone so argmax commutes with the
#     (x + w_j) + da_j post-ops.
#
# Parity is asserted in tests/test_fleet_scale.py and re-gated by
# benchmarks/bench_fleet_scale.py (0 mismatches across all algorithms).


@dataclass(frozen=True)
class BatchedCollectiveCost:
    """Per-group cost columns + per-member busy/bytes columns.

    Members are returned in the kernel's canonical order (``member_*``
    arrays): sorted by group, then by the scalar ``_by_region`` ring
    order — ``member_device[i]`` is a row into the priced
    :class:`~repro.core.net.fleet_arrays.FleetArrays`.
    """
    algorithm: str
    group_ids: np.ndarray       # (G,) sorted unique group labels
    participants: np.ndarray    # (G,) members per group
    time_s: np.ndarray          # (G,)
    wire_bytes: np.ndarray      # (G,)
    wan_bytes: np.ndarray       # (G,)
    member_device: np.ndarray   # (M,) fleet rows, canonical order
    member_group: np.ndarray    # (M,) index into group_ids
    busy_s: np.ndarray          # (M,)
    bytes_dev: np.ndarray       # (M,)

    def group(self, g: int) -> int:
        return int(np.searchsorted(self.group_ids, g))


def _segment_index(grp_sorted: np.ndarray):
    gids, seg_start, n_g = np.unique(grp_sorted, return_index=True,
                                     return_counts=True)
    seg_of = np.repeat(np.arange(gids.shape[0]), n_g)
    return gids, seg_start, n_g, seg_of


def _ring_segments(seg_start, n_g, seg_of, accbw, accd, rid, wdm,
                   wan_bw, chunk, steps):
    """Ring allreduce/allgather over contiguous region-sorted segments.

    ``chunk``/``steps`` are per-segment (callers encode allreduce's
    nbytes/n · 2(n-1) vs allgather's shard · (n-1)).  Returns per-segment
    time/wire/wan/regions and per-member busy/bytes, each the scalar
    formula's exact op sequence.  n==1 segments price to zero naturally
    (steps == 0), matching the scalar early return.
    """
    M = accbw.shape[0]
    nxt = np.arange(1, M + 1)
    nxt[seg_start + n_g - 1] = seg_start          # ring wrap per segment
    cross = rid != rid[nxt]
    d_pair = np.where(cross,
                      ((accd + wdm) + wdm[nxt]) + accd[nxt],
                      accd + accd[nxt])
    delay = np.maximum.reduceat(d_pair, seg_start)
    bmin = np.minimum.reduceat(accbw, seg_start)
    prev_same = np.zeros(M, bool)
    prev_same[1:] = seg_of[1:] == seg_of[:-1]
    same_reg = np.zeros(M, bool)
    same_reg[1:] = rid[1:] == rid[:-1]
    newblk = ~(prev_same & same_reg)
    regions = np.add.reduceat(newblk.astype(np.int64), seg_start)
    bw = np.where(regions > 1, np.minimum(bmin, wan_bw), bmin)
    time = steps * (chunk / bw + delay)
    wire = (steps * chunk) * n_g
    wan = np.where(regions > 1, (steps * regions) * chunk, 0.0)
    chunk_m = chunk[seg_of]
    steps_m = steps[seg_of]
    busy = (steps_m * chunk_m) / accbw
    nbytes_m = steps_m * chunk_m
    return time, wire, wan, regions, newblk, busy, nbytes_m


def _block_tables(seg_start, n_g, seg_of, newblk):
    """Contiguous (group, region) block structure over sorted members."""
    block_start = np.flatnonzero(newblk)
    blk_of = np.cumsum(newblk) - 1
    blocks_per = np.add.reduceat(newblk.astype(np.int64), seg_start)
    first_blk = np.concatenate(([0], np.cumsum(blocks_per)[:-1]))
    blk_grp = seg_of[block_start]
    slot = np.arange(block_start.shape[0]) - first_blk[blk_grp]
    return block_start, blk_of, blocks_per, first_blk, blk_grp, slot


def _group_max_delay_sorted(seg_start, n_g, seg_of, accd, rid, wdm, newblk):
    """``group_max_delay_s`` per segment, members in (region, node) order.

    Same-region pairs contribute da_i + da_j (symmetric single add →
    block top-2).  Cross pairs contribute ((da_i + w_i) + w_j) + da_j for
    i before j; rounding monotonicity lets the max over i collapse to an
    exclusive prefix-max of x_i = da_i + w_i over earlier region blocks.
    """
    (block_start, blk_of, blocks_per, first_blk, blk_grp,
     slot) = _block_tables(seg_start, n_g, seg_of, newblk)
    B = block_start.shape[0]
    G = seg_start.shape[0]
    x = accd + wdm
    max_x_b = np.maximum.reduceat(x, block_start)
    top1_da = np.maximum.reduceat(accd, block_start)
    ismax = accd == top1_da[blk_of]
    cs = np.cumsum(ismax.astype(np.int64))
    before = cs[block_start] - ismax[block_start]
    first = ismax & ((cs - before[blk_of]) == 1)
    top2_da = np.maximum.reduceat(np.where(first, -np.inf, accd),
                                  block_start)
    same_b = top1_da + top2_da                    # -inf: singleton block
    rmax = int(blocks_per.max())
    dense_x = np.full((G, rmax), -np.inf)
    dense_x[blk_grp, slot] = max_x_b
    pref = np.full((G, rmax), -np.inf)
    for k in range(1, rmax):
        pref[:, k] = np.maximum(pref[:, k - 1], dense_x[:, k - 1])
    m_b = pref[blk_grp, slot]                     # -inf: first block
    cross_b = (m_b + wdm[block_start]) + top1_da
    cand = np.maximum(same_b, cross_b)
    return np.maximum(np.maximum.reduceat(cand, first_blk), 0.0)


def batched_collective_cost(fleet, member_device, member_group,
                            nbytes, algorithm: str = "ring", *,
                            rounds: Optional[int] = None
                            ) -> BatchedCollectiveCost:
    """Price every group of a placement in one vectorized call.

    ``member_device``/``member_group`` are parallel arrays: fleet row →
    group label.  ``nbytes`` is a scalar or per-group array aligned with
    the sorted unique group labels.  Output values are bit-identical to
    running the matching scalar model per group on
    ``fleet.to_topology()``.
    """
    if algorithm not in COLLECTIVES:
        raise ValueError(f"unknown collective {algorithm!r}; "
                         f"have {sorted(COLLECTIVES)}")
    device = np.asarray(member_device, dtype=np.int64).ravel()
    grp_in = np.asarray(member_group, dtype=np.int64).ravel()
    if device.shape[0] == 0:
        z = np.zeros(0)
        return BatchedCollectiveCost(algorithm, np.zeros(0, np.int64),
                                     np.zeros(0, np.int64), z, z, z,
                                     device, grp_in, z, z)
    if algorithm == "gossip":
        # the scalar model does NOT ring-sort the group: keep caller
        # member order (stable) — pairwise delay is order-sensitive
        order = np.argsort(grp_in, kind="stable")
    else:
        order = np.lexsort((fleet.name_rank[device], grp_in))
    dev = device[order]
    gids, seg_start, n_g, seg_of = _segment_index(grp_in[order])
    G = gids.shape[0]
    nb = np.broadcast_to(
        np.asarray(nbytes, dtype=np.float64).ravel(), (G,))
    accbw = fleet.acc_bw[dev]
    accd = fleet.acc_delay[dev]
    rid = fleet.region_of[dev].astype(np.int64)
    wdm = fleet.wan_delay[rid]
    wan_bw = fleet.params.wan_bw_Bps

    if algorithm in ("ring", "allgather"):
        if algorithm == "ring":
            chunk, steps = nb / n_g, 2 * (n_g - 1)
        else:
            chunk, steps = nb + np.zeros(G), n_g - 1
        time, wire, wan, _, _, busy, bytes_m = _ring_segments(
            seg_start, n_g, seg_of, accbw, accd, rid, wdm, wan_bw,
            chunk, steps)
        return BatchedCollectiveCost(algorithm, gids, n_g, time, wire,
                                     wan, dev, seg_of, busy, bytes_m)

    if algorithm == "tree":
        _, _, _, regions, newblk, _, _ = _ring_segments(
            seg_start, n_g, seg_of, accbw, accd, rid, wdm, wan_bw,
            nb / n_g, 2 * (n_g - 1))
        bmin = np.minimum.reduceat(accbw, seg_start)
        bw = np.where(regions > 1, np.minimum(bmin, wan_bw), bmin)
        delay = _group_max_delay_sorted(seg_start, n_g, seg_of, accd,
                                        rid, wdm, newblk)
        nrounds = (2 * np.ceil(np.log2(n_g))).astype(np.int64)
        multi = n_g > 1
        time = np.where(multi, nrounds * (nb / bw + delay), 0.0)
        wire = np.where(multi, (2 * (n_g - 1)) * nb, 0.0)
        wan = np.where(multi & (regions > 1), (2 * (regions - 1)) * nb,
                       0.0)
        nb_m = nb[seg_of]
        multi_m = multi[seg_of]
        busy = np.where(multi_m, (2 * nb_m) / accbw, 0.0)
        bytes_m = np.where(multi_m, 2 * nb_m, 0.0)
        return BatchedCollectiveCost("tree", gids, n_g, time, wire, wan,
                                     dev, seg_of, busy, bytes_m)

    if algorithm == "hierarchical":
        return _batched_hierarchical(fleet, gids, seg_start, n_g, seg_of,
                                     dev, accbw, accd, rid, wdm, wan_bw,
                                     nb)
    return _batched_gossip(gids, seg_start, n_g, seg_of, dev, accbw,
                           accd, rid, wdm, wan_bw, nb, rounds)


def _batched_hierarchical(fleet, gids, seg_start, n_g, seg_of, dev,
                          accbw, accd, rid, wdm, wan_bw, nb
                          ) -> BatchedCollectiveCost:
    G = gids.shape[0]
    # flat-ring pricing doubles as the R==1 fallback (scalar behaviour)
    ring_t, ring_wire, ring_wan, regions, newblk, ring_busy, ring_bytes \
        = _ring_segments(seg_start, n_g, seg_of, accbw, accd, rid, wdm,
                         wan_bw, nb / n_g, 2 * (n_g - 1))
    (block_start, blk_of, blocks_per, first_blk, blk_grp,
     slot) = _block_tables(seg_start, n_g, seg_of, newblk)
    B = block_start.shape[0]
    n_b = np.diff(np.append(block_start, accbw.shape[0]))
    # phase 1+3: one ring allreduce per region block (single region, so
    # _ring_segments with block segments prices it exactly)
    blk_newblk = np.ones(B, bool)  # each block is its own region run
    t_b, wire_b, _, _, _, busy1, bytes1 = _ring_segments(
        block_start, n_b, blk_of, accbw, accd, rid, wdm, wan_bw,
        nb[blk_grp] / n_b, 2 * (n_b - 1))
    t_intra = np.maximum(np.maximum.reduceat(t_b, first_blk), 0.0)
    rmax = int(blocks_per.max())
    garange = np.arange(G)
    dense_wire = np.zeros((G, rmax))
    dense_wire[blk_grp, slot] = wire_b
    wire_acc = np.zeros(G)
    for k in range(rmax):        # scalar left fold, sorted-region order
        wire_acc = wire_acc + dense_wire[:, k]
    # phase 2: ring over region leaders (first block member)
    dense_da = np.zeros((G, rmax))
    dense_wd = np.zeros((G, rmax))
    dense_da[blk_grp, slot] = accd[block_start]
    dense_wd[blk_grp, slot] = wdm[block_start]
    wan_delay = np.full(G, -np.inf)
    for k in range(rmax):
        nxtk = np.where(k + 1 < blocks_per, k + 1, 0)
        val = ((dense_da[:, k] + dense_wd[:, k])
               + dense_wd[garange, nxtk]) + dense_da[garange, nxtk]
        wan_delay = np.maximum(wan_delay,
                               np.where(k < blocks_per, val, -np.inf))
    chunk = nb / blocks_per
    steps = 2 * (blocks_per - 1)
    per_member_b = chunk[blk_grp] / n_b
    acc_min_b = np.minimum.reduceat(accbw, block_start)
    t_wan_b = np.maximum(chunk[blk_grp] / wan_bw,
                         per_member_b / acc_min_b)
    t_wan = np.maximum(np.maximum.reduceat(t_wan_b, first_blk), 0.0)
    t_inter = steps * (t_wan + wan_delay)
    wan = (steps * chunk) * blocks_per
    per_member_m = per_member_b[blk_of]
    steps_m = steps[seg_of]
    busy = busy1 + (steps_m * per_member_m) / accbw
    bytes_m = bytes1 + steps_m * per_member_m
    multi = regions > 1
    multi_m = multi[seg_of]
    return BatchedCollectiveCost(
        "hierarchical", gids, n_g,
        np.where(multi, t_intra + t_inter, ring_t),
        np.where(multi, wire_acc + wan, ring_wire),
        np.where(multi, wan, ring_wan),
        dev, seg_of,
        np.where(multi_m, busy, ring_busy),
        np.where(multi_m, bytes_m, ring_bytes))


def _batched_gossip(gids, seg_start, n_g, seg_of, dev, accbw, accd, rid,
                    wdm, wan_bw, nb, rounds) -> BatchedCollectiveCost:
    G = gids.shape[0]
    bmin = np.minimum.reduceat(accbw, seg_start)
    # distinct regions per group (members NOT region-sorted here)
    nreg = np.zeros(G, np.int64)
    delay = np.zeros(G)
    for s in range(G):            # O(n_g²) pairwise, like the scalar
        a = seg_start[s]
        b = a + n_g[s]
        r = rid[a:b]
        nreg[s] = np.unique(r).shape[0]
        if n_g[s] <= 1:
            continue
        da = accd[a:b]
        w = wdm[a:b]
        x = da + w
        v = np.where(r[:, None] != r[None, :],
                     (x[:, None] + w[None, :]) + da[None, :],
                     da[:, None] + da[None, :])
        iu = np.triu_indices(int(n_g[s]), 1)
        delay[s] = np.maximum(v[iu].max(), 0.0)
    bw = np.where(nreg > 1, np.minimum(bmin, wan_bw), bmin)
    nrounds = np.ceil(np.log2(n_g)).astype(np.int64) if rounds is None \
        else np.full(G, int(rounds), np.int64)
    multi = n_g > 1
    time = np.where(multi, nrounds * (nb / bw + delay), 0.0)
    wire = np.where(multi, (nrounds * n_g) * nb, 0.0)
    wan = np.where(multi & (nreg > 1), wire * (1.0 - 1.0 / nreg), 0.0)
    nb_m = nb[seg_of]
    rounds_m = nrounds[seg_of]
    multi_m = multi[seg_of]
    busy = np.where(multi_m, (rounds_m * nb_m) / accbw, 0.0)
    bytes_m = np.where(multi_m, rounds_m * nb_m, 0.0)
    return BatchedCollectiveCost("gossip", gids, n_g, time, wire, wan,
                                 dev, seg_of, busy, bytes_m)


def batched_sync_cost(fleet, member_device, member_group,
                      num_elements, *, algorithm: str = "ring",
                      compress=None, dtype_bytes: int = 4,
                      sync_interval: int = 1) -> BatchedCollectiveCost:
    """Batched :func:`sync_cost`: compression + local-update amortization
    over every group at once.  ``num_elements`` is a scalar or per-group
    array aligned with the sorted unique group labels."""
    from repro.optim.compress import wire_bytes_count
    ne = np.atleast_1d(np.asarray(num_elements))
    nbytes = np.array([wire_bytes_count(int(x), compress,
                                        dtype_bytes=dtype_bytes)
                       for x in ne], dtype=np.float64)
    if nbytes.shape[0] == 1:
        nbytes = float(nbytes[0])
    c = batched_collective_cost(fleet, member_device, member_group,
                                nbytes, algorithm)
    k = max(1, sync_interval)
    if k == 1:
        return c
    return BatchedCollectiveCost(
        c.algorithm, c.group_ids, c.participants, c.time_s / k,
        c.wire_bytes / k, c.wan_bytes / k, c.member_device,
        c.member_group, c.busy_s / k, c.bytes_dev / k)


def sync_cost(topo: Topology, group: Sequence[str], num_elements: int, *,
              algorithm: str = "ring", compress=None,
              dtype_bytes: int = 4, sync_interval: int = 1
              ) -> CollectiveCost:
    """Gradient-sync cost with compression and local-update amortization.

    ``compress`` is an :class:`repro.optim.compress.CompressConfig`; the
    payload is the *wire* byte count that compressor actually transmits
    (``optim.compress.wire_bytes_count``), so collective choice and
    compression compose.  ``sync_interval`` is the local-SGD K: one sync
    per K steps, so per-step cost divides by K.
    """
    from repro.optim.compress import wire_bytes_count
    nbytes = wire_bytes_count(num_elements, compress,
                              dtype_bytes=dtype_bytes)
    c = collective_cost(topo, group, nbytes, algorithm)
    k = max(1, sync_interval)
    if k == 1:
        return c
    return CollectiveCost(
        c.algorithm, c.participants, c.time_s / k, c.wire_bytes / k,
        c.wan_bytes / k,
        {d: v / k for d, v in c.per_device_busy_s.items()},
        {d: v / k for d, v in c.per_device_bytes.items()})
