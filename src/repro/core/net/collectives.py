"""Analytic cost models for collectives over a wide-area :class:`Topology`.

Each model answers, for a participant group and a per-device payload of
``nbytes``: how long does the collective take, how many bytes cross the
wire in total, how many of those cross the WAN, and how long is each
device's radio busy (which is what its ``power_comm_w`` multiplies).

The algorithms:

* ``ring``        — bandwidth-optimal flat ring allreduce
                    (reduce-scatter + allgather, Patarasuk & Yuan).
* ``tree``        — binomial-tree reduce + broadcast: latency-optimal,
                    2x the bytes of ring at the bottleneck.
* ``hierarchical``— intra-region ring, inter-region ring over the region
                    leaders, intra-region broadcast — crosses the WAN
                    O(R) times instead of O(N) (DT-FM / Gaia style).
* ``gossip``      — randomized pairwise averaging; approximate consensus
                    in O(log N) rounds, no global barrier.
* ``allgather``   — ring allgather of per-device shards.

Every transfer is priced ``delay + bytes/bw`` on the bottleneck link of
its path, with concurrent same-step transfers overlapped (the slowest
one gates the step) — the standard alpha-beta model lifted onto the
hierarchical topology.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.net.topology import Topology


@dataclass(frozen=True)
class CollectiveCost:
    algorithm: str
    participants: int
    time_s: float
    wire_bytes: float                  # total bytes over all links
    wan_bytes: float                   # subset crossing inter-region links
    per_device_busy_s: Dict[str, float] = field(default_factory=dict)
    per_device_bytes: Dict[str, float] = field(default_factory=dict)


def _by_region(topo: Topology, group: Sequence[str]) -> List[str]:
    """Ring order minimizing WAN crossings: contiguous region blocks."""
    return sorted(group, key=lambda d: (topo.device_region[d], d))


def _region_blocks(topo: Topology, group: Sequence[str]) -> Dict[str, List[str]]:
    blocks: Dict[str, List[str]] = {}
    for d in group:
        blocks.setdefault(topo.device_region[d], []).append(d)
    return blocks


def ring_allreduce(topo: Topology, group: Sequence[str], nbytes: float
                   ) -> CollectiveCost:
    """Flat ring: 2(N-1) steps of nbytes/N chunks.

    time = 2(N-1)/N * nbytes / bottleneck_bw + 2(N-1) * step_delay
    """
    group = _by_region(topo, group)
    n = len(group)
    if n <= 1:
        return CollectiveCost("ring", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    chunk = nbytes / n
    bw = topo.group_bottleneck_bw_Bps(group)
    # every step some neighbour pair spans the worst path in the ring
    delay = max(topo.path_delay_s(group[i], group[(i + 1) % n])
                for i in range(n))
    steps = 2 * (n - 1)
    time = steps * (chunk / bw + delay)
    busy = {d: steps * chunk / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: steps * chunk for d in group}
    regions = len(_region_blocks(topo, group))
    wan = steps * regions * chunk if regions > 1 else 0.0
    return CollectiveCost("ring", n, time, steps * chunk * n, wan,
                          busy, per_dev)


def tree_allreduce(topo: Topology, group: Sequence[str], nbytes: float
                   ) -> CollectiveCost:
    """Binomial reduce-to-root + broadcast: 2*ceil(log2 N) full-payload
    rounds — fewer latency terms than ring, more bottleneck bytes."""
    group = _by_region(topo, group)
    n = len(group)
    if n <= 1:
        return CollectiveCost("tree", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    rounds = 2 * math.ceil(math.log2(n))
    bw = topo.group_bottleneck_bw_Bps(group)
    delay = topo.group_max_delay_s(group)
    time = rounds * (nbytes / bw + delay)
    # each non-root sends the vector up once and receives it down once
    wire = 2 * (n - 1) * nbytes
    busy = {d: 2 * nbytes / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: 2 * nbytes for d in group}
    regions = len(_region_blocks(topo, group))
    wan = 2 * (regions - 1) * nbytes if regions > 1 else 0.0
    return CollectiveCost("tree", n, time, wire, wan, busy, per_dev)


def hierarchical_allreduce(topo: Topology, group: Sequence[str],
                           nbytes: float) -> CollectiveCost:
    """Three-phase hierarchical allreduce (Horovod/Gaia style):

    1. intra-region ring reduce-scatter — each device ends with a
       region-reduced shard,
    2. cross-region ring allreduce of the shards — the region's
       aggregate flow is carried collectively by its members, so each
       region uplink moves 2(R-1)/R * nbytes instead of sitting inside
       every one of the flat ring's 2(N-1) steps,
    3. intra-region ring allgather of the now-global shards.

    Per-device access-link traffic stays at the ring-optimal
    ~2(n_r-1)/n_r * nbytes, while WAN traffic and WAN latency hits drop
    from O(N) to O(R).
    """
    blocks = _region_blocks(topo, group)
    regions = sorted(blocks)
    R = len(regions)
    if R <= 1:
        return ring_allreduce(topo, group, nbytes)

    busy = {d: 0.0 for d in group}
    per_dev = {d: 0.0 for d in group}
    wire = 0.0

    # phases 1 + 3: concurrent intra-region reduce-scatter + allgather,
    # together one full ring allreduce worth of intra traffic
    t_intra = 0.0
    for region in regions:
        members = blocks[region]
        c = ring_allreduce(topo, members, nbytes)
        t_intra = max(t_intra, c.time_s)
        wire += c.wire_bytes
        for d in members:
            busy[d] += c.per_device_busy_s.get(d, 0.0)
            per_dev[d] += c.per_device_bytes.get(d, 0.0)

    # phase 2: ring over regions; each step moves nbytes/R per region,
    # split across that region's members' access links and funnelled
    # through the shared region uplink
    leaders = [blocks[r][0] for r in regions]
    wan_delay = max(topo.path_delay_s(leaders[i], leaders[(i + 1) % R])
                    for i in range(R))
    chunk = nbytes / R
    steps = 2 * (R - 1)
    t_wan = 0.0
    for region in regions:
        members = blocks[region]
        acc = min(topo.access_bw_Bps(d) for d in members)
        per_member = chunk / len(members)
        t_wan = max(t_wan, max(chunk / topo.params.wan_bw_Bps,
                               per_member / acc))
        for d in members:
            busy[d] += steps * per_member / topo.access_bw_Bps(d)
            per_dev[d] += steps * per_member
    t_inter = steps * (t_wan + wan_delay)
    wan = steps * chunk * R            # every region uplink, both phases
    wire += wan

    return CollectiveCost("hierarchical", len(group),
                          t_intra + t_inter, wire, wan, busy, per_dev)


def gossip_average(topo: Topology, group: Sequence[str], nbytes: float, *,
                   rounds: Optional[int] = None) -> CollectiveCost:
    """Randomized pairwise averaging (approximate — no exact allreduce):
    each round every device exchanges its full payload with one peer."""
    n = len(group)
    if n <= 1:
        return CollectiveCost("gossip", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    rounds = rounds if rounds is not None else math.ceil(math.log2(n))
    bw = topo.group_bottleneck_bw_Bps(group)
    delay = topo.group_max_delay_s(group)
    time = rounds * (nbytes / bw + delay)
    wire = rounds * n * nbytes
    regions = len(_region_blocks(topo, group))
    # expected fraction of random pairs that cross a region boundary
    wan = wire * (1.0 - 1.0 / regions) if regions > 1 else 0.0
    busy = {d: rounds * nbytes / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: rounds * nbytes for d in group}
    return CollectiveCost("gossip", n, time, wire, wan, busy, per_dev)


def ring_allgather(topo: Topology, group: Sequence[str], shard_bytes: float
                   ) -> CollectiveCost:
    """Ring allgather: N-1 steps, each forwarding one device's shard."""
    group = _by_region(topo, group)
    n = len(group)
    if n <= 1:
        return CollectiveCost("allgather", n, 0.0, 0.0, 0.0,
                              {d: 0.0 for d in group},
                              {d: 0.0 for d in group})
    bw = topo.group_bottleneck_bw_Bps(group)
    delay = max(topo.path_delay_s(group[i], group[(i + 1) % n])
                for i in range(n))
    steps = n - 1
    time = steps * (shard_bytes / bw + delay)
    busy = {d: steps * shard_bytes / topo.access_bw_Bps(d) for d in group}
    per_dev = {d: steps * shard_bytes for d in group}
    regions = len(_region_blocks(topo, group))
    wan = steps * regions * shard_bytes if regions > 1 else 0.0
    return CollectiveCost("allgather", n, time, steps * shard_bytes * n,
                          wan, busy, per_dev)


COLLECTIVES: Dict[str, Callable[..., CollectiveCost]] = {
    "ring": ring_allreduce,
    "tree": tree_allreduce,
    "hierarchical": hierarchical_allreduce,
    "gossip": gossip_average,
    "allgather": ring_allgather,
}


def collective_cost(topo: Topology, group: Sequence[str], nbytes: float,
                    algorithm: str = "ring") -> CollectiveCost:
    try:
        fn = COLLECTIVES[algorithm]
    except KeyError:
        raise ValueError(f"unknown collective {algorithm!r}; "
                         f"have {sorted(COLLECTIVES)}") from None
    return fn(topo, group, nbytes)


def sync_cost(topo: Topology, group: Sequence[str], num_elements: int, *,
              algorithm: str = "ring", compress=None,
              dtype_bytes: int = 4, sync_interval: int = 1
              ) -> CollectiveCost:
    """Gradient-sync cost with compression and local-update amortization.

    ``compress`` is an :class:`repro.optim.compress.CompressConfig`; the
    payload is the *wire* byte count that compressor actually transmits
    (``optim.compress.wire_bytes_count``), so collective choice and
    compression compose.  ``sync_interval`` is the local-SGD K: one sync
    per K steps, so per-step cost divides by K.
    """
    from repro.optim.compress import wire_bytes_count
    nbytes = wire_bytes_count(num_elements, compress,
                              dtype_bytes=dtype_bytes)
    c = collective_cost(topo, group, nbytes, algorithm)
    k = max(1, sync_interval)
    if k == 1:
        return c
    return CollectiveCost(
        c.algorithm, c.participants, c.time_s / k, c.wire_bytes / k,
        c.wan_bytes / k,
        {d: v / k for d, v in c.per_device_busy_s.items()},
        {d: v / k for d, v in c.per_device_bytes.items()})
