"""Struct-of-arrays fleet representation for massive-scale simulation.

The dict-of-``Link`` :class:`~repro.core.net.topology.Topology` is the
right *authoring* surface — heterogeneous per-device links, incremental
churn — but every cost query walks Python objects, which caps the
orchestrator sim and placement search at tens of devices.
:class:`FleetArrays` is the same fleet flattened into dense numpy
columns (device flops / access bandwidth / region / power / carbon,
region-blocked WAN link parameters), which is what the batched
collective kernels (:func:`repro.core.net.collectives.
batched_collective_cost`), the hierarchical placement search
(:mod:`repro.core.placement.fleet`) and the vectorized churn sweep
(:mod:`repro.core.sched.fleet_sim`) price 10⁴–10⁶ devices against.

The contract: for any fleet expressible as a ``Topology`` built through
``add_device`` (per-device access links + per-region WAN uplinks), the
batched kernels over the arrays are **numerically identical** — same
IEEE-754 operations in the same order — to the scalar cost models over
the dict topology.  ``name_rank`` precomputes the ``(region_name,
node_name)`` string sort the scalar ``_by_region`` ring order uses, so
batched group sorts are integer lexsorts instead of string sorts.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.carbon.intensity import INTENSITY_BY_REGION
from repro.core.energy.devices import CATALOG, DeviceSpec
from repro.core.net.topology import BACKBONE, NetParams, Topology


@dataclass
class FleetArrays:
    """Dense per-device columns + per-region WAN parameters."""
    node_names: np.ndarray          # (N,) str — topology node ids
    region_of: np.ndarray           # (N,) int32 — index into ``regions``
    regions: np.ndarray             # (R,) str — region names, SORTED
    acc_bw: np.ndarray              # (N,) float64 — access link bytes/s
    acc_delay: np.ndarray           # (N,) float64 — access latency+jitter
    eff_flops: np.ndarray           # (N,) float64
    power_active_w: np.ndarray      # (N,) float64
    power_idle_w: np.ndarray        # (N,) float64
    power_comm_w: np.ndarray        # (N,) float64
    carbon_kg_per_kwh: np.ndarray   # (N,) float64 — region grid intensity
    wan_bw: np.ndarray              # (R,) float64 — region uplink bytes/s
    wan_delay: np.ndarray           # (R,) float64 — uplink latency+jitter
    params: NetParams = field(default_factory=NetParams)
    spec_names: Optional[np.ndarray] = None     # (N,) str, provenance
    name_rank: np.ndarray = field(default=None)  # (N,) int64, see below

    def __post_init__(self) -> None:
        if self.name_rank is None:
            # rank of each device under the scalar _by_region sort key
            # (region_name, node_name); regions[] is name-sorted so the
            # int pair (region_of, node_name) sorts identically
            order = np.lexsort((self.node_names, self.region_of))
            rank = np.empty(len(order), dtype=np.int64)
            rank[order] = np.arange(len(order))
            self.name_rank = rank

    # ---------------------------------------------------------------- shape
    @property
    def num_devices(self) -> int:
        return int(self.node_names.shape[0])

    @property
    def num_regions(self) -> int:
        return int(self.regions.shape[0])

    def row_of(self) -> Dict[str, int]:
        return {str(n): i for i, n in enumerate(self.node_names)}

    def region_counts(self) -> np.ndarray:
        return np.bincount(self.region_of, minlength=self.num_regions)

    def region_flops(self) -> np.ndarray:
        """Aggregate effective FLOP/s per region (the region summary the
        hierarchical search ranks candidates on)."""
        return np.bincount(self.region_of, weights=self.eff_flops,
                           minlength=self.num_regions)

    # --------------------------------------------------------- constructors
    @classmethod
    def from_topology(cls, topo: Topology) -> "FleetArrays":
        devices = topo.devices
        regions = np.array(sorted({topo.device_region[d] for d in devices}))
        ridx = {r: i for i, r in enumerate(regions)}
        n = len(devices)
        acc_bw = np.empty(n)
        acc_delay = np.empty(n)
        region_of = np.empty(n, dtype=np.int32)
        eff = np.empty(n)
        p_act = np.empty(n)
        p_idle = np.empty(n)
        p_comm = np.empty(n)
        names = []
        for i, d in enumerate(devices):
            r = topo.device_region[d]
            link = topo.links[(d, topo._region_node(r))]
            acc_bw[i] = link.bw_Bps
            acc_delay[i] = link.delay_s
            region_of[i] = ridx[r]
            spec = topo.device_spec[d]
            eff[i] = spec.effective_flops
            p_act[i] = spec.power_active_w
            p_idle[i] = spec.power_idle_w
            p_comm[i] = spec.power_comm_w
            names.append(spec.name)
        wan_bw = np.empty(len(regions))
        wan_delay = np.empty(len(regions))
        for r, i in ridx.items():
            up = topo.links[(topo._region_node(r), BACKBONE)]
            wan_bw[i] = up.bw_Bps
            wan_delay[i] = up.delay_s
        carbon = np.array([_region_intensity(str(r)) for r in regions])
        return cls(node_names=np.array([str(d) for d in devices]),
                   region_of=region_of, regions=regions,
                   acc_bw=acc_bw, acc_delay=acc_delay, eff_flops=eff,
                   power_active_w=p_act, power_idle_w=p_idle,
                   power_comm_w=p_comm,
                   carbon_kg_per_kwh=carbon[region_of],
                   wan_bw=wan_bw, wan_delay=wan_delay,
                   params=topo.params,
                   spec_names=np.array(names))

    @classmethod
    def from_fleet(cls, fleet: Sequence, *,
                   params: Optional[NetParams] = None) -> "FleetArrays":
        """From ``FleetDevice``s, without materializing the dict graph —
        identical columns to ``from_topology(Topology.from_fleet(...))``."""
        params = params or NetParams()
        regions = np.array(sorted({d.region for d in fleet}))
        ridx = {r: i for i, r in enumerate(regions)}
        acc_delay_v = params.access_latency_s + params.access_jitter_s
        wan_delay_v = params.wan_latency_s + params.wan_jitter_s
        n = len(fleet)
        return cls(
            node_names=np.array([str(d.device_id) for d in fleet]),
            region_of=np.array([ridx[d.region] for d in fleet], np.int32),
            regions=regions,
            acc_bw=np.array([d.spec.net_bw_Bps for d in fleet]),
            acc_delay=np.full(n, acc_delay_v),
            eff_flops=np.array([d.spec.effective_flops for d in fleet]),
            power_active_w=np.array([d.spec.power_active_w for d in fleet]),
            power_idle_w=np.array([d.spec.power_idle_w for d in fleet]),
            power_comm_w=np.array([d.spec.power_comm_w for d in fleet]),
            carbon_kg_per_kwh=np.array(
                [_region_intensity(d.region) for d in fleet]),
            wan_bw=np.full(len(regions), params.wan_bw_Bps),
            wan_delay=np.full(len(regions), wan_delay_v),
            params=params,
            spec_names=np.array([d.spec.name for d in fleet]))

    def to_topology(self) -> Topology:
        """Materialize the dict graph (the scalar reference the parity
        tests and the ≥50× speedup baseline price against)."""
        topo = Topology(params=self.params)
        for i in range(self.num_devices):
            spec = _spec_for_row(self, i)
            topo.add_device(str(self.node_names[i]),
                            str(self.regions[self.region_of[i]]), spec,
                            bw_Bps=float(self.acc_bw[i]))
        return topo

    # ------------------------------------------------------------- subsets
    def take(self, rows: np.ndarray) -> "FleetArrays":
        """Sub-fleet view over device ``rows`` (regions table shared)."""
        rows = np.asarray(rows)
        return FleetArrays(
            node_names=self.node_names[rows],
            region_of=self.region_of[rows], regions=self.regions,
            acc_bw=self.acc_bw[rows], acc_delay=self.acc_delay[rows],
            eff_flops=self.eff_flops[rows],
            power_active_w=self.power_active_w[rows],
            power_idle_w=self.power_idle_w[rows],
            power_comm_w=self.power_comm_w[rows],
            carbon_kg_per_kwh=self.carbon_kg_per_kwh[rows],
            wan_bw=self.wan_bw, wan_delay=self.wan_delay,
            params=self.params,
            spec_names=self.spec_names[rows]
            if self.spec_names is not None else None,
            name_rank=None)


def _region_intensity(region: str) -> float:
    table = INTENSITY_BY_REGION.get(region)
    if table:
        return table[max(table)]
    return 0.30                      # generic-grid fallback, kg/kWh


def _spec_for_row(fleet: FleetArrays, i: int) -> DeviceSpec:
    name = str(fleet.spec_names[i]) if fleet.spec_names is not None \
        else f"dev{i}"
    base = CATALOG.get(name)
    if base is not None and base.effective_flops == fleet.eff_flops[i]:
        return base
    return DeviceSpec(
        name=name, kind="edge",
        peak_flops=float(fleet.eff_flops[i]), mfu=1.0,
        power_active_w=float(fleet.power_active_w[i]),
        power_idle_w=float(fleet.power_idle_w[i]),
        power_comm_w=float(fleet.power_comm_w[i]),
        mem_gb=8.0, net_bw_Bps=float(fleet.acc_bw[i]),
        embodied_kgco2e=0.0, lifetime_years=3.0)


def synthetic_fleet(n: int, *, regions: Sequence[str] = ("europe",
                                                         "north_america",
                                                         "east_asia",
                                                         "nordics"),
                    spec_names: Sequence[str] = ("smartphone-sd888",
                                                 "laptop-m2pro"),
                    spec_weights: Optional[Sequence[float]] = None,
                    params: Optional[NetParams] = None,
                    region_mix: str = "round_robin",
                    seed: int = 0) -> FleetArrays:
    """Deterministic synthetic edge fleet at arbitrary scale.

    Devices draw a spec from ``spec_names`` (seeded) and land in a
    region round-robin — the same shape ``make_fleet`` produces, but
    array-native so a 10⁶-device fleet costs milliseconds, not a
    million dict inserts.  ``region_mix="shuffled"`` draws each device's
    region uniformly instead (the arrival order a real volunteer fleet
    presents: interleaved, not striped — what naive carve-ups trip on).
    """
    params = params or NetParams()
    rng = np.random.default_rng(seed)
    w = np.asarray(spec_weights if spec_weights is not None
                   else np.ones(len(spec_names)), float)
    pick = rng.choice(len(spec_names), size=n, p=w / w.sum())
    specs = [CATALOG[s] for s in spec_names]
    reg_sorted = np.array(sorted(regions))
    ridx = {r: i for i, r in enumerate(reg_sorted)}
    if region_mix == "shuffled":
        reg_map = np.array([ridx[r] for r in regions], np.int32)
        region_of = reg_map[rng.integers(0, len(regions), size=n)]
    elif region_mix == "round_robin":
        region_of = np.array([ridx[regions[i % len(regions)]]
                              for i in range(n)], np.int32)
    else:
        raise ValueError(f"unknown region_mix {region_mix!r}")
    eff = np.array([s.effective_flops for s in specs])[pick]
    acc_delay_v = params.access_latency_s + params.access_jitter_s
    wan_delay_v = params.wan_latency_s + params.wan_jitter_s
    carbon = np.array([_region_intensity(str(r)) for r in reg_sorted])
    # zero-padded decimal node ids keep string sort == numeric sort,
    # so ring orders stay stable under fleet growth
    width = len(str(max(n - 1, 1)))
    names = np.array([str(i).zfill(width) for i in range(n)])
    return FleetArrays(
        node_names=names, region_of=region_of, regions=reg_sorted,
        acc_bw=np.array([s.net_bw_Bps for s in specs])[pick],
        acc_delay=np.full(n, acc_delay_v),
        eff_flops=eff,
        power_active_w=np.array([s.power_active_w for s in specs])[pick],
        power_idle_w=np.array([s.power_idle_w for s in specs])[pick],
        power_comm_w=np.array([s.power_comm_w for s in specs])[pick],
        carbon_kg_per_kwh=carbon[region_of],
        wan_bw=np.full(len(reg_sorted), params.wan_bw_Bps),
        wan_delay=np.full(len(reg_sorted), wan_delay_v),
        params=params,
        spec_names=np.array([specs[p].name for p in pick]))
