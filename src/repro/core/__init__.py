"""The paper's contribution: carbon-aware decentralized foundation-model
training — carbon accounting, edge energy models, distributed-training
planners (idealized + DT-FM), and carbon/thermal/fault-aware orchestration.
"""
