"""Trip-count-aware HLO analysis for collective-byte accounting.

``compiled.as_text()`` prints each while-loop body ONCE, but the body
executes trip-count times (layer scans, microbatch accumulation), so a
naive textual sum undercounts collective bytes by the loop depth.  This
parser:

1. splits the HLO module into named computations,
2. sums collective result bytes per computation,
3. walks the call graph (while/call/fusion/conditional) multiplying
   while-body contributions by the loop trip count, which jax scans encode
   in the while *condition* computation as ``constant(N)`` fed to an
   iter < N compare.

The same walk yields per-op execution counts used in EXPERIMENTS.md
§Roofline (e.g. "all-gather x126 per step").
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s+\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_REF_RE = re.compile(
    r"(condition|body|to_apply|calls|true_computation|false_computation)"
    r"=%([\w\.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_COLL_RE = re.compile(
    r" (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\S.*?)\s+"
                     r"([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_dims(text: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    """First shape in ``text`` -> (dtype, dims); None if not an array type."""
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return None
    return dt, tuple(int(x) for x in dims.split(",") if x)


def _numel(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


class Computation:
    def __init__(self, name: str, is_entry: bool = False):
        self.name = name
        self.is_entry = is_entry
        self.lines: List[str] = []
        self.collectives: List[Tuple[str, int]] = []   # (op, result bytes)
        self.whiles: List[Tuple[str, str]] = []        # (condition, body)
        self.plain_calls: List[str] = []               # executed once per hit
        self.trip_hint: Dict[str, int] = {}            # body -> trip count
        self.fused_calls: set = set()                  # computations fused in
        self.flops: float = 0.0                        # dot flops, this comp
        self.bytes_accessed: float = 0.0               # operand+result bytes
        self.symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = {}

    # ---------------------------------------------------------- per-line
    def ingest(self, stripped: str) -> None:
        dm = _DEF_RE.match(stripped)
        if not dm:
            return
        name, result_type, op = dm.groups()
        shape = _parse_dims(result_type)
        if shape:
            self.symbols[name] = shape

        # operand text = between op( and the matching close paren (approx:
        # up to '), ' or end)
        args_start = stripped.find(op + "(") + len(op) + 1
        args_end = stripped.find(")", args_start)
        args_text = stripped[args_start:args_end] if args_end > 0 else ""
        operands = _OPERANDS_RE.findall(args_text)

        if op == "dot" and shape:
            cd = _CDIMS_RE.search(stripped)
            contract = 1
            if cd and operands:
                lhs = self.symbols.get(operands[0])
                if lhs:
                    for ax in (int(x) for x in cd.group(1).split(",") if x):
                        if ax < len(lhs[1]):
                            contract *= lhs[1][ax]
            self.flops += 2.0 * _numel(shape[1]) * contract

        # HBM-traffic proxy: operands + result of materializing ops
        if op in ("dot", "fusion", "convolution", "copy", "dynamic-slice",
                  "dynamic-update-slice", "all-reduce", "all-gather",
                  "reduce-scatter", "all-to-all", "collective-permute",
                  "all-reduce-start", "all-gather-start",
                  "collective-permute-start", "custom-call", "reduce",
                  "transpose", "sort", "scatter", "gather", "concatenate"):
            b = 0
            if shape:
                b += _numel(shape[1]) * _DTYPE_BYTES[shape[0]]
            for o in operands:
                s = self.symbols.get(o)
                if s:
                    b += _numel(s[1]) * _DTYPE_BYTES[s[0]]
            self.bytes_accessed += b


def parse_computations(hlo: str) -> Tuple[Dict[str, "Computation"], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in hlo.splitlines():
        stripped = raw.strip()
        m = _HDR_RE.match(raw.strip()) if stripped.endswith("{") else None
        if m and "->" in raw:
            cur = Computation(m.group(2), bool(m.group(1)))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        cur.lines.append(stripped)
        cur.ingest(stripped)
        cm = _COLL_RE.search(stripped)
        if cm and cm.group(2) != "-done":
            seg = stripped.split(" = ", 1)
            result_type = seg[1].split(f" {cm.group(1)}")[0] if len(seg) == 2 \
                else stripped
            cur.collectives.append((cm.group(1), _shape_bytes(result_type)))
        refs = dict()
        for kind, name in _REF_RE.findall(stripped):
            refs.setdefault(kind, name)
        if " while(" in stripped and "condition" in refs and "body" in refs:
            cur.whiles.append((refs["condition"], refs["body"]))
            tm = _TRIP_RE.search(stripped)
            if tm:
                cur.trip_hint[refs["body"]] = int(tm.group(1))
        else:
            is_fusion = " fusion(" in stripped
            for kind, name in _REF_RE.findall(stripped):
                cur.plain_calls.append(name)
                if is_fusion and kind == "calls":
                    cur.fused_calls.add(name)
    return comps, entry


def _trip_count(cond: Optional["Computation"]) -> int:
    if cond is None:
        return 1
    consts: List[int] = []
    for line in cond.lines:
        consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def collective_totals(hlo: str) -> Dict[str, Any]:
    comps, entry = parse_computations(hlo)
    memo: Dict[str, Tuple[Dict[str, int], Dict[str, int]]] = {}

    def walk(name: str, stack=()) -> Tuple[Dict[str, int], Dict[str, int]]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or name in stack:
            return {}, {}
        by_bytes: Dict[str, int] = {}
        by_count: Dict[str, int] = {}
        for op, b in c.collectives:
            by_bytes[op] = by_bytes.get(op, 0) + b
            by_count[op] = by_count.get(op, 0) + 1
        for cond_name, body_name in c.whiles:
            trips = _trip_count(comps.get(cond_name))
            bb, bc = walk(body_name, stack + (name,))
            for op, v in bb.items():
                by_bytes[op] = by_bytes.get(op, 0) + v * trips
            for op, v in bc.items():
                by_count[op] = by_count.get(op, 0) + v * trips
        for cal in c.plain_calls:
            bb, bc = walk(cal, stack + (name,))
            for op, v in bb.items():
                by_bytes[op] = by_bytes.get(op, 0) + v
            for op, v in bc.items():
                by_count[op] = by_count.get(op, 0) + v
        memo[name] = (by_bytes, by_count)
        return memo[name]

    by_bytes, by_count = walk(entry) if entry else ({}, {})
    return {"bytes_by_op": by_bytes, "counts": by_count,
            "total_bytes": sum(by_bytes.values())}


def compute_totals(hlo: str) -> Dict[str, float]:
    """Trip-count-aware FLOP and HBM-byte totals from per-device HLO text.

    FLOPs: every ``dot`` (2 x out-numel x contraction), anywhere in the call
    graph, multiplied by enclosing while-loop trip counts — this is what
    ``cost_analysis()`` misses (it counts loop bodies once).

    Bytes: operands+result of materializing top-level ops (fusion, dot,
    copy, collectives, ...).  Inner ops of a fusion are NOT charged bytes
    (they live in registers/VMEM), but inner dots ARE charged flops.
    """
    comps, entry = parse_computations(hlo)
    memo: Dict[Tuple[str, bool], Tuple[float, float]] = {}

    def walk(name: str, in_fusion: bool, stack=()) -> Tuple[float, float]:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None or name in stack:
            return 0.0, 0.0
        fl = c.flops
        by = 0.0 if in_fusion else c.bytes_accessed
        for cond_name, body_name in c.whiles:
            trips = c.trip_hint.get(body_name) or \
                _trip_count(comps.get(cond_name))
            f2, b2 = walk(body_name, in_fusion, stack + (name,))
            fl += f2 * trips
            by += b2 * trips
        for cal in c.plain_calls:
            f2, b2 = walk(cal, in_fusion or cal in c.fused_calls,
                          stack + (name,))
            fl += f2
            by += b2
        memo[key] = (fl, by)
        return memo[key]

    fl, by = walk(entry, False) if entry else (0.0, 0.0)
    return {"flops": fl, "bytes_accessed": by}


def loop_trip_counts(hlo: str) -> List[Tuple[str, int]]:
    """(body name, trip count) for every while loop — compile-plan sanity."""
    comps, _ = parse_computations(hlo)
    out = []
    for c in comps.values():
        for cond_name, body_name in c.whiles:
            out.append((body_name, _trip_count(comps.get(cond_name))))
    return out
