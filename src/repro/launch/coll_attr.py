"""Attribute collective bytes to source ops (trip-count-aware).

    PYTHONPATH=src python -m repro.launch.coll_attr --arch X --shape Y [...]

Buckets every collective's result bytes by the jax op_name metadata on its
HLO line — the §Perf microscope for "which op is moving these bytes".
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import re                # noqa: E402
from collections import defaultdict  # noqa: E402

from repro.launch import hlo_analysis as HA  # noqa: E402

_NAME_RE = re.compile(r'op_name="([^"]*)"')


def attribute(hlo: str, top: int = 25):
    comps, entry = HA.parse_computations(hlo)
    buckets = defaultdict(float)
    ops = defaultdict(float)

    coll_re = re.compile(
        r"^(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(\S.*?)\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")

    def walk(name, mult, stack=()):
        c = comps.get(name)
        if c is None or name in stack:
            return
        for ln in c.lines:
            m = coll_re.match(ln)
            if not m:
                continue
            b = HA._shape_bytes(m.group(1)) * mult
            tag = _NAME_RE.search(ln)
            tag = tag.group(1) if tag else "(untagged)"
            # strip trailing op ids, keep the semantic path
            tag = re.sub(r"\[[^\]]*\]", "", tag)
            buckets[f"{m.group(2)} :: {tag[:110]}"] += b
            ops[m.group(2)] += b
        for cond, body in c.whiles:
            trips = c.trip_hint.get(body) or HA._trip_count(comps.get(cond))
            walk(body, mult * trips, stack + (name,))
        for cal in c.plain_calls:
            walk(cal, mult, stack + (name,))

    walk(entry, 1.0)
    print("== by op ==")
    for k, v in sorted(ops.items(), key=lambda kv: -kv[1]):
        print(f"  {v/2**40:8.2f} TiB  {k}")
    print("== top sources ==")
    for k, v in sorted(buckets.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v/2**40:8.2f} TiB  {k}")


def main():
    from repro.launch import dryrun as DR
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    # reuse dryrun's lowering, but grab the HLO text
    import jax
    from repro import compat
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config, input_shape
    from repro.launch.mesh import make_production_mesh
    from repro.distributed import sharding as SH
    from repro.launch import specs as SP
    from repro.models import params as PM, model as M
    from repro.optim import adamw
    from repro.train.step import make_train_step
    from repro.serve.step import make_serve_step

    cfg = get_config(args.arch)
    shape = input_shape(args.shape)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    p_abs = PM.abstract_params(cfg)
    p_shard = SH.param_shardings(cfg, mesh, SH.DEFAULT_RULES)
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = adamw.OptConfig(moment_dtype=args.moment_dtype)
            opt_abs = jax.eval_shape(
                lambda p: adamw.init_opt_state(p, opt_cfg), p_abs)
            opt_shard = {"mu": p_shard, "nu": p_shard,
                         "step": NamedSharding(mesh, P())}
            batch = SP.input_specs(cfg, shape)
            b_shard = SH.batch_shardings(mesh, batch)
            step = make_train_step(cfg, opt_cfg, remat=args.remat,
                                   microbatches=args.microbatches)
            hlo = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                          out_shardings=(p_shard, opt_shard, None),
                          donate_argnums=(0, 1)).lower(
                p_abs, opt_abs, batch).compile().as_text()
        elif shape.kind == "prefill":
            batch = SP.input_specs(cfg, shape)
            batch.pop("labels", None)
            b_shard = SH.batch_shardings(mesh, batch)
            fn = lambda p, b: M.forward_logits(p, cfg, b)  # noqa: E731
            hlo = jax.jit(fn, in_shardings=(p_shard, b_shard)).lower(
                p_abs, batch).compile().as_text()
        else:
            raise SystemExit("decode attribution not wired; use train/prefill")
    attribute(hlo, args.top)


if __name__ == "__main__":
    main()
