"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

MUST set the placeholder-device flag before any other import (jax locks the
device count on first backend init).
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse       # noqa: E402
import json           # noqa: E402
import re             # noqa: E402
import time           # noqa: E402
from pathlib import Path  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs.registry import (INPUT_SHAPES, get_config, input_shape,  # noqa: E402
                                    list_archs, shape_applicable)
from repro.launch.hlo_analysis import collective_totals, compute_totals  # noqa: E402
from repro.distributed import sharding as SH  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import params as PM  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.serve.step import make_serve_step  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

RESULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(.+?)\s+(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> Dict[str, Any]:
    """Sum result bytes of every collective op in (per-device) HLO text."""
    per_op: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for line in hlo.splitlines():
        mm = _COLL_RE.search(line)
        if not mm:
            continue
        shapes_str, op = mm.groups()
        op = op.replace("-start", "")
        total = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for x in dims.split(","):
                if x:
                    n *= int(x)
            total += n * _DTYPE_BYTES[dt]
        per_op[op] = per_op.get(op, 0) + total
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": per_op, "counts": counts,
            "total_bytes": sum(per_op.values())}


def _opt_shardings(p_shard, mesh):
    return {"mu": p_shard, "nu": p_shard,
            "step": NamedSharding(mesh, P())}


def lower_case(arch: str, shape_name: str, *, multi_pod: bool = False,
               remat: str = "full", moment_dtype: str = "float32",
               rules_name: str = "default", microbatches: int = 8,
               donate: bool = True, moe_layout: str = "") -> Dict[str, Any]:
    import dataclasses
    cfg = get_config(arch)
    if moe_layout and cfg.moe.enabled:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, layout=moe_layout))
    shape = input_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = {"default": SH.DEFAULT_RULES, "tp_only": SH.TP_ONLY_RULES}[rules_name]

    p_abs = PM.abstract_params(cfg)
    p_shard = SH.param_shardings(cfg, mesh, rules)

    with compat.set_mesh(mesh):
        return _lower_compile_record(cfg, shape, mesh, rules, arch,
                                     shape_name, multi_pod, remat,
                                     moment_dtype, rules_name, donate,
                                     p_abs, p_shard, microbatches)


def _lower_compile_record(cfg, shape, mesh, rules, arch, shape_name,
                          multi_pod, remat, moment_dtype, rules_name,
                          donate, p_abs, p_shard, microbatches):
    t0 = time.time()
    if shape.kind in ("train",):
        opt_cfg = adamw.OptConfig(moment_dtype=moment_dtype)
        opt_abs = jax.eval_shape(lambda p: adamw.init_opt_state(p, opt_cfg),
                                 p_abs)
        opt_shard = _opt_shardings(p_shard, mesh)
        batch = SP.input_specs(cfg, shape)
        b_shard = SH.batch_shardings(mesh, batch)
        step = make_train_step(cfg, opt_cfg, remat=remat,
                               microbatches=microbatches)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, opt_shard, b_shard),
                         out_shardings=(p_shard, opt_shard, None),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(p_abs, opt_abs, batch)
    elif shape.kind == "prefill":
        batch = SP.input_specs(cfg, shape)
        batch.pop("labels", None)
        b_shard = SH.batch_shardings(mesh, batch)
        # chunked (flash) attention for prefill: the naive path materializes
        # the full S x S score tensor — 120 TiB/dev of exp/div/add at 32k
        # (EXPERIMENTS.md §Perf A2)
        fn = lambda p, b: M.forward_logits(p, cfg, b,   # noqa: E731
                                           attn_impl="chunked")
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(p_abs, batch)
    else:  # decode
        T = SP.cache_len(cfg, shape)
        cache_abs = M.abstract_cache(cfg, shape.global_batch, T)
        c_shard = SH.cache_shardings(cfg, mesh, cache_abs, shape.global_batch)
        dspec = SP.decode_specs(cfg, shape)
        serve = make_serve_step(cfg)
        b = shape.global_batch
        tok_shard = NamedSharding(mesh, SH.batch_spec(mesh, b))
        # enc-dec: cross-KV lives in the (pre-warmed) cache, so serve_step
        # never touches the raw encoder output (§Perf beyond-paper #6)
        args = [p_abs, cache_abs, dspec["tokens"], dspec["index"]]
        in_sh = [p_shard, c_shard,
                 NamedSharding(mesh, P(*SH.batch_spec(mesh, b), None)),
                 NamedSharding(mesh, P())]
        fn = serve
        jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_totals(hlo)          # trip-count-aware (hlo_analysis)
    ct = compute_totals(hlo)               # trip-count-aware flops/bytes

    n_chips = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips),
        "kind": shape.kind,
        "remat": remat, "moment_dtype": moment_dtype, "rules": rules_name,
        "microbatches": microbatches if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        # trip-count-aware walk of the per-device HLO (hlo_analysis):
        # cost_analysis() counts while bodies once, these do not
        "hlo_flops_per_device": ct["flops"],
        "hlo_bytes_per_device": ct["bytes_accessed"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "collectives": coll,
        "params_total": PM.count_params(cfg),
        "params_active": PM.count_params(cfg, active_only=True),
    }
    return rec


def run_and_save(arch: str, shape_name: str, tag: str = "", **kw
                 ) -> Dict[str, Any]:
    rec = lower_case(arch, shape_name, **kw)
    out_dir = RESULT_DIR if not tag else RESULT_DIR.parent / "perf"
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "multipod" if kw.get("multi_pod") else "pod"
    extra = f"_{tag}" if tag else ""
    if not tag and (kw.get("remat", "full") != "full"
                    or kw.get("moment_dtype", "float32") != "float32"
                    or kw.get("rules_name", "default") != "default"):
        extra = f"_{kw.get('remat','full')}_{kw.get('moment_dtype','float32')}" \
                f"_{kw.get('rules_name','default')}"
    rec["tag"] = tag
    out = out_dir / f"{arch}_{shape_name}_{suffix}{extra}.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] {arch} x {shape_name} ({rec['mesh']}): "
          f"compile {rec['compile_s']}s, "
          f"flops/dev {rec['hlo_flops_per_device']:.3e}, "
          f"mem temp {rec['memory']['temp_bytes']/2**30:.2f} GiB, "
          f"coll {rec['collectives']['total_bytes']/2**30:.3f} GiB -> {out.name}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="with --all: skip combos whose record already exists")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--tag", default="",
                    help="perf-iteration tag: save under experiments/perf/")
    ap.add_argument("--moe-layout", default="",
                    choices=["", "auto", "ep_full", "unconstrained"])
    args = ap.parse_args()

    kw = dict(multi_pod=args.multi_pod, remat=args.remat,
              moment_dtype=args.moment_dtype, rules_name=args.rules,
              microbatches=args.microbatches, tag=args.tag,
              moe_layout=args.moe_layout)
    if args.all:
        for arch in list_archs(assigned_only=True):
            for shape_name in INPUT_SHAPES:
                if not shape_applicable(arch, shape_name):
                    print(f"[dryrun] SKIP {arch} x {shape_name} "
                          f"(sub-quadratic attention required; see DESIGN.md)")
                    continue
                if args.skip_existing:
                    suffix = "multipod" if args.multi_pod else "pod"
                    if (RESULT_DIR / f"{arch}_{shape_name}_{suffix}.json").exists():
                        continue
                run_and_save(arch, shape_name, **kw)
    else:
        assert args.arch and args.shape
        if not shape_applicable(args.arch, args.shape):
            print(f"[dryrun] SKIP {args.arch} x {args.shape} (see DESIGN.md)")
            return
        run_and_save(args.arch, args.shape, **kw)


if __name__ == "__main__":
    main()
