"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

``input_specs(cfg, shape)`` returns the exact batch the train/prefill step
consumes; ``decode_specs`` the (tokens, index) pair for ``serve_step``.
Frontend stubs ([vlm]/[audio] carve-out): precomputed patch/frame embeddings
of the right shape stand in for the vision/audio encoders.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import InputShape
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def vlm_split(seq_len: int) -> Tuple[int, int]:
    """(vision tokens, text tokens) for a VLM sequence budget."""
    v = min(1024, seq_len // 4)
    return v, seq_len - v


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Batch spec for a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    emb_dt = jnp.dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        # whisper: geometry is fixed by the model (1500 frames, <=448 dec)
        S_dec = cfg.max_target_positions
        return {"frames": SDS((B, cfg.encoder_seq_len, d), emb_dt),
                "tokens": SDS((B, S_dec), jnp.int32),
                "labels": SDS((B, S_dec), jnp.int32)}
    if cfg.arch_type == "vlm":
        Sv, St = vlm_split(S)
        return {"tokens": SDS((B, St), jnp.int32),
                "vision_embeds": SDS((B, Sv, d), emb_dt),
                "labels": SDS((B, S), jnp.int32),
                "positions": SDS((3, B, S), jnp.int32)}
    return {"tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """(tokens, index[, enc]) specs for serve_step; cache comes separately."""
    B = shape.global_batch
    out: Dict[str, Any] = {"tokens": SDS((B, 1), jnp.int32),
                           "index": SDS((), jnp.int32)}
    if cfg.is_encoder_decoder:
        out["enc"] = SDS((B, cfg.encoder_seq_len, cfg.d_model),
                         jnp.dtype(cfg.compute_dtype))
    return out


def cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.is_encoder_decoder:
        return cfg.max_target_positions
    return shape.seq_len


def concrete_batch(cfg: ModelConfig, shape: InputShape, rng: jax.Array
                   ) -> Dict[str, jax.Array]:
    """Materialize a random batch matching input_specs (small shapes only)."""
    specs = input_specs(cfg, shape)
    out: Dict[str, jax.Array] = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32 and k in ("tokens",):
            out[k] = jax.random.randint(rng, s.shape, 0, cfg.vocab_size,
                                        jnp.int32)
        elif k == "labels":
            out[k] = jax.random.randint(rng, s.shape, 0, cfg.vocab_size,
                                        jnp.int32)
        elif k == "positions":
            S = s.shape[-1]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   s.shape[1:])
            out[k] = jnp.broadcast_to(pos[None], s.shape)
        else:
            out[k] = jax.random.normal(rng, s.shape, s.dtype)
    if cfg.arch_type == "vlm":
        # labels: mask the vision prefix
        Sv = out["vision_embeds"].shape[1]
        lbl = out["labels"]
        mask = jnp.arange(lbl.shape[1]) < Sv
        out["labels"] = jnp.where(mask[None, :], -1, lbl)
    return out
