"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the trainer loop for any registered architecture on whatever devices
exist.  ``--reduced`` (default on CPU) trains the smoke variant;
``--mesh data,model`` builds a local mesh from the visible devices so the
same entrypoint drives a laptop, an edge mesh simulation
(``--host-devices N``), or a real pod slice.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
        --host-devices 8 --mesh 2,4 --steps 50
"""

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots", "dots_no_batch"])
    ap.add_argument("--full", action="store_true",
                    help="train the FULL config (needs real accelerators)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fake host device count (CPU simulation)")
    ap.add_argument("--mesh", default="",
                    help="comma dims for a (data, model) mesh")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest complete checkpoint in "
                         "--checkpoint-dir before training; the "
                         "checkpoint may come from a DIFFERENT fleet "
                         "placement (layer-sliced shards are re-sliced "
                         "onto whatever runs now)")
    ap.add_argument("--device", default="laptop-m2pro",
                    help="energy-model device for the carbon ledger")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro import compat
    from repro.configs import get_config
    from repro.core.carbon.accounting import CarbonLedger
    from repro.core.energy.devices import get_device
    from repro.core.energy.monitor import ComponentModel, EnergyMonitor
    from repro.train.trainer import TrainerConfig, train

    cfg = get_config(args.arch if args.full else args.arch + "-smoke")
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")

    monitor = EnergyMonitor(ComponentModel.for_device(
        get_device(args.device)))
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume needs --checkpoint-dir")
    tc = TrainerConfig(steps=args.steps, batch=args.batch, seq_len=args.seq,
                       microbatches=args.microbatches, remat=args.remat,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every,
                       resume=args.resume)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(dims, ("data", "model")[: len(dims)])
        with compat.set_mesh(mesh):
            res = train(cfg, tc, monitor=monitor)
    else:
        res = train(cfg, tc, monitor=monitor)

    led = CarbonLedger()
    led.add_operational_wh("train", res.energy_wh)
    print(f"[train] final loss {res.final_loss:.4f}  "
          f"{res.steps_per_s:.2f} steps/s  "
          f"{res.energy_wh:.3f} Wh modelled  "
          f"{led.operational_kg*1000:.3f} gCO2e")


if __name__ == "__main__":
    main()
