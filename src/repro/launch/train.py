"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the trainer loop for any registered architecture on whatever devices
exist.  ``--reduced`` (default on CPU) trains the smoke variant;
``--mesh data,model`` builds a local mesh from the visible devices so the
same entrypoint drives a laptop, an edge mesh simulation
(``--host-devices N``), or a real pod slice.  ``--local-sgd`` switches to
the DiLoCo-style local-update loop (``--replicas`` × ``--inner-steps``);
``--async`` upgrades it to bounded-staleness async outer updates
(``--quorum`` / ``--staleness-bound``), and ``--straggler-frac`` /
``--crash-prob`` / ``--link-flap-prob`` inject a deterministic,
seed-replayable fault plan (``--fault-seed``).

Telemetry: ``--trace-out trace.json`` captures a Chrome-trace /
Perfetto timeline of every step phase (data / fwd_bwd_opt / outer-sync /
checkpoint, with J + gCO2e attached); ``--metrics-out metrics.jsonl``
writes the metrics registry (per-phase step-time histograms with
p50/p95/p99, loss/grad-norm distributions, byte counters).  Validate
either with ``python -m repro.obs.validate <file>``.

Health: ``--health-out health.jsonl`` attaches the streaming detectors
(stragglers / link degradation / loss spikes — the async quorum then
excludes *detected* stragglers) and writes their alert record;
``--slo tokens_per_s=500,gco2e=5`` adds burn-rate-monitored SLOs, with
a one-line verdict summary at the end of the run.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch opt-125m \
        --local-sgd --replicas 2 --inner-steps 8 --steps 32 \
        --trace-out trace.json --metrics-out metrics.jsonl
"""

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots", "dots_no_batch"])
    ap.add_argument("--full", action="store_true",
                    help="train the FULL config (needs real accelerators)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="fake host device count (CPU simulation)")
    ap.add_argument("--mesh", default="",
                    help="comma dims for a (data, model) mesh")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest complete checkpoint in "
                         "--checkpoint-dir before training; the "
                         "checkpoint may come from a DIFFERENT fleet "
                         "placement (layer-sliced shards are re-sliced "
                         "onto whatever runs now)")
    ap.add_argument("--device", default="laptop-m2pro",
                    help="energy-model device for the carbon ledger")
    ap.add_argument("--local-sgd", action="store_true",
                    help="run the DiLoCo local-update loop instead of "
                         "the plain trainer")
    ap.add_argument("--replicas", type=int, default=2,
                    help="local-SGD replica count")
    ap.add_argument("--inner-steps", type=int, default=8,
                    help="local-SGD inner steps per sync round (K)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="bounded-staleness async outer updates instead "
                         "of the barrier sync round (implies --local-sgd)")
    ap.add_argument("--quorum", type=int, default=0,
                    help="async: replicas required before an outer "
                         "update fires (0 = all replicas)")
    ap.add_argument("--staleness-bound", type=int, default=0,
                    help="async: drop + resync replicas more than S "
                         "outer versions stale (0 = lockstep)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed the deterministic fault-injection plan")
    ap.add_argument("--straggler-frac", type=float, default=0.0,
                    help="fraction of replicas slowed 4-8x by the plan")
    ap.add_argument("--crash-prob", type=float, default=0.0,
                    help="per-round crash probability per replica")
    ap.add_argument("--link-flap-prob", type=float, default=0.0,
                    help="per-round link flap probability per replica")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON timeline")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry as JSONL")
    ap.add_argument("--slo", default=None, metavar="K=V[,K=V...]",
                    help="monitor train SLOs and print end-of-run "
                         "verdicts; keys: tokens_per_s=<floor>, "
                         "staleness=<bound>, gco2e=<budget>, "
                         "horizon=<s> (e.g. --slo tokens_per_s=500,"
                         "gco2e=5)")
    ap.add_argument("--health-out", default=None,
                    help="attach the streaming health detectors "
                         "(straggler / link / loss-spike) and write "
                         "their alert record + SLO verdicts as JSONL")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro import compat
    from repro.configs import get_config
    from repro.core.carbon.accounting import CarbonLedger
    from repro.core.energy.devices import get_device
    from repro.core.energy.monitor import ComponentModel, EnergyMonitor
    from repro.obs import MetricsRegistry, Tracer, set_tracer
    from repro.train.trainer import TrainerConfig, train

    cfg = get_config(args.arch if args.full else args.arch + "-smoke")
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")

    registry = None
    if args.trace_out or args.metrics_out:
        # tracing on: span durations feed the registry's histograms, so
        # --metrics-out alone still gets per-phase step-time summaries
        registry = MetricsRegistry()
        set_tracer(Tracer(enabled=True, registry=registry,
                          process=f"train:{cfg.name}"))

    monitor = EnergyMonitor(ComponentModel.for_device(
        get_device(args.device)))
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume needs --checkpoint-dir")
    tc = TrainerConfig(steps=args.steps, batch=args.batch, seq_len=args.seq,
                       microbatches=args.microbatches, remat=args.remat,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every,
                       resume=args.resume)

    fault_plan = None
    if args.straggler_frac or args.crash_prob or args.link_flap_prob:
        from repro.core.faultinject import FaultPlan
        fault_plan = FaultPlan(seed=args.fault_seed,
                               straggler_frac=args.straggler_frac,
                               crash_prob=args.crash_prob,
                               link_flap_prob=args.link_flap_prob)

    health = slo = None
    if args.health_out is not None or args.slo is not None:
        from repro.obs import HealthMonitor, SLOMonitor, train_slos
        health = HealthMonitor(registry=registry)
        if args.slo is not None:
            kv = dict(p.split("=", 1) for p in args.slo.split(",") if p)
            slo = SLOMonitor(train_slos(
                tokens_per_s_floor=float(kv.get("tokens_per_s", 0)),
                staleness_bound=float(kv.get("staleness", 0)),
                gco2e_budget=float(kv.get("gco2e", 0)),
                horizon_s=float(kv.get("horizon", 3600.0))),
                registry=health.registry)

    def _run():
        if args.local_sgd or args.async_mode:
            from repro.train.local_sgd import (LocalSGDConfig,
                                               train_local_sgd)
            ls = LocalSGDConfig(replicas=args.replicas,
                                inner_steps=args.inner_steps,
                                checkpoint_dir=args.checkpoint_dir,
                                checkpoint_every_rounds=args.checkpoint_every,
                                resume=args.resume,
                                async_mode=args.async_mode,
                                quorum=args.quorum or None,
                                staleness_bound=args.staleness_bound)
            return train_local_sgd(
                cfg, tc, ls,
                monitor=None if args.async_mode else monitor,
                metrics=registry, fault_plan=fault_plan, health=health)
        return train(cfg, tc, monitor=monitor, metrics=registry,
                     health=health)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(dims, ("data", "model")[: len(dims)])
        with compat.set_mesh(mesh):
            res = _run()
    else:
        res = _run()

    led = CarbonLedger()
    led.add_operational_wh("train", res.energy_wh)
    rate = res.steps_per_s
    print(f"[train] final loss {res.final_loss:.4f}  "
          f"{rate:.2f} steps/s  "
          f"{res.energy_wh:.3f} Wh modelled  "
          f"{led.operational_kg*1000:.3f} gCO2e")
    if getattr(res, "mode", "") == "async":
        print(f"[train] async: {res.outer_updates} outer updates, "
              f"{res.dropped_stale} dropped stale, {res.resyncs} resyncs, "
              f"{res.crashes} crashes, "
              f"{res.virtual_tokens_per_s:.0f} virtual tok/s")
        if res.fault_counts:
            faults = " ".join(f"{k}={v}"
                              for k, v in sorted(res.fault_counts.items()))
            print(f"[train] faults: {faults}")

    if slo is not None:
        tok_s = getattr(res, "virtual_tokens_per_s", 0.0) \
            or rate * args.batch * args.seq
        slo.observe("train_tokens_per_s", tok_s)
        elapsed = getattr(res, "virtual_time_s", 0.0) \
            or args.steps / max(rate, 1e-9)
        slo.observe("train_gco2e", led.operational_kg * 1000, t=0.0)
        slo.observe("train_gco2e", 0.0, t=elapsed)
    if health is not None:
        print(f"[train] health: {health.summary_line()}")
    if slo is not None:
        print(f"[train] {slo.summary_line()}")
    if args.health_out:
        health.dump_jsonl(args.health_out, slo=slo,
                          meta={"arch": cfg.name, "steps": args.steps,
                                "local_sgd": bool(args.local_sgd
                                                  or args.async_mode)})
        print(f"[train] health record: {args.health_out}")

    if args.trace_out:
        from repro.obs import get_tracer
        get_tracer().save_chrome_trace(args.trace_out)
        print(f"[train] trace: {args.trace_out} "
              f"({len(get_tracer().events)} events — open in "
              "https://ui.perfetto.dev)")
    if args.metrics_out:
        registry.dump_jsonl(args.metrics_out,
                            meta={"arch": cfg.name, "steps": args.steps,
                                  "local_sgd": args.local_sgd,
                                  "backend": jax.default_backend()})
        print(f"[train] metrics: {args.metrics_out} "
              f"({len(registry.names())} metrics)")


if __name__ == "__main__":
    main()
