"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching paged-KV engine (``repro.serve.engine``) over
a mixed-length request set for any registered architecture (reduced
variant by default — CPU-runnable), prints tokens/s plus the per-token
energy/carbon estimate, and falls back to the dense ``greedy_generate``
path for architectures whose caches are not token-paged (SSM / MLA /
encoder-decoder).  A warmup generation runs before the timing window so
compile time never pollutes the tokens/s measurement.
"""

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=8,
                    help="number of requests in the mixed-length set")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max prompt length (requests vary 4..prompt-len)")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--device", default="tpu-v5e",
                    help="energy/carbon profile from core.energy.devices "
                         "(smartphone-sd888 | laptop-m2pro | cloud-a5000 | "
                         "cloud-h100 | tpu-v5e)")
    ap.add_argument("--attn-impl", default="gather",
                    choices=["gather", "pallas"],
                    help="paged decode attention: XLA gather or the Pallas "
                         "flash-decode kernel (interpret mode off-TPU)")
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "float32", "int8"],
                    help="paged KV pool dtype; int8 stores quantized pages "
                         "with per-vector fp32 scales (~0.53x the bf16 "
                         "bytes) and dequantizes inside the attention "
                         "gather")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens fed per engine step, shared across "
                         "prefilling slots (1 = token-by-token)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the prefix-cache index (every request "
                         "recomputes its full prompt)")
    ap.add_argument("--legacy", action="store_true",
                    help="force the dense greedy_generate path")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON timeline "
                         "(per-request queued/prefill/decode lifecycle "
                         "spans + engine steps + KV counters)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the engine metrics registry as JSONL "
                         "(TTFT p50/p99, per-request tokens/s, KV "
                         "utilization histograms)")
    ap.add_argument("--slo", default=None, metavar="K=V[,K=V...]",
                    help="monitor serve SLOs (the engine defers "
                         "admissions while the TTFT SLO burns); keys: "
                         "ttft=<p99 s>, itl=<inter-token p99 s>, "
                         "gco2e=<budget>, horizon=<s> (e.g. "
                         "--slo ttft=0.5,gco2e=2)")
    ap.add_argument("--health-out", default=None,
                    help="write the SLO verdicts + alert record as "
                         "JSONL")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro.configs import get_config
    from repro.core.energy.devices import get_device
    from repro.models import model as M
    from repro.models import params as P

    device = get_device(args.device)
    cfg = get_config(args.arch if args.full else args.arch + "-smoke")
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"(energy profile: {device.name})")
    params = P.init_params(cfg, jax.random.PRNGKey(0))

    if args.trace_out or args.metrics_out:
        from repro.obs import Tracer, set_tracer
        set_tracer(Tracer(enabled=True, process=f"serve:{cfg.name}"))

    slo = health = None
    if args.slo is not None or args.health_out is not None:
        from repro.obs import HealthMonitor, SLOMonitor, serve_slos
        health = HealthMonitor()
        kv = dict(p.split("=", 1)
                  for p in (args.slo or "").split(",") if p)
        slo = SLOMonitor(serve_slos(
            ttft_p99_s=float(kv.get("ttft", 0.5)),
            inter_token_p99_s=float(kv.get("itl", 0.2)),
            gco2e_budget=float(kv.get("gco2e", 0)),
            horizon_s=float(kv.get("horizon", 3600.0))),
            registry=health.registry)

    if not args.legacy and M.paged_decode_supported(cfg):
        _run_engine(args, cfg, params, device, slo=slo, health=health)
    else:
        _run_legacy(args, cfg, params, device)
        if slo is not None:
            print(f"[serve] {slo.summary_line()}")

    if args.health_out and health is not None:
        health.dump_jsonl(args.health_out, slo=slo,
                          meta={"arch": cfg.name,
                                "requests": args.batch})
        print(f"[serve] health record: {args.health_out}")

    if args.trace_out:
        from repro.obs import get_tracer
        get_tracer().save_chrome_trace(args.trace_out)
        print(f"[serve] trace: {args.trace_out} "
              f"({len(get_tracer().events)} events — open in "
              "https://ui.perfetto.dev)")


def _mixed_requests(args, cfg, tag: str):
    import jax
    from repro.serve.engine import Request
    lens = [4 + (7 * i) % max(args.prompt_len - 3, 1)
            for i in range(args.batch)]
    reqs = []
    for i, L in enumerate(lens):
        toks = jax.random.randint(jax.random.PRNGKey(100 + i), (L,), 0,
                                  cfg.vocab_size)
        reqs.append(Request(uid=f"{tag}{i}", prompt=list(map(int, toks)),
                            max_new=args.max_new))
    return reqs


def _run_engine(args, cfg, params, device, slo=None,
                health=None) -> None:
    from repro.serve.engine import EngineConfig, ServeEngine
    from repro.serve.paged_cache import blocks_for

    block = 16
    per_seq = blocks_for(args.prompt_len + args.max_new, block) + 1
    ecfg = EngineConfig(max_slots=min(args.batch, 8), block_size=block,
                        num_blocks=per_seq * min(args.batch, 8) + 2,
                        max_blocks_per_seq=per_seq,
                        attn_impl=args.attn_impl,
                        cache_dtype=args.kv_dtype,
                        prefill_chunk=args.prefill_chunk,
                        prefix_sharing=not args.no_prefix_sharing)
    engine = ServeEngine(params, cfg, ecfg, device=device, slo=slo)
    # warmup compiles BOTH step shapes (C=1 decode + C=chunk mixed) and
    # the sampler; reset_stats() then zeroes the EnergyMonitor so the
    # reported J/token prices serving, not XLA compilation
    engine.warmup()
    engine.reset_stats()

    engine.run(_mixed_requests(args, cfg, "r"))
    s = engine.stats()
    print(f"[serve] engine: {int(s['tokens_generated'])} tokens in "
          f"{engine.wall_s:.2f}s ({s['tokens_per_s']:.1f} tok/s, "
          f"{int(s['steps'])} steps, {ecfg.max_slots} slots)")
    if "ttft_p50_s" in s:
        print(f"[serve] TTFT p50 {s['ttft_p50_s']*1e3:.1f} ms / "
              f"p99 {s['ttft_p99_s']*1e3:.1f} ms")
    if args.metrics_out:
        import jax
        engine.metrics.dump_jsonl(
            args.metrics_out,
            meta={"arch": cfg.name, "requests": args.batch,
                  "max_new": args.max_new, "attn_impl": args.attn_impl,
                  "backend": jax.default_backend()})
        print(f"[serve] metrics: {args.metrics_out} "
              f"({len(engine.metrics.names())} metrics)")
    print(f"[serve] paged KV: peak {s['peak_cache_bytes']/1e6:.2f} MB of "
          f"{s['pool_bytes']/1e6:.2f} MB pool "
          f"(peak frag {s['frag_tokens_peak']:.0f} tokens, "
          f"peak util {100*s['utilization_peak']:.0f}%)")
    print(f"[serve] fast path: prefix hit rate "
          f"{100*s['prefix_hit_rate']:.0f}% "
          f"({int(s['prefix_hit_tokens'])} tokens), "
          f"{int(s['cow_forks_total'])} CoW forks, "
          f"{s['kv_bytes_saved']/1e6:.2f} MB KV saved "
          f"(chunk={ecfg.prefill_chunk}, kv={ecfg.cache_dtype})")
    print(f"[serve] energy ({device.name}): {s['energy_j']:.2f} J "
          f"({s['j_per_token']:.3f} J/token, {s['carbon_g']:.4f} gCO2e)")
    if slo is not None:
        # carbon spend paces against the budget over the serving window
        slo.observe("serve_gco2e", s["carbon_g"], t=0.0)
        slo.observe("serve_gco2e", 0.0, t=max(engine.wall_s, 1e-9))
        deferred = int(engine.metrics.counter(
            "serve/admission_deferred").value)
        print(f"[serve] {slo.summary_line()} | admissions deferred "
              f"under burn: {deferred}")
    if health is not None:
        print(f"[serve] health: {health.summary_line()}")


def _run_legacy(args, cfg, params, device) -> None:
    import time

    import jax
    import jax.numpy as jnp
    from repro.core import flops as F
    from repro.models import model as M

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        enc = M.encoder_forward(params, cfg, frames, {})

    from repro.serve.step import greedy_generate
    # warmup: same shapes, compile outside the timing window (the cached
    # jitted step makes the timed run reuse this compile)
    greedy_generate(params, cfg, prompt, max_new=2,
                    cache_len=args.prompt_len + args.max_new,
                    enc=enc).block_until_ready()

    from repro.obs import get_tracer
    t0 = time.time()
    with get_tracer().span("greedy_generate", "serve", batch=args.batch,
                           max_new=args.max_new):
        out = greedy_generate(params, cfg, prompt, max_new=args.max_new,
                              enc=enc)
        out.block_until_ready()
    wall = time.time() - t0
    n_new = args.batch * args.max_new
    dec_flops = sum(
        F.decode_flops(cfg, args.batch, args.prompt_len + i)
        for i in range(args.max_new))
    print(f"[serve] legacy dense: {n_new} tokens in {wall:.2f}s "
          f"({n_new/wall:.1f} tok/s); analytic decode "
          f"{dec_flops/1e9:.2f} GFLOP "
          f"({device.name} roofline: "
          f"{dec_flops/device.peak_flops*1e3:.3f} ms compute-bound)")
    print(f"[serve] sample: {list(map(int, out[0, -10:]))}")
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        reg.counter("serve/tokens").inc(n_new)
        reg.histogram("serve/tokens_per_s", lo=1e-3, hi=1e6) \
            .observe(n_new / wall)
        reg.dump_jsonl(args.metrics_out,
                       meta={"arch": cfg.name, "path": "legacy"})


if __name__ == "__main__":
    main()
