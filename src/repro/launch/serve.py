"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Prefill + batched greedy decode for any registered architecture (reduced
variant by default — CPU-runnable).  Prints tokens/s and the decode-side
energy/carbon estimate, mirroring what the decode dry-run shapes lower.
"""

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core import flops as F
    from repro.core.energy.devices import TPU_V5E
    from repro.models import model as M
    from repro.models import params as P
    from repro.serve.step import greedy_generate

    cfg = get_config(args.arch if args.full else args.arch + "-smoke")
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        enc = M.encoder_forward(params, cfg, frames, {})

    t0 = time.time()
    out = greedy_generate(params, cfg, prompt, max_new=args.max_new,
                          enc=enc)
    out.block_until_ready()
    wall = time.time() - t0
    n_new = args.batch * args.max_new
    dec_flops = sum(
        F.decode_flops(cfg, args.batch, args.prompt_len + i)
        for i in range(args.max_new))
    print(f"[serve] {n_new} tokens in {wall:.2f}s "
          f"({n_new/wall:.1f} tok/s); analytic decode "
          f"{dec_flops/1e9:.2f} GFLOP "
          f"(v5e roofline: {dec_flops/TPU_V5E.peak_flops*1e3:.3f} ms "
          f"compute-bound)")
    print(f"[serve] sample: {list(map(int, out[0, -10:]))}")


if __name__ == "__main__":
    main()
