"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init, and smoke
tests must see 1 CPU device while the dry-run sees 512 placeholders).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip v5e pod, or 2 pods = 512 chips over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_edge_mesh(num_stages: int, data_parallel: int = 1):
    """Edge-fleet mesh for DT-FM pipeline runs: (data, stage)."""
    return jax.make_mesh((data_parallel, num_stages), ("data", "stage"))


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): 1-D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
