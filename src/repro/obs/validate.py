"""Schema validation for the telemetry artifacts CI uploads.

Usage::

    python -m repro.obs.validate trace.json metrics.jsonl [...]

``.json`` files are validated as Chrome trace-event JSON (the format
https://ui.perfetto.dev loads): a ``traceEvents`` list whose entries
carry ``ph``/``ts``/``pid``/``tid``, with ``dur`` on complete (``X``)
events.  ``.jsonl`` files are validated as either a metrics dump (lines
of ``{"record": "metric", "name", "type", ...}`` with histogram
summaries carrying count/sum and percentiles when non-empty), a raw
trace event log (lines of ``{name, ph, ts_us, dur_us, track, args}``),
or a health artifact (``--health-out``: alert / slo-verdict /
health_summary records).  Fault (``fault.<kind>``), alert
(``alert.<kind>``) and SLO (``slo.breach``/``slo.recovered``) instants
are schema-checked wherever they appear.  Exits non-zero, naming the
offending line/event, on any violation.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict

_PHASES = {"X", "i", "C", "M", "b", "e", "n"}

# alert.<kind> instants HealthMonitor may emit (cat "alert")
_ALERT_KINDS = ("straggler", "straggler_cleared", "link_degraded",
                "loss_spike", "divergence")
# slo.* instants SLOMonitor may emit (cat "slo")
_SLO_NAMES = ("slo.breach", "slo.recovered")


def _check_alert_event(path: str, where: str, rec: Dict[str, Any]) -> bool:
    """Alert-event schema: every ``cat == "alert"`` record must be named
    ``alert.<kind>`` with a known kind and carry ``entity`` +
    ``detector`` in its args (what :class:`repro.obs.health.
    HealthMonitor` emits)."""
    if rec.get("cat") != "alert":
        return False
    name = rec.get("name", "")
    if not (isinstance(name, str) and name.startswith("alert.")
            and name[len("alert."):] in _ALERT_KINDS):
        raise ValueError(f"{path}: {where} alert event has bad name "
                         f"{name!r} (want 'alert.<kind>', kind in "
                         f"{_ALERT_KINDS})")
    args = rec.get("args")
    if not isinstance(args, dict) or "entity" not in args \
            or "detector" not in args:
        raise ValueError(f"{path}: {where} alert event {name!r} args "
                         "missing 'entity'/'detector'")
    return True


def _check_slo_event(path: str, where: str, rec: Dict[str, Any]) -> bool:
    """SLO-event schema: every ``cat == "slo"`` record must be a
    ``slo.breach``/``slo.recovered`` instant carrying the ``slo`` name
    and numeric ``burn`` in its args (what :class:`repro.obs.slo.
    SLOMonitor` emits)."""
    if rec.get("cat") != "slo":
        return False
    name = rec.get("name", "")
    if name not in _SLO_NAMES:
        raise ValueError(f"{path}: {where} slo event has bad name "
                         f"{name!r} (want one of {_SLO_NAMES})")
    args = rec.get("args")
    if not isinstance(args, dict) or "slo" not in args:
        raise ValueError(f"{path}: {where} slo event {name!r} args "
                         "missing 'slo'")
    if not isinstance(args.get("burn"), (int, float)):
        raise ValueError(f"{path}: {where} slo event {name!r} args "
                         "missing numeric 'burn'")
    return True


def _check_fault_event(path: str, where: str, rec: Dict[str, Any]) -> bool:
    """Fault-event schema: every ``cat == "fault"`` record must be named
    ``fault.<kind>`` and carry the affected ``entity`` in its args (what
    :class:`repro.core.faultinject.FaultInjector` emits)."""
    if rec.get("cat") != "fault":
        return False
    name = rec.get("name", "")
    if not (isinstance(name, str) and name.startswith("fault.")
            and len(name) > len("fault.")):
        raise ValueError(f"{path}: {where} fault event has bad name "
                         f"{name!r} (want 'fault.<kind>')")
    args = rec.get("args")
    if not isinstance(args, dict) or "entity" not in args:
        raise ValueError(f"{path}: {where} fault event {name!r} args "
                         "missing 'entity'")
    return True


def validate_chrome_trace(path: str) -> Dict[str, int]:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "traceEvents" not in data:
        raise ValueError(f"{path}: not object-format chrome trace "
                         "(missing traceEvents)")
    events = data["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError(f"{path}: traceEvents empty or not a list")
    counts: Dict[str, int] = {}
    for i, e in enumerate(events):
        for key in ("ph", "pid", "tid", "name"):
            if key not in e:
                raise ValueError(f"{path}: event {i} missing {key!r}: {e}")
        ph = e["ph"]
        if ph not in _PHASES:
            raise ValueError(f"{path}: event {i} unknown ph {ph!r}")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"{path}: event {i} bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{path}: event {i} (X) bad dur {dur!r}")
        if _check_fault_event(path, f"event {i}", e):
            counts["fault"] = counts.get("fault", 0) + 1
        if _check_alert_event(path, f"event {i}", e):
            counts["alert"] = counts.get("alert", 0) + 1
        if _check_slo_event(path, f"event {i}", e):
            counts["slo"] = counts.get("slo", 0) + 1
        counts[ph] = counts.get(ph, 0) + 1
    if counts.get("X", 0) == 0:
        raise ValueError(f"{path}: no complete (X) span events")
    return counts


def validate_metrics_jsonl(path: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty metrics/event log")
    for i, ln in enumerate(lines):
        try:
            rec = json.loads(ln)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}: line {i + 1} not JSON: {e}")
        if not isinstance(rec, dict):
            raise ValueError(f"{path}: line {i + 1} not an object")
        if rec.get("record") == "meta":
            counts["meta"] = counts.get("meta", 0) + 1
        elif rec.get("record") == "metric":
            for key in ("name", "type"):
                if key not in rec:
                    raise ValueError(f"{path}: line {i + 1} missing "
                                     f"{key!r}: {rec}")
            if rec["type"] == "histogram":
                if "count" not in rec or "sum" not in rec:
                    raise ValueError(f"{path}: line {i + 1} histogram "
                                     "missing count/sum")
                if rec["count"] > 0:
                    for p in ("p50", "p95", "p99"):
                        if p not in rec:
                            raise ValueError(f"{path}: line {i + 1} "
                                             f"non-empty histogram "
                                             f"missing {p}")
            elif rec["type"] in ("counter", "gauge"):
                if "value" not in rec:
                    raise ValueError(f"{path}: line {i + 1} "
                                     f"{rec['type']} missing value")
            else:
                raise ValueError(f"{path}: line {i + 1} unknown metric "
                                 f"type {rec['type']!r}")
            counts["metric"] = counts.get("metric", 0) + 1
        elif rec.get("record") == "alert":
            # --health-out artifact: one line per HealthMonitor alert
            for key in ("kind", "detector", "entity", "value", "ts_s"):
                if key not in rec:
                    raise ValueError(f"{path}: line {i + 1} alert "
                                     f"record missing {key!r}")
            if rec["kind"] not in _ALERT_KINDS:
                raise ValueError(f"{path}: line {i + 1} alert record "
                                 f"unknown kind {rec['kind']!r}")
            counts["alert"] = counts.get("alert", 0) + 1
        elif rec.get("record") == "slo":
            # --health-out artifact: one SLO verdict line per spec
            for key in ("slo", "kind", "target", "worst_burn", "ok"):
                if key not in rec:
                    raise ValueError(f"{path}: line {i + 1} slo "
                                     f"record missing {key!r}")
            counts["slo"] = counts.get("slo", 0) + 1
        elif rec.get("record") == "health_summary":
            for key in ("alerts_total", "alerts_by_kind", "stragglers"):
                if key not in rec:
                    raise ValueError(f"{path}: line {i + 1} "
                                     f"health_summary missing {key!r}")
            counts["health_summary"] = counts.get("health_summary", 0) + 1
        elif "ph" in rec and "ts_us" in rec:      # raw trace event log
            if _check_fault_event(path, f"line {i + 1}", rec):
                counts["fault"] = counts.get("fault", 0) + 1
            if _check_alert_event(path, f"line {i + 1}", rec):
                counts["alert"] = counts.get("alert", 0) + 1
            if _check_slo_event(path, f"line {i + 1}", rec):
                counts["slo"] = counts.get("slo", 0) + 1
            counts["event"] = counts.get("event", 0) + 1
        else:
            raise ValueError(f"{path}: line {i + 1} unrecognized record: "
                             f"{rec}")
    return counts


_BENCH_META_KEYS = ("commit", "timestamp_utc", "jax_version", "backend")


def validate_bench_json(path: str) -> Dict[str, int]:
    """Schema check for ``BENCH_*.json`` artifacts (what
    ``benchmarks.common.write_bench_json`` emits): a provenance ``meta``
    stamp (commit, UTC timestamp, jax version, backend) plus — when the
    benchmark embeds its acceptance gates — a non-empty ``claims`` list
    whose entries carry text/value/lo/hi and all hold (``ok``)."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: bench artifact is not a JSON object")
    meta = data.get("meta")
    if not isinstance(meta, dict):
        raise ValueError(f"{path}: missing provenance 'meta' object")
    for key in _BENCH_META_KEYS:
        if not meta.get(key):
            raise ValueError(f"{path}: meta missing/empty {key!r}")
    counts = {"meta": 1}
    if "claims" in data:
        claims = data["claims"]
        if not isinstance(claims, list) or not claims:
            raise ValueError(f"{path}: 'claims' empty or not a list")
        for i, c in enumerate(claims):
            for key in ("text", "value", "lo", "hi", "ok"):
                if key not in c:
                    raise ValueError(f"{path}: claim {i} missing {key!r}")
            if not (c["lo"] <= c["value"] <= c["hi"]) or not c["ok"]:
                raise ValueError(f"{path}: claim {i} FAILED: "
                                 f"{c['text']!r} derived {c['value']:.4g} "
                                 f"(accept [{c['lo']:.4g}, {c['hi']:.4g}])")
        counts["claim"] = len(claims)
    return counts


def validate(path: str) -> Dict[str, int]:
    if path.endswith(".jsonl"):
        return validate_metrics_jsonl(path)
    with open(path) as f:
        head = json.load(f)
    if isinstance(head, dict) and "traceEvents" not in head \
            and "meta" in head:
        return validate_bench_json(path)
    return validate_chrome_trace(path)


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m repro.obs.validate FILE [FILE ...]")
        return 2
    rc = 0
    for p in paths:
        try:
            counts = validate(p)
        except (OSError, ValueError) as e:
            print(f"[obs.validate] FAIL {e}")
            rc = 1
            continue
        detail = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        print(f"[obs.validate] OK {p}: {detail}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
