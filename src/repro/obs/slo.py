"""Declarative SLOs with multi-window burn-rate evaluation.

An :class:`SLOSpec` states an objective the fleet owes its users —
"serve TTFT p99 ≤ 250 ms", "training sustains ≥ 50k tokens/s",
"≤ 2 kgCO2e for this run" — and an :class:`SLOMonitor` evaluates a set
of them online against the same observation streams the metrics
registry already sees.

Evaluation follows the SRE multi-window burn-rate recipe rather than a
naive threshold: for event SLOs (latency, staleness) the *burn rate* is
``bad_fraction / error_budget`` over a window — burn 1.0 means the
error budget is being consumed exactly as provisioned, burn 10 means
ten times too fast — and a breach fires only when **both** a fast and a
slow window burn above the threshold (the fast window gives detection
latency, the slow window keeps one unlucky request from paging).
Budget SLOs (gCO2e, joules) instead compare spend rate against a
horizon: ``(spent / budget) / (elapsed / horizon)``.

Transitions emit schema-validated ``slo.breach`` / ``slo.recovered``
instants (cat ``slo``) so breaches sit on the same timeline as the
spans that caused them, and consumers poll :meth:`SLOMonitor.burning`
to *act* — the serve engine tightens admission while the TTFT SLO
burns, which is the observability loop closing into the runtime.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

SLO_KINDS = ("latency", "throughput", "budget")


@dataclass(frozen=True)
class SLOSpec:
    """One declarative objective.

    kind="latency":    observations are durations (s); an observation is
        *bad* when it exceeds ``target``; ``objective`` is the promised
        good fraction (0.99 → p99 ≤ target).  Staleness budgets are the
        same shape with staleness as the "latency".
    kind="throughput": observations are rates; *bad* when below
        ``target`` (a floor, e.g. train tokens/s).
    kind="budget":     observations are monotone cumulative spend
        (e.g. gCO2e); burn compares spend pace vs ``target`` over
        ``horizon_s``.
    """
    name: str
    kind: str
    target: float
    objective: float = 0.99          # good fraction (event SLOs)
    fast_window: int = 32            # observations (event SLOs)
    slow_window: int = 256
    burn_threshold: float = 2.0      # breach when both windows ≥ this
    horizon_s: float = 0.0           # budget SLOs: provisioned horizon
    description: str = ""

    def __post_init__(self):
        if self.kind not in SLO_KINDS:
            raise ValueError(f"unknown SLO kind: {self.kind!r}")
        if self.kind == "budget" and self.horizon_s <= 0:
            raise ValueError("budget SLO needs horizon_s > 0")
        if not (0.0 < self.objective < 1.0):
            raise ValueError("objective must be in (0, 1)")


class _WindowBurn:
    """Bad-fraction burn over a bounded observation window."""

    __slots__ = ("buf", "bad")

    def __init__(self, size: int):
        self.buf: Deque[bool] = deque(maxlen=size)
        self.bad = 0

    def push(self, is_bad: bool) -> None:
        if len(self.buf) == self.buf.maxlen and self.buf[0]:
            self.bad -= 1
        self.buf.append(is_bad)
        if is_bad:
            self.bad += 1

    def burn(self, error_budget: float) -> float:
        if not self.buf:
            return 0.0
        return (self.bad / len(self.buf)) / error_budget


class _SLOState:
    __slots__ = ("spec", "fast", "slow", "breached", "worst_burn",
                 "observations", "bad_total", "spent", "t0", "last_t")

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self.fast = _WindowBurn(spec.fast_window)
        self.slow = _WindowBurn(spec.slow_window)
        self.breached = False
        self.worst_burn = 0.0
        self.observations = 0
        self.bad_total = 0
        self.spent = 0.0          # budget SLOs: cumulative spend
        self.t0: Optional[float] = None
        self.last_t: Optional[float] = None

    def burn(self) -> float:
        spec = self.spec
        if spec.kind == "budget":
            if self.t0 is None or self.last_t is None \
                    or self.last_t <= self.t0 or spec.target <= 0:
                return 0.0
            elapsed = self.last_t - self.t0
            pace = (self.spent / spec.target) / (elapsed / spec.horizon_s)
            return pace
        budget = 1.0 - spec.objective
        # breach requires BOTH windows hot; report the min as the
        # effective (multi-window) burn
        return min(self.fast.burn(budget), self.slow.burn(budget))


class SLOMonitor:
    """Evaluates a set of :class:`SLOSpec` against observation streams.

    ``observe(name, value, t=...)`` feeds one observation into the SLO's
    windows; breach/recovery transitions are emitted as ``slo.breach`` /
    ``slo.recovered`` instants (cat ``slo``, args ``slo``/``burn``/
    ``target``) and counted in ``slo/breaches``.  ``burning(name)`` is
    the runtime's control signal."""

    def __init__(self, specs, *, registry=None, tracer=None):
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.trace import get_tracer
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.states: Dict[str, _SLOState] = {}
        for spec in specs:
            if spec.name in self.states:
                raise ValueError(f"duplicate SLO name: {spec.name!r}")
            self.states[spec.name] = _SLOState(spec)
        self.events: List[Dict[str, Any]] = []

    def spec(self, name: str) -> SLOSpec:
        return self.states[name].spec

    # ---------------------------------------------------------------- feed
    def observe(self, name: str, value: float, *,
                t: Optional[float] = None) -> Optional[str]:
        """Feed one observation; returns "breach"/"recovered" on a
        transition, else None.  Unknown names are ignored (producers
        emit unconditionally; the spec set decides what is monitored)."""
        st = self.states.get(name)
        if st is None:
            return None
        spec = st.spec
        if not math.isfinite(value):
            return None
        st.observations += 1
        if spec.kind == "budget":
            st.spent += value
            now = t if t is not None else self.tracer.now_s()
            if st.t0 is None:
                st.t0 = now
            st.last_t = now
        else:
            bad = (value > spec.target) if spec.kind == "latency" \
                else (value < spec.target)
            if bad:
                st.bad_total += 1
            st.fast.push(bad)
            st.slow.push(bad)
        return self._transition(st, t)

    def _transition(self, st: _SLOState,
                    t: Optional[float]) -> Optional[str]:
        burn = st.burn()
        st.worst_burn = max(st.worst_burn, burn)
        spec = st.spec
        hot = burn >= spec.burn_threshold
        if spec.kind != "budget" and len(st.slow.buf) < spec.fast_window:
            hot = False     # not enough signal to page on yet
        if hot and not st.breached:
            st.breached = True
            self._emit("slo.breach", spec, burn, t)
            return "breach"
        if st.breached and not hot \
                and burn < 0.5 * spec.burn_threshold:   # hysteresis
            st.breached = False
            self._emit("slo.recovered", spec, burn, t)
            return "recovered"
        return None

    def _emit(self, name: str, spec: SLOSpec, burn: float,
              t: Optional[float]) -> None:
        self.tracer.instant(name, "slo", track="health", ts_s=t,
                            slo=spec.name, kind=spec.kind,
                            burn=round(burn, 4), target=spec.target,
                            objective=spec.objective)
        self.registry.counter(
            "slo/breaches" if name == "slo.breach"
            else "slo/recoveries").inc(1)
        self.events.append({
            "event": name, "slo": spec.name, "burn": round(burn, 4),
            "ts_s": t if t is not None else self.tracer.now_s()})

    # ------------------------------------------------------------- verdicts
    def burning(self, name: str) -> bool:
        st = self.states.get(name)
        return bool(st is not None and st.breached)

    def burn_rate(self, name: str) -> float:
        st = self.states.get(name)
        return st.burn() if st is not None else 0.0

    def worst(self) -> Tuple[str, float]:
        """(slo_name, worst_burn) across all SLOs; ("-", 0.0) if none."""
        if not self.states:
            return "-", 0.0
        name = max(self.states, key=lambda n: self.states[n].worst_burn)
        return name, self.states[name].worst_burn

    def verdicts(self) -> List[Dict[str, Any]]:
        out = []
        for name, st in sorted(self.states.items()):
            out.append({
                "slo": name, "kind": st.spec.kind,
                "target": st.spec.target,
                "objective": st.spec.objective,
                "observations": st.observations,
                "bad_total": st.bad_total,
                "spent": round(st.spent, 6),
                "burn": round(st.burn(), 4),
                "worst_burn": round(st.worst_burn, 4),
                "breached_now": st.breached,
                "ok": st.worst_burn < st.spec.burn_threshold,
            })
        return out

    def summary_line(self) -> str:
        name, worst = self.worst()
        parts = []
        for v in self.verdicts():
            parts.append(f"{v['slo']}:{'OK' if v['ok'] else 'BREACH'}")
        return (f"slo: {' '.join(parts) or '-'} | worst burn: "
                f"{name}={worst:.2f}")


# --------------------------------------------------------------------------
# Stock SLO sets for the two launchers.  Targets are knobs, not truth —
# the launchers override them from the CLI.

def serve_slos(*, ttft_p99_s: float = 0.5, inter_token_p99_s: float = 0.2,
               gco2e_budget: float = 0.0, horizon_s: float = 3600.0
               ) -> List[SLOSpec]:
    specs = [
        SLOSpec("serve_ttft", "latency", ttft_p99_s, objective=0.99,
                fast_window=16, slow_window=128,
                description="time-to-first-token p99"),
        SLOSpec("serve_inter_token", "latency", inter_token_p99_s,
                objective=0.99, fast_window=32, slow_window=256,
                description="inter-token latency p99"),
    ]
    if gco2e_budget > 0:
        specs.append(SLOSpec("serve_gco2e", "budget", gco2e_budget,
                             horizon_s=horizon_s,
                             description="serve carbon budget"))
    return specs


def train_slos(*, tokens_per_s_floor: float = 0.0,
               staleness_bound: float = 0.0,
               gco2e_budget: float = 0.0, horizon_s: float = 3600.0
               ) -> List[SLOSpec]:
    specs = []
    if tokens_per_s_floor > 0:
        specs.append(SLOSpec(
            "train_tokens_per_s", "throughput", tokens_per_s_floor,
            objective=0.9, fast_window=8, slow_window=32,
            description="training throughput floor"))
    if staleness_bound > 0:
        specs.append(SLOSpec(
            "train_staleness", "latency", staleness_bound,
            objective=0.9, fast_window=8, slow_window=32,
            description="outer-update staleness budget"))
    if gco2e_budget > 0:
        specs.append(SLOSpec("train_gco2e", "budget", gco2e_budget,
                             horizon_s=horizon_s,
                             description="train carbon budget"))
    return specs
