"""Offline trace analytics: `python -m repro.obs.analyze`.

The online half of this PR (health.py / slo.py) reacts while the fleet
runs; this is the postmortem half — it reads the Chrome-trace / JSONL
artifacts every launcher already writes and answers the questions an
operator asks after the fact:

* ``rollup``   — where did the time go, grouped by span name (or cat,
  track, or any ``args`` key such as the region) — count / total /
  mean / p95 / max per group.
* ``top``      — the k slowest individual spans, with attribution.
* ``critical`` — per-round critical-path breakdown: for each ``round``
  (or other parent) span, how its child phases stack up against the
  parent wall time and how much is uncovered gap.
* ``diff``     — two runs side by side, per span name: count and total
  deltas, sorted by |Δtotal| — the "what regressed" view.
* ``alerts``   — alert / SLO / fault instants in timeline order (reads
  the ``--health-out`` artifact or any trace with instants).

Everything operates on the normalized event list from
:func:`load_events`, which accepts both artifact shapes (Chrome JSON
object with ``traceEvents`` and JSONL with ``record`` wrappers) so one
CLI serves every artifact the repo produces.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Tuple


# --------------------------------------------------------------------------
# loading / normalization

def load_events(path: str) -> List[Dict[str, Any]]:
    """Read a trace artifact → list of Chrome-trace-shaped event dicts.

    Accepts a Chrome JSON object (``{"traceEvents": [...]}``), a bare
    JSON array of events, or JSONL where each line is either a raw event
    or a ``{"record": ...}`` wrapper (metric/meta/alert/... records are
    skipped — they carry no timeline position)."""
    with open(path) as f:
        head = f.read(1)
        f.seek(0)
        if head == "{":
            try:
                doc = json.load(f)
            except json.JSONDecodeError:
                f.seek(0)
                return _load_jsonl(f)
            if isinstance(doc, dict) and "traceEvents" in doc:
                return list(doc["traceEvents"])
            if isinstance(doc, dict):
                f.seek(0)
                return _load_jsonl(f)
            raise ValueError(f"unrecognized trace shape in {path}")
        if head == "[":
            return list(json.load(f))
        return _load_jsonl(f)


def _load_jsonl(f) -> List[Dict[str, Any]]:
    out = []
    for line in f:
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        if "ph" in rec and "ts" in rec:
            out.append(rec)
        elif rec.get("record") == "alert":
            # health artifact line → synthesize an instant so alert
            # timelines are analyzable alongside traces
            out.append({"name": f"alert.{rec['kind']}", "ph": "i",
                        "cat": "alert",
                        "ts": float(rec.get("ts_s", 0.0)) * 1e6,
                        "args": {k: v for k, v in rec.items()
                                 if k not in ("record", "kind")}})
    return out


def complete_spans(events: Iterable[Dict[str, Any]]
                   ) -> List[Dict[str, Any]]:
    """X-phase (complete) events with a finite duration, μs units."""
    out = []
    for e in events:
        if e.get("ph") == "X" and "dur" in e:
            d = e["dur"]
            if isinstance(d, (int, float)) and math.isfinite(d):
                out.append(e)
    return out


def instants(events: Iterable[Dict[str, Any]],
             cats: Optional[Tuple[str, ...]] = None
             ) -> List[Dict[str, Any]]:
    out = [e for e in events if e.get("ph") in ("i", "I")]
    if cats is not None:
        out = [e for e in out if e.get("cat") in cats]
    return sorted(out, key=lambda e: e.get("ts", 0))


def _group_key(e: Dict[str, Any], by: str) -> str:
    if by == "name":
        return str(e.get("name", "?"))
    if by == "cat":
        return str(e.get("cat", "?"))
    if by == "track":
        # PR-6 tracer encodes the track as the thread name via metadata;
        # in the raw events it is the tid — good enough to group by
        return str(e.get("tid", e.get("pid", "?")))
    if by.startswith("arg:"):
        return str(e.get("args", {}).get(by[4:], "?"))
    raise ValueError(f"unknown group key: {by!r} "
                     "(use name|cat|track|arg:<key>)")


# --------------------------------------------------------------------------
# analyses (all return printable row lists so the CLI and tests share them)

def rollup(events: List[Dict[str, Any]], by: str = "name"
           ) -> List[Dict[str, Any]]:
    groups: Dict[str, List[float]] = defaultdict(list)
    for e in complete_spans(events):
        groups[_group_key(e, by)].append(e["dur"] / 1e6)
    rows = []
    for key, durs in groups.items():
        durs.sort()
        n = len(durs)
        total = sum(durs)
        rows.append({
            "group": key, "count": n, "total_s": total,
            "mean_s": total / n,
            "p95_s": durs[min(n - 1, int(0.95 * n))],
            "max_s": durs[-1],
        })
    rows.sort(key=lambda r: -r["total_s"])
    return rows


def top_spans(events: List[Dict[str, Any]], k: int = 10
              ) -> List[Dict[str, Any]]:
    spans = complete_spans(events)
    spans.sort(key=lambda e: -e["dur"])
    rows = []
    for e in spans[:k]:
        rows.append({
            "name": e.get("name", "?"), "dur_s": e["dur"] / 1e6,
            "ts_s": e.get("ts", 0) / 1e6, "cat": e.get("cat", ""),
            "args": {k_: v for k_, v in e.get("args", {}).items()
                     if isinstance(v, (int, float, str))},
        })
    return rows


def critical_path(events: List[Dict[str, Any]], parent: str = "round"
                  ) -> List[Dict[str, Any]]:
    """For each span named ``parent``, break its wall time into child
    phases (spans fully inside its [ts, ts+dur) on any track) plus the
    uncovered gap.  With concurrent children the per-phase sums can
    exceed wall time — that is signal (parallelism), not error."""
    spans = complete_spans(events)
    parents = [e for e in spans if e.get("name") == parent]
    parents.sort(key=lambda e: e.get("ts", 0))
    rows = []
    for i, p in enumerate(parents):
        t0, t1 = p["ts"], p["ts"] + p["dur"]
        phases: Dict[str, float] = defaultdict(float)
        covered: List[Tuple[float, float]] = []
        for e in spans:
            if e is p or e.get("name") == parent:
                continue
            if e["ts"] >= t0 and e["ts"] + e["dur"] <= t1:
                phases[e.get("name", "?")] += e["dur"] / 1e6
                covered.append((e["ts"], e["ts"] + e["dur"]))
        # merged coverage → uncovered gap on the parent's wall
        covered.sort()
        gap = p["dur"]
        last = t0
        for a, b in covered:
            a = max(a, last)
            if b > a:
                gap -= (b - a)
                last = b
        rows.append({
            "round": i, "wall_s": p["dur"] / 1e6,
            "ts_s": t0 / 1e6,
            "phases": dict(sorted(phases.items(),
                                  key=lambda kv: -kv[1])),
            "uncovered_s": max(0.0, gap / 1e6),
        })
    return rows


def diff_runs(events_a: List[Dict[str, Any]],
              events_b: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    ra = {r["group"]: r for r in rollup(events_a)}
    rb = {r["group"]: r for r in rollup(events_b)}
    rows = []
    for name in sorted(set(ra) | set(rb)):
        a, b = ra.get(name), rb.get(name)
        ta = a["total_s"] if a else 0.0
        tb = b["total_s"] if b else 0.0
        rows.append({
            "name": name,
            "count_a": a["count"] if a else 0,
            "count_b": b["count"] if b else 0,
            "total_a_s": ta, "total_b_s": tb,
            "delta_s": tb - ta,
            "ratio": (tb / ta) if ta > 0 else math.inf,
        })
    rows.sort(key=lambda r: -abs(r["delta_s"]))
    return rows


# --------------------------------------------------------------------------
# CLI

def _fmt_s(v) -> str:
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        return str(v)          # args values can be strings/bools
    return f"{v:.6f}" if v < 1.0 else f"{v:.3f}"


def _print_table(rows: List[Dict[str, Any]], cols: List[str],
                 out=None) -> None:
    out = out if out is not None else sys.stdout
    if not rows:
        print("(no spans)", file=out)
        return
    widths = {c: max(len(c), *(len(_cell(r, c)) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols), file=out)
    for r in rows:
        print("  ".join(_cell(r, c).ljust(widths[c]) for c in cols),
              file=out)


def _cell(r: Dict[str, Any], c: str) -> str:
    v = r.get(c, "")
    if isinstance(v, float):
        return _fmt_s(v) if math.isfinite(v) else "inf"
    if isinstance(v, dict):
        return " ".join(f"{k}={_fmt_s(x)}" for k, x in v.items())
    return str(v)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.analyze",
        description="offline analytics over repro trace artifacts")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("rollup", help="time by group")
    p.add_argument("trace")
    p.add_argument("--by", default="name",
                   help="name|cat|track|arg:<key> (e.g. arg:region)")

    p = sub.add_parser("top", help="k slowest spans")
    p.add_argument("trace")
    p.add_argument("-k", type=int, default=10)

    p = sub.add_parser("critical", help="per-round critical path")
    p.add_argument("trace")
    p.add_argument("--parent", default="round")

    p = sub.add_parser("diff", help="compare two runs by span name")
    p.add_argument("trace_a")
    p.add_argument("trace_b")

    p = sub.add_parser("alerts", help="alert/slo/fault instants")
    p.add_argument("trace")

    args = ap.parse_args(argv)

    if args.cmd == "rollup":
        rows = rollup(load_events(args.trace), by=args.by)
        _print_table(rows, ["group", "count", "total_s", "mean_s",
                            "p95_s", "max_s"])
    elif args.cmd == "top":
        rows = top_spans(load_events(args.trace), k=args.k)
        _print_table(rows, ["name", "dur_s", "ts_s", "cat", "args"])
    elif args.cmd == "critical":
        rows = critical_path(load_events(args.trace),
                             parent=args.parent)
        _print_table(rows, ["round", "wall_s", "uncovered_s", "phases"])
    elif args.cmd == "diff":
        rows = diff_runs(load_events(args.trace_a),
                         load_events(args.trace_b))
        _print_table(rows, ["name", "count_a", "count_b", "total_a_s",
                            "total_b_s", "delta_s", "ratio"])
    elif args.cmd == "alerts":
        evs = instants(load_events(args.trace),
                       cats=("alert", "slo", "fault"))
        rows = [{"ts_s": e.get("ts", 0) / 1e6,
                 "name": e.get("name", "?"), "cat": e.get("cat", ""),
                 "args": {k: v for k, v in e.get("args", {}).items()
                          if isinstance(v, (int, float, str))}}
                for e in evs]
        _print_table(rows, ["ts_s", "name", "cat", "args"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
