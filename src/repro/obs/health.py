"""Streaming fleet-health detectors: telemetry in, decisions out.

PR 6 made the fleet *observable* (spans, counters, histograms); PR 7
made failure *injectable* (the seeded :class:`~repro.core.faultinject.
FaultPlan`).  But until now every consumer that *responded* to a fault
was handed the plan itself — oracle knowledge no real fleet has.  This
module is the missing middle layer: detectors that recover the fleet's
health state from **observed telemetry alone** (the same span durations
and counters PR 6 already emits), so the scheduler / trainer / engine
can react to what they can actually measure.  The fault plan stays what
it always was — the *hidden ground truth* driving the simulation — and
``benchmarks/bench_health.py`` gates how faithfully the detectors
recover it (precision / recall / detection latency).

Three streaming detectors, each O(1) per observation (bounded deques +
cached robust statistics refreshed every few samples — the ≤2% overhead
budget from PR 6 applies to the *instrumented detector path* too):

* :class:`StragglerDetector` — per-entity step/round durations, flagged
  against the **fleet** median via a windowed median/MAD z-score (a
  straggling phone is slow *relative to its peers*, persistently).
  Also supports *overdue* checks: an entity whose round has already run
  longer than the straggler threshold can be flagged before it ever
  reports — which is how the async trainer stops waiting on a straggler
  it has never heard back from.
* :class:`LinkDegradeDetector` — per-entity sync/restore durations,
  flagged against the **entity's own** trailing median/MAD (a link flap
  is a spike on one link, not a level shift across the fleet).
* :class:`LossSpikeDetector` — the training-loss stream (what the
  device-resident accumulator drains), robust z-score spikes plus a
  two-window divergence test (recent median sustainedly above the
  trailing median).

Every detection lands on the :mod:`repro.obs` timeline as an
``alert.<kind>`` instant (cat ``alert``, args always carrying ``entity``
and ``detector`` — the schema ``repro.obs.validate`` enforces) plus a
``health/<detector>`` counter, and accumulates in
:attr:`HealthMonitor.alerts` for end-of-run summaries and the
``--health-out`` artifact.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Set, Tuple

ALERT_KINDS = ("straggler", "straggler_cleared", "link_degraded",
               "loss_spike", "divergence")


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return math.nan
    m = n // 2
    return s[m] if n % 2 else 0.5 * (s[m - 1] + s[m])


def _mad(xs: List[float], med: float) -> float:
    return _median([abs(x - med) for x in xs])


@dataclass
class Alert:
    """One detection: what fired, on whom, how bad, and when."""
    kind: str                 # one of ALERT_KINDS
    detector: str             # "straggler" | "link" | "loss"
    entity: str
    value: float              # the offending observation / level
    threshold: float          # what it was compared against
    ts_s: float               # timeline seconds (virtual or real)
    severity: float = 0.0     # robust z-score (or ratio) at detection
    detail: Dict[str, Any] = field(default_factory=dict)

    def to_record(self) -> Dict[str, Any]:
        return {"record": "alert", "kind": self.kind,
                "detector": self.detector, "entity": self.entity,
                "value": self.value, "threshold": self.threshold,
                "ts_s": self.ts_s, "severity": self.severity,
                **({"detail": self.detail} if self.detail else {})}


class _RobustStats:
    """Cached windowed median/MAD over a bounded deque; refreshed every
    ``refresh_every`` appends so the per-observation cost stays O(1)
    amortized (the sort is W log W but runs 1/refresh_every of the
    time)."""

    __slots__ = ("window", "refresh_every", "_buf", "_since", "med", "mad")

    def __init__(self, window: int = 64, refresh_every: int = 4):
        self.window = window
        self.refresh_every = refresh_every
        self._buf: Deque[float] = deque(maxlen=window)
        self._since = 0
        self.med = math.nan
        self.mad = math.nan

    def push(self, v: float) -> None:
        self._buf.append(v)
        self._since += 1
        if self._since >= self.refresh_every or math.isnan(self.med):
            self.refresh()

    def refresh(self) -> None:
        xs = list(self._buf)
        self.med = _median(xs)
        self.mad = _mad(xs, self.med)
        self._since = 0

    def __len__(self) -> int:
        return len(self._buf)

    def scale(self, rel_floor: float, abs_floor: float) -> float:
        """Robust sigma with a floor: 1.4826*MAD, but never below
        ``rel_floor * median`` (deterministic virtual clocks make MAD
        collapse to 0) nor ``abs_floor``."""
        base = 1.4826 * self.mad if not math.isnan(self.mad) else 0.0
        med = self.med if not math.isnan(self.med) else 0.0
        return max(base, rel_floor * abs(med), abs_floor)

    def z(self, v: float, rel_floor: float = 0.05,
          abs_floor: float = 1e-9) -> float:
        if math.isnan(self.med):
            return 0.0
        return (v - self.med) / self.scale(rel_floor, abs_floor)


class StragglerDetector:
    """Cross-entity robust z-score over observed per-entity durations.

    An entity is flagged when its own windowed median sits
    ``z_flag`` robust sigmas above the fleet median AND at least
    ``ratio_flag`` times the fleet median (the ratio guard keeps tiny
    absolute jitter from flagging when the fleet MAD collapses); it
    clears with hysteresis at ``z_clear``.  Needs ``min_obs``
    observations for the entity and ``min_entities`` peers before any
    verdict — you cannot call one device slow without a fleet to
    compare it to."""

    name = "straggler"

    def __init__(self, *, window: int = 32, z_flag: float = 4.0,
                 z_clear: float = 2.0, ratio_flag: float = 1.75,
                 min_obs: int = 1, min_entities: int = 3,
                 rel_floor: float = 0.05):
        self.z_flag = z_flag
        self.z_clear = z_clear
        self.ratio_flag = ratio_flag
        self.min_obs = min_obs
        self.min_entities = min_entities
        self.rel_floor = rel_floor
        self.fleet = _RobustStats(window=window * 4)
        self.per_entity: Dict[str, _RobustStats] = {}
        self._window = window
        self.flagged: Set[str] = set()
        self.obs_count: Dict[str, int] = {}

    def _entity(self, entity: str) -> _RobustStats:
        st = self.per_entity.get(entity)
        if st is None:
            st = self.per_entity[entity] = _RobustStats(self._window)
        return st

    def _verdict(self, entity: str, level: float
                 ) -> Tuple[bool, float, float]:
        """(should_flag, z, threshold_level) for an entity running at
        ``level`` seconds, vs the current fleet statistics."""
        if len(self.per_entity) < self.min_entities \
                or math.isnan(self.fleet.med) or self.fleet.med <= 0:
            return False, 0.0, math.inf
        z = self.fleet.z(level, self.rel_floor)
        thresh = max(
            self.fleet.med + self.z_flag * self.fleet.scale(self.rel_floor,
                                                            1e-9),
            self.ratio_flag * self.fleet.med)
        return (z >= self.z_flag and level >= thresh), z, thresh

    def observe(self, entity: str, duration_s: float) -> Optional[Alert]:
        """Record one completed step/round duration; returns an Alert on
        a flag/clear transition, else None."""
        st = self._entity(entity)
        st.push(duration_s)
        self.obs_count[entity] = self.obs_count.get(entity, 0) + 1
        self.fleet.push(duration_s)
        if self.obs_count[entity] < self.min_obs:
            return None
        level = st.med
        flag, z, thresh = self._verdict(entity, level)
        if flag and entity not in self.flagged:
            self.flagged.add(entity)
            return Alert("straggler", self.name, entity, level, thresh,
                         0.0, severity=z)
        if entity in self.flagged and not math.isnan(self.fleet.med):
            z_now = self.fleet.z(level, self.rel_floor)
            if z_now < self.z_clear \
                    and level < self.ratio_flag * self.fleet.med:
                self.flagged.discard(entity)
                return Alert("straggler_cleared", self.name, entity,
                             level, thresh, 0.0, severity=z_now)
        return None

    def check_overdue(self, entity: str, elapsed_s: float
                      ) -> Optional[Alert]:
        """Flag an entity whose round has ALREADY run ``elapsed_s``
        without completing: since the true duration can only be larger,
        exceeding the straggler threshold now is conclusive.  Nothing is
        recorded into the windows (the round is not done)."""
        if entity in self.flagged:
            return None
        flag, z, thresh = self._verdict(entity, elapsed_s)
        if flag:
            self.flagged.add(entity)
            return Alert("straggler", self.name, entity, elapsed_s,
                         thresh, 0.0, severity=z,
                         detail={"overdue": True})
        return None


class LinkDegradeDetector:
    """Per-entity spike detection over sync/restore durations: a flap is
    an observation ``z_spike`` robust sigmas above the **entity's own**
    trailing median (with an absolute floor so sub-floor wobble never
    alerts).  Entities with ``degrade_after`` spikes inside their window
    are reported as *degraded* — the persistent verdict the scheduler
    can act on."""

    name = "link"

    def __init__(self, *, window: int = 32, z_spike: float = 6.0,
                 min_obs: int = 3, abs_floor_s: float = 0.05,
                 degrade_after: int = 2):
        self.z_spike = z_spike
        self.min_obs = min_obs
        self.abs_floor_s = abs_floor_s
        self.degrade_after = degrade_after
        self._window = window
        self.per_entity: Dict[str, _RobustStats] = {}
        self.obs_count: Dict[str, int] = {}
        self.spikes: Dict[str, Deque[int]] = {}   # obs indices of spikes

    def observe(self, entity: str, duration_s: float) -> Optional[Alert]:
        st = self.per_entity.get(entity)
        if st is None:
            st = self.per_entity[entity] = _RobustStats(self._window)
        n = self.obs_count.get(entity, 0)
        alert = None
        if n >= self.min_obs and not math.isnan(st.med):
            scale = st.scale(0.05, self.abs_floor_s)
            z = (duration_s - st.med) / scale
            if z >= self.z_spike and duration_s >= st.med \
                    + self.abs_floor_s:
                sp = self.spikes.setdefault(
                    entity, deque(maxlen=self._window))
                sp.append(n)
                alert = Alert("link_degraded", self.name, entity,
                              duration_s, st.med + self.z_spike * scale,
                              0.0, severity=z,
                              detail={"baseline_s": st.med,
                                      "spikes": len(sp)})
        self.obs_count[entity] = n + 1
        # spikes stay OUT of the baseline window: a flapping link must
        # not teach the detector that flapping is normal
        if alert is None:
            st.push(duration_s)
        return alert

    def degraded(self) -> Set[str]:
        return {e for e, sp in self.spikes.items()
                if len(sp) >= self.degrade_after}


class LossSpikeDetector:
    """Robust z-score spikes + two-window divergence over the scalar
    loss stream (fed from the device-accumulated histogram drain — which
    is why ``Histogram.observe`` must reject NaN/inf: a NaN-poisoned
    snapshot would blind this detector exactly when it matters)."""

    name = "loss"

    def __init__(self, *, window: int = 32, z_spike: float = 6.0,
                 min_obs: int = 8, div_ratio: float = 1.2,
                 div_patience: int = 4, rel_floor: float = 0.02):
        self.z_spike = z_spike
        self.min_obs = min_obs
        self.div_ratio = div_ratio
        self.div_patience = div_patience
        self.rel_floor = rel_floor
        self.stats = _RobustStats(window)
        self.recent: Deque[float] = deque(maxlen=max(4, window // 4))
        self.count = 0
        self._div_run = 0
        self.diverged = False

    def observe(self, value: float, entity: str = "train"
                ) -> Optional[Alert]:
        self.count += 1
        alert = None
        if not math.isfinite(value):
            # a non-finite loss IS the divergence signal, immediately
            self.diverged = True
            return Alert("divergence", self.name, entity,
                         float("inf"), self.stats.med, 0.0,
                         severity=math.inf,
                         detail={"non_finite": True})
        if self.count > self.min_obs and not math.isnan(self.stats.med):
            z = self.stats.z(value, self.rel_floor)
            if z >= self.z_spike:
                alert = Alert(
                    "loss_spike", self.name, entity, value,
                    self.stats.med
                    + self.z_spike * self.stats.scale(self.rel_floor,
                                                      1e-9),
                    0.0, severity=z, detail={"median": self.stats.med})
        self.recent.append(value)
        # divergence: the short recent window sustainedly above the long
        # trailing median by div_ratio
        if alert is None and self.count > self.min_obs \
                and len(self.recent) == self.recent.maxlen \
                and not math.isnan(self.stats.med) and self.stats.med > 0:
            if _median(list(self.recent)) > self.div_ratio * self.stats.med:
                self._div_run += 1
            else:
                self._div_run = 0
            if self._div_run >= self.div_patience and not self.diverged:
                self.diverged = True
                alert = Alert("divergence", self.name, entity,
                              _median(list(self.recent)),
                              self.div_ratio * self.stats.med, 0.0,
                              severity=self._div_run)
        self.stats.push(value)
        return alert


class HealthMonitor:
    """The fleet's health state, derived from telemetry alone.

    Producers feed observations (step durations per entity, sync/link
    durations per entity, loss scalars); the monitor runs the streaming
    detectors, emits every transition onto the obs timeline
    (``alert.<kind>`` instants, cat ``alert``) and into the metrics
    registry (``health/<detector>`` counters), and exposes the verdicts
    consumers act on:

    * :meth:`stragglers` — entities currently flagged slow,
    * :meth:`degraded_links` — entities with repeated link spikes,
    * :attr:`diverged` — the loss stream has left the rails,
    * :attr:`alerts` — every Alert, for summaries and ``--health-out``.

    The closed loop (what this PR exists for): the async local-SGD
    quorum excludes :meth:`stragglers`, the orchestrator degrades them
    out of the active set, and the serve engine tightens admission when
    an SLO burns — all without reading the fault plan."""

    def __init__(self, *, registry=None, tracer=None,
                 straggler: Optional[StragglerDetector] = None,
                 link: Optional[LinkDegradeDetector] = None,
                 loss: Optional[LossSpikeDetector] = None):
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.trace import get_tracer
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.straggler = straggler if straggler is not None \
            else StragglerDetector()
        self.link = link if link is not None else LinkDegradeDetector()
        self.loss = loss if loss is not None else LossSpikeDetector()
        self.alerts: List[Alert] = []
        self.counts: Dict[str, int] = {}

    # ------------------------------------------------------------- emission
    def _emit(self, alert: Optional[Alert],
              ts_s: Optional[float]) -> Optional[Alert]:
        if alert is None:
            return None
        alert.ts_s = ts_s if ts_s is not None else self.tracer.now_s()
        self.alerts.append(alert)
        self.counts[alert.kind] = self.counts.get(alert.kind, 0) + 1
        self.tracer.instant(
            f"alert.{alert.kind}", "alert", track="health", ts_s=ts_s,
            entity=alert.entity, detector=alert.detector,
            value=round(alert.value, 6),
            threshold=(round(alert.threshold, 6)
                       if math.isfinite(alert.threshold) else -1.0),
            severity=(round(alert.severity, 3)
                      if math.isfinite(alert.severity) else -1.0),
            **alert.detail)
        self.registry.counter(f"health/{alert.detector}").inc(1)
        self.registry.counter("health/alerts").inc(1)
        return alert

    # ---------------------------------------------------------- observations
    def observe_step(self, entity, duration_s: float, *,
                     ts_s: Optional[float] = None) -> Optional[Alert]:
        """One completed step/round of ``entity`` took ``duration_s``."""
        return self._emit(self.straggler.observe(str(entity),
                                                 float(duration_s)), ts_s)

    def check_overdue(self, entity, elapsed_s: float, *,
                      ts_s: Optional[float] = None) -> Optional[Alert]:
        """``entity``'s round has been running ``elapsed_s`` and has not
        reported — flag it now if that alone crosses the threshold."""
        return self._emit(self.straggler.check_overdue(str(entity),
                                                       float(elapsed_s)),
                          ts_s)

    def observe_link(self, entity, duration_s: float, *,
                     ts_s: Optional[float] = None) -> Optional[Alert]:
        """One sync/restore/transfer involving ``entity``'s link."""
        return self._emit(self.link.observe(str(entity),
                                            float(duration_s)), ts_s)

    def observe_loss(self, value: float, *, entity: str = "train",
                     ts_s: Optional[float] = None) -> Optional[Alert]:
        return self._emit(self.loss.observe(float(value), entity), ts_s)

    # --------------------------------------------------------------- verdicts
    def stragglers(self) -> Set[str]:
        return set(self.straggler.flagged)

    def is_straggler(self, entity) -> bool:
        return str(entity) in self.straggler.flagged

    def degraded_links(self) -> Set[str]:
        return self.link.degraded()

    @property
    def diverged(self) -> bool:
        return self.loss.diverged

    def alerts_by_kind(self) -> Dict[str, int]:
        return dict(sorted(self.counts.items()))

    # --------------------------------------------------------------- reporting
    def summary(self) -> Dict[str, Any]:
        return {
            "alerts_total": len(self.alerts),
            "alerts_by_kind": self.alerts_by_kind(),
            "stragglers": sorted(self.stragglers()),
            "degraded_links": sorted(self.degraded_links()),
            "diverged": self.diverged,
        }

    def summary_line(self) -> str:
        by_kind = " ".join(f"{k}={v}"
                           for k, v in self.alerts_by_kind().items()) \
            or "none"
        return (f"alerts: {by_kind} | stragglers: "
                f"{','.join(sorted(self.stragglers())) or '-'} | "
                f"degraded links: "
                f"{','.join(sorted(self.degraded_links())) or '-'} | "
                f"diverged: {self.diverged}")

    def dump_jsonl(self, path: str, *, slo=None,
                   meta: Optional[Dict[str, Any]] = None) -> None:
        """The ``--health-out`` artifact: one ``{"record": "alert", ...}``
        line per alert (plus optional meta and, when an
        :class:`repro.obs.slo.SLOMonitor` is passed, one
        ``{"record": "slo", ...}`` verdict line per SLO)."""
        import json
        with open(path, "w") as f:
            if meta is not None:
                f.write(json.dumps({"record": "meta", **meta}) + "\n")
            f.write(json.dumps({"record": "health_summary",
                                **self.summary()}) + "\n")
            for a in self.alerts:
                rec = a.to_record()
                for k, v in list(rec.items()):
                    if isinstance(v, float) and not math.isfinite(v):
                        rec[k] = str(v)
                f.write(json.dumps(rec) + "\n")
            if slo is not None:
                for v in slo.verdicts():
                    f.write(json.dumps({"record": "slo", **v}) + "\n")
