"""Unified fleet telemetry: span tracing (Perfetto/Chrome-trace + JSONL
export), a metrics registry (counters / gauges / fixed-bucket histograms
with p50/p95/p99), and the device-resident accumulator that keeps
instrumentation off the dispatch critical path.

Producers across the repo emit onto ONE timeline: trainer + local-SGD
step phases, the serving engine's per-request lifecycle
(queued→prefill→decode→finished/preempted), orchestrator fleet events
(churn / replan / restore / checkpoint on the simulated clock), and
EnergyMonitor / CarbonLedger attributions (J, gCO2e) attached to
whatever span encloses them.

Since PR 9 the telemetry is also an *input*: :class:`HealthMonitor`
runs streaming detectors (stragglers, link degradation, loss spikes /
divergence) over the observed durations and losses, :class:`SLOMonitor`
evaluates declarative SLOs with multi-window burn rates, and the
scheduler / async trainer / serve engine act on their verdicts —
``python -m repro.obs.analyze`` is the offline counterpart.
"""

from repro.obs.health import (Alert, HealthMonitor, LinkDegradeDetector,
                              LossSpikeDetector, StragglerDetector)
from repro.obs.metrics import (Counter, DeviceAccumulator, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.slo import (SLOMonitor, SLOSpec, serve_slos, train_slos)
from repro.obs.trace import (NULL_SPAN, Span, Tracer, get_tracer,
                             set_tracer)

__all__ = [
    "Alert", "Counter", "DeviceAccumulator", "Gauge", "HealthMonitor",
    "Histogram", "LinkDegradeDetector", "LossSpikeDetector",
    "MetricsRegistry", "NULL_SPAN", "SLOMonitor", "SLOSpec", "Span",
    "StragglerDetector", "Tracer", "get_tracer", "serve_slos",
    "set_tracer", "train_slos",
]
