"""Unified fleet telemetry: span tracing (Perfetto/Chrome-trace + JSONL
export), a metrics registry (counters / gauges / fixed-bucket histograms
with p50/p95/p99), and the device-resident accumulator that keeps
instrumentation off the dispatch critical path.

Producers across the repo emit onto ONE timeline: trainer + local-SGD
step phases, the serving engine's per-request lifecycle
(queued→prefill→decode→finished/preempted), orchestrator fleet events
(churn / replan / restore / checkpoint on the simulated clock), and
EnergyMonitor / CarbonLedger attributions (J, gCO2e) attached to
whatever span encloses them.
"""

from repro.obs.metrics import (Counter, DeviceAccumulator, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.trace import (NULL_SPAN, Span, Tracer, get_tracer,
                             set_tracer)

__all__ = [
    "Counter", "DeviceAccumulator", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_SPAN", "Span", "Tracer", "get_tracer",
    "set_tracer",
]
