"""Span tracing with Chrome-trace / Perfetto and JSONL export.

The fleet telemetry substrate (§5 generalized: time × bytes × joules ×
gCO2e need ONE timeline to be comparable).  Design constraints, in order:

* **near-zero overhead when disabled** — the default global tracer is
  off; ``tracer.span(...)`` then returns a shared no-op context manager
  after a single attribute check, so the zero-sync training loops (PR 2)
  keep their step time (gated by ``bench_train_step.py`` and the
  tight-loop overhead test in ``tests/test_obs.py``);
* **thread-safe** — events append under the GIL; span nesting lives in a
  ``threading.local`` stack so concurrent threads trace independently;
* **monotonic timestamps** — ``time.perf_counter_ns`` relative to the
  tracer's epoch; wall clock never appears in a timeline;
* **two export formats** — Chrome trace-event JSON (open in
  https://ui.perfetto.dev or ``chrome://tracing``) and a line-per-event
  JSONL log for ad-hoc grep/pandas analysis.

Three span shapes cover every producer in the repo:

* ``with tracer.span("fwd_bwd_opt", "train"):`` — stack-nested complete
  events (trainer / local-SGD step phases).  ``metric="train/step_s"``
  additionally feeds the span's duration into the attached
  :class:`~repro.obs.metrics.MetricsRegistry` histogram on exit.
* ``h = tracer.begin("decode", track="req:42"); ... tracer.end(h)`` —
  detached spans that outlive the current frame (per-request lifecycle
  states in ``serve.engine`` that stretch across many engine steps).
* ``tracer.complete("restore", ts_s=t, dur_s=rc.time_s, ...)`` —
  explicit-timestamp events for simulated clocks (the orchestrator's
  discrete-event time).

Plus ``instant`` (point events: churn, preemption), ``counter``
(Perfetto counter tracks: KV utilization per step) and ``annotate``
(attach key/values — energy J, carbon g — to the innermost open span,
which is how ``EnergyMonitor``/``CarbonLedger`` land on the timeline).
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared no-op span: the entire disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def end(self, **attrs):
        return None


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("_tr", "name", "cat", "tid", "metric", "args", "t0_us",
                 "dur_us", "_open")

    def __init__(self, tr: "Tracer", name: str, cat: str, tid: int,
                 metric: Optional[str], args: Dict[str, Any]):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.tid = tid
        self.metric = metric
        self.args = args
        self.t0_us = tr._now_us()
        self.dur_us = 0.0
        self._open = True

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    # -- nested (with-statement) use ------------------------------------
    def __enter__(self) -> "Span":
        self._tr._stack().append(self)
        return self

    def __exit__(self, *exc):
        st = self._tr._stack()
        if st and st[-1] is self:
            st.pop()
        self._finish()
        return False

    # -- detached (begin/end) use ---------------------------------------
    def end(self, **attrs) -> None:
        if attrs:
            self.args.update(attrs)
        self._finish()

    def _finish(self) -> None:
        if not self._open:
            return
        self._open = False
        self.dur_us = self._tr._now_us() - self.t0_us
        self._tr._record("X", self.name, self.cat, self.t0_us,
                         self.dur_us, self.tid, self.args)
        if self._tr.registry is not None and self.metric:
            self._tr.registry.histogram(self.metric).observe(
                self.dur_us / 1e6)


class Tracer:
    """Collects trace events; one instance per run (or the global one)."""

    def __init__(self, enabled: bool = True, *, registry=None,
                 process: str = "repro"):
        self.enabled = enabled
        self.registry = registry      # optional MetricsRegistry: spans
                                      # with metric= feed duration hists
        self.process = process
        self._t0_ns = time.perf_counter_ns()
        self._events: List[Dict[str, Any]] = []
        self._tracks: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._local = threading.local()

    # ------------------------------------------------------------- internals
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def now_s(self) -> float:
        """Seconds on the tracer's clock (for TTFT-style host math that
        must share the timeline's timebase)."""
        return (time.perf_counter_ns() - self._t0_ns) / 1e9

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self, track: Optional[str]) -> int:
        if track is None:
            t = threading.current_thread()
            track = t.name if t.name else f"thread-{t.ident}"
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(track,
                                              len(self._tracks) + 1)
        return tid

    def _record(self, ph: str, name: str, cat: str, ts_us: float,
                dur_us: float, tid: int, args: Dict[str, Any]) -> None:
        # list.append is atomic under the GIL; no lock on the hot path
        self._events.append({"name": name, "cat": cat, "ph": ph,
                             "ts": ts_us, "dur": dur_us, "tid": tid,
                             "args": args})

    # ------------------------------------------------------------------- API
    def span(self, name: str, cat: str = "", *, track: Optional[str] = None,
             metric: Optional[str] = None, **attrs):
        """Nested complete event (context manager)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, self._tid(track), metric, attrs)

    def begin(self, name: str, cat: str = "", *,
              track: Optional[str] = None, metric: Optional[str] = None,
              **attrs):
        """Detached span: caller keeps the handle, ends it later with
        ``tracer.end(h)`` / ``h.end()`` — possibly from another frame
        or engine step (request lifecycle states)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, self._tid(track), metric, attrs)

    def end(self, handle, **attrs) -> None:
        handle.end(**attrs)

    def instant(self, name: str, cat: str = "", *,
                track: Optional[str] = None, ts_s: Optional[float] = None,
                **attrs) -> None:
        if not self.enabled:
            return
        ts = self._now_us() if ts_s is None else ts_s * 1e6
        self._record("i", name, cat, ts, 0.0, self._tid(track), attrs)

    def complete(self, name: str, *, ts_s: float, dur_s: float,
                 cat: str = "", track: Optional[str] = None,
                 **attrs) -> None:
        """Explicit-timestamp complete event — for simulated clocks (the
        orchestrator's discrete-event time, in seconds from run start)."""
        if not self.enabled:
            return
        self._record("X", name, cat, ts_s * 1e6, dur_s * 1e6,
                     self._tid(track), attrs)

    def counter(self, name: str, value: float, *,
                track: Optional[str] = None,
                ts_s: Optional[float] = None) -> None:
        """Perfetto counter track sample (e.g. KV utilization per step)."""
        if not self.enabled:
            return
        ts = self._now_us() if ts_s is None else ts_s * 1e6
        self._record("C", name, "", ts, 0.0,
                     self._tid(track or "counters"), {"value": value})

    def annotate(self, **attrs) -> None:
        """Attach attributes to the innermost open span on this thread —
        how EnergyMonitor (J) and CarbonLedger (gCO2e) land on whatever
        phase span encloses them.  No-op outside any span."""
        if not self.enabled:
            return
        st = self._stack()
        if st:
            st[-1].args.update(attrs)

    # ---------------------------------------------------------------- export
    @property
    def events(self) -> List[Dict[str, Any]]:
        return self._events

    def clear(self) -> None:
        self._events = []

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object format (Perfetto-loadable)."""
        pid = 1
        out: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": self.process}},
        ]
        for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name", "args": {"name": track}})
        for e in self._events:
            ev: Dict[str, Any] = {"name": e["name"], "cat": e["cat"] or "-",
                                  "ph": e["ph"], "ts": e["ts"],
                                  "pid": pid, "tid": e["tid"]}
            if e["ph"] == "X":
                ev["dur"] = e["dur"]
            elif e["ph"] == "i":
                ev["s"] = "t"
            if e["args"]:
                ev["args"] = e["args"]
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def save_jsonl(self, path: str) -> None:
        """One JSON object per line: ``{name, cat, ph, ts_us, dur_us,
        track, args}`` — the grep/pandas-friendly event log."""
        names = {tid: track for track, tid in self._tracks.items()}
        with open(path, "w") as f:
            for e in self._events:
                f.write(json.dumps({
                    "name": e["name"], "cat": e["cat"], "ph": e["ph"],
                    "ts_us": e["ts"], "dur_us": e["dur"],
                    "track": names.get(e["tid"], str(e["tid"])),
                    "args": e["args"]}) + "\n")


# ------------------------------------------------------------ global tracer
_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-global tracer; returns the old
    one (restore it in tests)."""
    global _GLOBAL
    old = _GLOBAL
    _GLOBAL = tracer
    return old
