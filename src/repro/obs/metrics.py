"""Metrics registry: counters, gauges, fixed-bucket histograms, and the
device-resident accumulator that keeps instrumentation out of the
dispatch pipeline.

Histograms use fixed log-spaced buckets (one ``bisect`` per observe, no
per-sample storage) and report p50/p95/p99 by linear interpolation
inside the owning bucket — accurate to one bucket width, which the
default 120-buckets-over-11-decades layout keeps within ~25% relative
and the tests pin against a numpy reference.

``DeviceAccumulator`` is the pattern that lets the zero-sync training
loops (PR 2) observe jnp scalars without host syncs: ``observe`` just
appends the device value to a pending list; ``drain()`` does ONE
``jax.device_get`` for the whole window and only then feeds the host
floats into the registry.  Draining at log-window boundaries means
instrumentation adds zero extra device round-trips.
"""

from __future__ import annotations

import json
import math
import threading
from bisect import bisect_right
from typing import Any, Dict, List, Optional, Tuple


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def set_max(self, v: float) -> None:
        """High-water update — keeps peaks (KV utilization, fragmentation)
        correct after the instantaneous stat has gone back to zero."""
        if v > self.value:
            self.value = v

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed log-spaced buckets over ``[lo, hi)`` plus underflow and
    overflow buckets; exact count/sum/min/max."""

    __slots__ = ("lo", "hi", "edges", "counts", "count", "sum",
                 "min", "max", "rejected")

    def __init__(self, lo: float = 1e-7, hi: float = 1e4,
                 nbuckets: int = 120):
        if not (lo > 0 and hi > lo and nbuckets >= 1):
            raise ValueError(f"bad histogram layout lo={lo} hi={hi} "
                             f"nbuckets={nbuckets}")
        self.lo, self.hi = lo, hi
        ratio = (hi / lo) ** (1.0 / nbuckets)
        self.edges = [lo * ratio ** i for i in range(nbuckets + 1)]
        self.edges[-1] = hi
        # counts[0] = underflow (< lo), counts[-1] = overflow (>= hi)
        self.counts = [0] * (nbuckets + 2)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.rejected = 0

    def observe(self, v: float) -> None:
        if not math.isfinite(v):
            # a single NaN would poison sum/mean forever and an inf
            # would wreck the percentile clamp — and downstream the
            # loss-spike detector needs to see spikes, not a NaN-blinded
            # snapshot.  Count the rejection so it is still observable.
            self.rejected += 1
            return
        self.counts[bisect_right(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def _bucket_bounds(self, i: int) -> Tuple[float, float]:
        if i == 0:                       # underflow
            return min(self.min, self.edges[0]), self.edges[0]
        if i == len(self.counts) - 1:    # overflow
            return self.edges[-1], max(self.max, self.edges[-1])
        return self.edges[i - 1], self.edges[i]

    def percentile(self, q: float) -> float:
        """q in [0, 100]; linear interpolation within the owning bucket,
        clamped to the observed [min, max]."""
        if self.count == 0:
            return math.nan
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo, hi = self._bucket_bounds(i)
                frac = (target - cum) / c
                return min(max(lo + frac * (hi - lo), self.min), self.max)
            cum += c
        return self.max

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"type": "histogram", "count": self.count,
                               "sum": self.sum}
        if self.rejected:
            out["rejected"] = self.rejected
        if self.count:
            out.update(mean=self.sum / self.count, min=self.min,
                       max=self.max, p50=self.percentile(50),
                       p95=self.percentile(95), p99=self.percentile(99))
        return out


class MetricsRegistry:
    """Name → metric; get-or-create, thread-safe, one snapshot schema."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls(*args, **kw))
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, lo: float = 1e-7, hi: float = 1e4,
                  nbuckets: int = 120) -> Histogram:
        return self._get(name, Histogram, lo, hi, nbuckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def dump_jsonl(self, path: str,
                   meta: Optional[Dict[str, Any]] = None) -> None:
        """One JSON object per line: an optional leading
        ``{"record": "meta", ...}`` line, then one
        ``{"record": "metric", "name": ..., **snapshot}`` per metric."""
        with open(path, "w") as f:
            if meta is not None:
                f.write(json.dumps({"record": "meta", **meta}) + "\n")
            for name, snap in self.snapshot().items():
                f.write(json.dumps({"record": "metric", "name": name,
                                    **snap}) + "\n")


class DeviceAccumulator:
    """Batches jnp scalar observations; ONE ``jax.device_get`` per drain.

    The hot-loop half (``observe``/``inc``) never touches the device —
    it only appends the (still-device-resident, possibly not yet
    computed) scalar to a pending list, so dispatch pipelining is
    preserved.  ``drain()`` fetches the whole window in a single
    transfer and routes the host floats into the registry — call it at
    log-window boundaries and at loop exit, exactly where the trainer
    already syncs."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self._pending: List[Tuple[str, str, Any]] = []

    def observe(self, hist_name: str, device_scalar) -> None:
        self._pending.append(("hist", hist_name, device_scalar))

    def inc(self, counter_name: str, device_scalar) -> None:
        self._pending.append(("ctr", counter_name, device_scalar))

    def __len__(self) -> int:
        return len(self._pending)

    def drain(self) -> List[float]:
        """Fetch + route every pending value; returns them in order."""
        if not self._pending:
            return []
        import jax
        vals = jax.device_get([p[2] for p in self._pending])
        out: List[float] = []
        for (kind, name, _), v in zip(self._pending, vals):
            fv = float(v)
            out.append(fv)
            if kind == "hist":
                self.registry.histogram(name).observe(fv)
            else:
                self.registry.counter(name).inc(fv)
        self._pending.clear()
        return out
