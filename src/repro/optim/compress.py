"""Gradient compression with error feedback — the paper's §5 communication-
minimization lever ("existing compression techniques reduce communication").

Two compressors over gradient pytrees:

* ``int8``: blockwise symmetric int8 (4× over bf16, 2x over fp32 wire bytes)
  via the ``kernels/quant8`` Pallas kernel,
* ``topk``: magnitude top-k sparsification (k as a fraction).

Error feedback (Seide et al. / EF-SGD): the compression residual is added
back to the next step's gradient, preserving convergence — the property
tests check that compress(g + e) round-trips within the quantization bound
and that EF keeps the long-run bias near zero.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quant8 import ops as q8

PyTree = Any


@dataclass(frozen=True)
class CompressConfig:
    method: str = "none"          # none | int8 | topk
    topk_fraction: float = 0.01
    block: int = 256
    error_feedback: bool = True


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf_int8(g: jax.Array, block: int) -> jax.Array:
    q, s, shape = q8.quantize(g, block)
    return q8.dequantize(q, s, shape, block, jnp.float32)


def _compress_leaf_topk(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(g.shape)


def compress_grads(grads: PyTree, error: Optional[PyTree],
                   cfg: CompressConfig) -> Tuple[PyTree, PyTree]:
    """Returns (decompressed-gradient-as-transmitted, new error feedback)."""
    if cfg.method == "none":
        return grads, error

    def one(g, e):
        gf = g.astype(jnp.float32)
        if cfg.error_feedback and e is not None:
            gf = gf + e
        if cfg.method == "int8":
            sent = _compress_leaf_int8(gf, cfg.block)
        elif cfg.method == "topk":
            sent = _compress_leaf_topk(gf, cfg.topk_fraction)
        else:
            raise ValueError(cfg.method)
        new_e = gf - sent if cfg.error_feedback else None
        return sent.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    if error is None or not cfg.error_feedback:
        # NB: tree.map(lambda _: None, ...) yields an EMPTY pytree (None
        # is not a leaf) — build the flat list directly
        flat_e = [None] * len(flat_g)
    else:
        flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sent = jax.tree.unflatten(tdef, [o[0] for o in outs])
    if not cfg.error_feedback:
        # no residual state: return None rather than a pytree of
        # wrong-shaped scalar placeholders (a later error_feedback=True
        # toggle or tree-map over the state would crash on those)
        return sent, None
    # error_feedback=True: one() always produced a residual per leaf
    new_err = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return sent, new_err


def wire_bytes_count(n: int, cfg: Optional[CompressConfig], *,
                     dtype_bytes: int = 4) -> int:
    """Bytes transmitted for ``n`` gradient elements under this compressor.

    The analytic counterpart of :func:`wire_bytes` — what the planner and
    the net-layer collective cost models consume, so compression choice
    composes with collective choice without materializing a pytree.
    """
    if cfg is None or cfg.method == "none":
        return n * dtype_bytes
    if cfg.method == "int8":
        return n + 4 * (n // cfg.block + 1)
    if cfg.method == "topk":
        k = max(1, int(n * cfg.topk_fraction))
        return k * 8                # value + index
    raise ValueError(cfg.method)


def wire_bytes(grads: PyTree, cfg: CompressConfig) -> int:
    """Bytes actually transmitted per all-reduce under this compressor."""
    return sum(wire_bytes_count(g.size, cfg, dtype_bytes=g.dtype.itemsize)
               for g in jax.tree.leaves(grads))
