"""AdamW (decoupled weight decay) over raw pytrees — no optax dependency.

Moments default to fp32; ``moment_dtype='bfloat16'`` halves optimizer-state
HBM for the 405B/671B dry-runs (recorded in EXPERIMENTS.md §Roofline).
Update math always runs in fp32 regardless of storage dtype.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to ``min_lr_ratio``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * scale


def init_opt_state(params: PyTree, cfg: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: PyTree, grads: PyTree, state: Dict[str, Any],
                  cfg: OptConfig) -> Tuple[PyTree, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu_f = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu_f = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mu_hat = mu_f / bc1
        nu_hat = nu_f / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        # decoupled weight decay on >=2D weights only (skip norms/biases)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu_f.astype(mdt), nu_f.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
