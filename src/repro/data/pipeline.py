"""Deterministic synthetic-token data pipeline.

Offline-friendly: a seeded, structured token stream (mixture of Zipfian
unigrams + local n-gram structure) so that training losses DECREASE
meaningfully — pure-uniform tokens would pin the loss at ln V and hide
integration bugs.  Sharded host loading: each data-parallel host slices its
batch rows, matching the production input pipeline contract.

Also provides frontend-stub generators for the VLM/audio carve-out.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.3
    ngram_repeat: int = 8     # every k-th token repeats an earlier one


class SyntheticLM:
    """Structured synthetic LM stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # zipf over the vocab (clipped)
        ranks = np.arange(1, cfg.vocab_size + 1)
        probs = 1.0 / np.power(ranks, cfg.zipf_a)
        self.probs = probs / probs.sum()

    def _sequence(self) -> np.ndarray:
        c = self.cfg
        toks = self.rng.choice(c.vocab_size, size=c.seq_len + 1,
                               p=self.probs).astype(np.int32)
        # inject copy structure: predictable continuation every k tokens
        for i in range(c.ngram_repeat, c.seq_len + 1, c.ngram_repeat):
            toks[i] = toks[i - c.ngram_repeat]
        return toks

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        c = self.cfg
        while True:
            seqs = np.stack([self._sequence() for _ in range(c.batch)])
            yield {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def host_shard(self, host_id: int, num_hosts: int
                   ) -> Iterator[Dict[str, np.ndarray]]:
        assert self.cfg.batch % num_hosts == 0
        per = self.cfg.batch // num_hosts
        for b in self.batches():
            yield {k: v[host_id * per:(host_id + 1) * per] for k, v in
                   b.items()}


def make_batch_fn(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0):
    """Returns an iterator of model-ready batches for any arch family."""
    rng = np.random.default_rng(seed + 1)
    if cfg.is_encoder_decoder:
        S_dec = min(seq_len, cfg.max_target_positions)
        stream = SyntheticLM(DataConfig(batch, S_dec, cfg.vocab_size, seed))

        def gen():
            for b in stream.batches():
                frames = rng.standard_normal(
                    (batch, cfg.encoder_seq_len, cfg.d_model)).astype(
                        np.float32) * 0.02
                yield dict(b, frames=frames)
        return gen()
    if cfg.arch_type == "vlm":
        from repro.launch.specs import vlm_split
        Sv, St = vlm_split(seq_len)
        stream = SyntheticLM(DataConfig(batch, St, cfg.vocab_size, seed))

        def gen():
            for b in stream.batches():
                vis = rng.standard_normal((batch, Sv, cfg.d_model)).astype(
                    np.float32) * 0.02
                lbl = np.concatenate(
                    [np.full((batch, Sv), -1, np.int32), b["labels"]], axis=1)
                pos = np.broadcast_to(
                    np.arange(Sv + St, dtype=np.int32)[None, None],
                    (3, batch, Sv + St))
                yield {"tokens": b["tokens"], "vision_embeds": vis,
                       "labels": lbl, "positions": np.ascontiguousarray(pos)}
        return gen()
    stream = SyntheticLM(DataConfig(batch, seq_len, cfg.vocab_size, seed))
    return stream.batches()
