"""Trainer loop: jit'd train_step + data pipeline + checkpointing + the
paper's energy monitor wired per step.

Runs on whatever mesh is ambient — a laptop (1 device), the edge mesh, or
the production pod.  ``examples/quickstart.py`` and the integration tests
drive a ~100M-param model through a few hundred steps with decreasing loss.

The hot loop is zero-sync by default (``benchmarks/bench_train_step.py``
records the step-time deltas):

* **donation** — ``donate_argnums=(params, opt_state)``: XLA updates the
  parameter and optimizer buffers in place instead of allocating + copying
  a full model's worth of HBM every step.  Only requested on backends that
  implement donation (TPU/GPU) — see :func:`donation_supported`;
* **async metrics** — per-step metrics stay on device; the loop keeps the
  uncopied device scalars and fetches with a single ``jax.device_get``
  every ``log_every`` steps (and one bulk fetch at the end), instead of a
  blocking ``float(...)`` round-trip per step that drains the dispatch
  pipeline;
* **prefetch** — the next batch is staged host→device with
  ``jax.device_put`` right after the step is dispatched, overlapping input
  transfer with device compute (double buffering).

Passing an ``EnergyMonitor`` opts back into per-step host sync: energy
accounting needs true per-step wall-clock, which only exists at a sync
point.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import flops as F
from repro.core.energy.monitor import ComponentModel, EnergyMonitor
from repro.data.pipeline import make_batch_fn
from repro.obs.metrics import DeviceAccumulator, MetricsRegistry
from repro.obs.trace import get_tracer
from repro.models import params as PM
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.step import make_train_step

PyTree = Any


@dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 256
    log_every: int = 10
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_placement: Any = None   # PlacementSpec/CheckpointSpec: save
                                       # layer-sliced by stage (elastic)
    checkpoint_replication: int = 0    # §5 neighbour shard copies
    resume: bool = False        # restore the newest complete checkpoint
                                # (any layout/boundaries) before training
    remat: str = "none"         # matches the make_train_step default
    attn_impl: str = "chunked"  # "naive" | "chunked" | "pallas"
    microbatches: int = 1
    donate: bool = True         # donate (params, opt_state) into the jit
    async_metrics: bool = True  # no per-step host sync; bulk-fetch metrics
    prefetch: bool = True       # double-buffer host->device batch transfer
    seed: int = 0


@dataclass
class TrainerResult:
    losses: List[float] = field(default_factory=list)
    steps_per_s: float = 0.0            # includes the compile step
    steady_steps_per_s: float = 0.0     # excludes the compile step
    compile_time_s: float = 0.0         # first-step (trace+compile+run) time
    energy_wh: float = 0.0
    final_loss: float = float("nan")
    resumed_from_step: int = 0          # 0 when starting fresh


def donation_supported() -> bool:
    """Buffer donation lands on TPU/GPU; XLA's CPU backend ignores it and
    jax still pays per-call donation bookkeeping for nothing (measured ~7%
    step-time overhead on the bench config), so the trainer only requests
    donation where it can actually reuse buffers."""
    return jax.default_backend() != "cpu"


def effective_donate(tc: TrainerConfig) -> bool:
    return tc.donate and donation_supported()


def make_jit_train_step(cfg: ModelConfig, tc: TrainerConfig,
                        opt_cfg: adamw.OptConfig) -> Callable:
    """The trainer's jit: (params, opt_state) donated per
    ``effective_donate`` — requested donation ∧ backend support."""
    return jax.jit(
        make_train_step(cfg, opt_cfg, remat=tc.remat,
                        attn_impl=tc.attn_impl,
                        microbatches=tc.microbatches),
        donate_argnums=(0, 1) if effective_donate(tc) else ())


def train(cfg: ModelConfig, tc: TrainerConfig,
          opt_cfg: Optional[adamw.OptConfig] = None,
          monitor: Optional[EnergyMonitor] = None,
          metrics: Optional[MetricsRegistry] = None,
          health=None) -> TrainerResult:
    """``metrics`` opts into per-phase step-time histograms + loss /
    grad-norm distributions WITHOUT extra host syncs: device scalars
    batch in a :class:`DeviceAccumulator` and drain at the same
    log-window boundaries the async-metrics loop already uses.  Span
    tracing rides the process-global tracer (``repro.obs``): a disabled
    tracer (the default) reduces every ``span`` call to one attribute
    check, keeping the zero-sync loop inside the
    ``bench_train_step.py`` regression gate.

    ``health`` (a :class:`repro.obs.HealthMonitor`) receives every loss
    the loop fetches — at the same sync points, never adding one — so
    its loss-spike / divergence detector watches the run live."""
    opt_cfg = opt_cfg or adamw.OptConfig(
        learning_rate=3e-4, warmup_steps=max(10, tc.steps // 20),
        decay_steps=tc.steps)
    rng = jax.random.PRNGKey(tc.seed)
    params = PM.init_params(cfg, rng)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    start_step = 0
    if tc.resume and tc.checkpoint_dir:
        # elastic resume: the checkpoint may have been written by ANY
        # placement (layer-sliced with different stage boundaries, or
        # leaf-modulo) — restore re-slices via its manifest either way,
        # so a changed fleet picks up exactly where the old one stopped
        found = ckpt.latest_complete_step(tc.checkpoint_dir)
        if found is not None:
            state = ckpt.restore(tc.checkpoint_dir,
                                 {"params": params, "opt": opt_state},
                                 step=found)
            params, opt_state = state["params"], state["opt"]
            start_step = found
            print(f"[trainer] resumed from step {found} "
                  f"({tc.checkpoint_dir})")
    step_fn = make_jit_train_step(cfg, tc, opt_cfg)
    data = make_batch_fn(cfg, tc.batch, tc.seq_len, tc.seed)

    step_flops = F.train_flops(cfg, tc.batch, tc.seq_len,
                               remat=tc.remat != "none")
    # the monitor needs true per-step wall clock -> forces the sync path
    sync_every_step = (not tc.async_metrics) or monitor is not None
    result = TrainerResult()
    pending: List[Dict[str, jax.Array]] = []   # device-resident metrics
    tr = get_tracer()
    acc = DeviceAccumulator(metrics) if metrics is not None else None

    batch = jax.device_put(next(data)) if tc.prefetch else None
    t0 = time.time()
    t_prev = t0
    for step in range(tc.steps):
        step_span = tr.span("step", "train", metric="train/step_s",
                            step=start_step + step)
        step_span.__enter__()
        if not tc.prefetch:
            with tr.span("data", "train", metric="train/data_s"):
                batch = jax.device_put(next(data))
        # forward+backward+optimizer are one fused jit; the span times
        # host-side dispatch under the async loop and true step time
        # under the sync loop (monitor present / async_metrics off)
        with tr.span("fwd_bwd_opt", "train",
                     metric="train/fwd_bwd_opt_s"):
            params, opt_state, mx = step_fn(params, opt_state, batch)
        if tc.prefetch and step + 1 < tc.steps:
            # step is dispatched but not complete: stage the next batch now
            # so generation + transfer overlap with device compute
            with tr.span("data", "train", metric="train/data_s"):
                batch = jax.device_put(next(data))

        host: Optional[Dict[str, Any]] = None
        if sync_every_step:
            host = jax.device_get(mx)               # one sync per step
            result.losses.append(float(host["loss"]))
            if health is not None:
                health.observe_loss(float(host["loss"]))
            if metrics is not None:
                metrics.histogram("train/loss", lo=1e-4, hi=1e4) \
                    .observe(float(host["loss"]))
                metrics.histogram("train/grad_norm", lo=1e-4, hi=1e4) \
                    .observe(float(host["grad_norm"]))
        else:
            pending.append(mx)                      # no sync
            if acc is not None:
                # device scalars only — drained with ONE device_get at
                # the log-window boundary below (zero extra syncs)
                acc.observe("train/loss", mx["loss"])
                acc.observe("train/grad_norm", mx["grad_norm"])
        if step == 0:
            if host is None:
                jax.block_until_ready(mx["loss"])
            result.compile_time_s = time.time() - t0
        if monitor is not None:
            t_now = time.time()
            monitor.record_step(flops=step_flops,
                                duration_s=t_now - t_prev)
            t_prev = t_now
        if tc.log_every and step % tc.log_every == 0:
            if host is None:
                # drain the whole window in ONE device_get: bounds the
                # device-resident metrics backlog at log_every entries
                with tr.span("metrics_drain", "train"):
                    fetched = jax.device_get(pending)
                    if acc is not None:
                        acc.drain()
                result.losses.extend(float(m["loss"]) for m in fetched)
                if health is not None:
                    for m in fetched:
                        health.observe_loss(float(m["loss"]))
                host = fetched[-1]
                pending.clear()
            print(f"step {step:5d}  loss {float(host['loss']):.4f}  "
                  f"gnorm {float(host['grad_norm']):.3f}  "
                  f"lr {float(host['lr']):.2e}")
        if tc.checkpoint_every and tc.checkpoint_dir \
                and (step + 1) % tc.checkpoint_every == 0:
            with tr.span("checkpoint", "train",
                         metric="train/checkpoint_s",
                         step=start_step + step + 1):
                state = {"params": params, "opt": opt_state}
                if tc.checkpoint_placement is not None:
                    ckpt.save_for_placement(
                        tc.checkpoint_dir, start_step + step + 1, state,
                        tc.checkpoint_placement,
                        replication=tc.checkpoint_replication)
                else:
                    ckpt.save(tc.checkpoint_dir, start_step + step + 1,
                              state)
                ckpt.prune(tc.checkpoint_dir)
        step_span.__exit__(None, None, None)
    if pending:
        with tr.span("metrics_drain", "train"):
            fetched = jax.device_get(pending)       # one bulk sync at exit
        result.losses.extend(float(m["loss"]) for m in fetched)
        if health is not None:
            for m in fetched:
                health.observe_loss(float(m["loss"]))
    if acc is not None:
        acc.drain()
    if metrics is not None:
        metrics.counter("train/steps").inc(tc.steps)
        metrics.counter("train/tokens").inc(
            tc.steps * tc.batch * tc.seq_len)
    wall = time.time() - t0
    result.steps_per_s = tc.steps / wall
    if tc.steps > 1 and wall > result.compile_time_s:
        result.steady_steps_per_s = (tc.steps - 1) / (wall -
                                                      result.compile_time_s)
    else:
        result.steady_steps_per_s = result.steps_per_s
    result.final_loss = result.losses[-1]
    result.resumed_from_step = start_step
    if monitor is not None:
        result.energy_wh = monitor.total_wh
    return result
