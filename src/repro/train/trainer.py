"""Trainer loop: jit'd train_step + data pipeline + checkpointing + the
paper's energy monitor wired per step.

Runs on whatever mesh is ambient — a laptop (1 device), the edge mesh, or
the production pod.  ``examples/quickstart.py`` and the integration tests
drive a ~100M-param model through a few hundred steps with decreasing loss.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import flops as F
from repro.core.energy.monitor import ComponentModel, EnergyMonitor
from repro.data.pipeline import make_batch_fn
from repro.models import params as PM
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.step import make_train_step

PyTree = Any


@dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 256
    log_every: int = 10
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    remat: str = "none"
    microbatches: int = 1
    seed: int = 0


@dataclass
class TrainerResult:
    losses: List[float] = field(default_factory=list)
    steps_per_s: float = 0.0
    energy_wh: float = 0.0
    final_loss: float = float("nan")


def train(cfg: ModelConfig, tc: TrainerConfig,
          opt_cfg: Optional[adamw.OptConfig] = None,
          monitor: Optional[EnergyMonitor] = None) -> TrainerResult:
    opt_cfg = opt_cfg or adamw.OptConfig(
        learning_rate=3e-4, warmup_steps=max(10, tc.steps // 20),
        decay_steps=tc.steps)
    rng = jax.random.PRNGKey(tc.seed)
    params = PM.init_params(cfg, rng)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=tc.remat,
                                      microbatches=tc.microbatches))
    data = make_batch_fn(cfg, tc.batch, tc.seq_len, tc.seed)

    step_flops = F.train_flops(cfg, tc.batch, tc.seq_len,
                               remat=tc.remat != "none")
    result = TrainerResult()
    t0 = time.time()
    t_prev = t0
    for step in range(tc.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        result.losses.append(loss)
        if monitor is not None:
            t_now = time.time()
            monitor.record_step(flops=step_flops,
                                duration_s=t_now - t_prev)
            t_prev = t_now
        if tc.log_every and step % tc.log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}")
        if tc.checkpoint_every and tc.checkpoint_dir \
                and (step + 1) % tc.checkpoint_every == 0:
            ckpt.save(tc.checkpoint_dir, step + 1,
                      {"params": params, "opt": opt_state})
            ckpt.prune(tc.checkpoint_dir)
    wall = time.time() - t0
    result.steps_per_s = tc.steps / wall
    result.final_loss = result.losses[-1]
    if monitor is not None:
        result.energy_wh = monitor.total_wh
    return result
