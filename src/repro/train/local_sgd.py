"""Local-update (DiLoCo-style) low-communication training.

Edge fleets cannot afford a gradient allreduce every step over wide-area
links; the viable regime (DiLoCo, FedOpt, post-local-SGD) is *local
update*: each replica runs K inner optimizer steps on its own data
shard, then the fleet synchronizes once on the **pseudo-gradient**

    delta_r = global_params - local_params_r          (after K steps)

with an outer Nesterov-momentum SGD applied to the averaged delta:

    m   <- mu * m + mean_r(delta_r)
    upd <- mean_r(delta_r) + mu * m      (Nesterov;  upd <- m  otherwise)
    global <- global - outer_lr * upd

Sync frequency — and therefore wide-area wire time — drops by K×, and
the pseudo-gradients additionally pass through the repro's gradient
compressors (int8 / top-k with per-replica error feedback), composing
with the collective cost models in :mod:`repro.core.net`.

Two outer-loop modes:

* **Synchronous** (default): every round waits for all replicas — one
  slow radio link stalls the fleet.  With ``inner_steps=1``,
  ``outer_momentum=0``, ``outer_lr=1`` and one replica the outer loop is
  the identity and the trajectory reduces exactly to the plain
  inner-optimizer trainer — the correctness anchor the tests pin down.
* **Bounded-staleness async** (``async_mode=True``): the outer update is
  *quorum-gated* — it applies as soon as ``quorum`` replicas have
  reported since the last update, so a straggler never stalls the round.
  Late pseudo-gradients fold into the *next* update with
  staleness-weighted averaging (weight ``1/(1+s)`` for a delta computed
  against a global version ``s`` updates old); past the hard bound
  ``staleness_bound`` a replica's work is dropped and it re-syncs from
  the current global params.  Per-replica K derives from the placement's
  region groups (slower regions run proportionally fewer inner steps so
  rounds finish together).  With ``quorum = replicas`` and
  ``staleness_bound = 0`` the async engine is **bit-identical** to the
  synchronous loop — the reduction property ``tests/test_faults.py``
  pins down and ``benchmarks/bench_faults.py`` gates.

Both modes drive a modelled **virtual fleet clock** (per-replica step
times from the placement's device specs, or ``nominal_step_s``), and
both consume a seeded :class:`repro.core.faultinject.FaultPlan`:
straggler slowdowns, crash/rejoin churn and link flaps/jitter move the
virtual clock (and, in async mode, which deltas arrive when) while every
injected fault lands on the :mod:`repro.obs` timeline as a
``fault.<kind>`` instant.  ``virtual_tokens_per_s`` is what
``bench_faults.py`` compares across modes under an injected straggler
distribution.

Inner steps run the same jit'd train step as :mod:`repro.train.trainer`
on whatever mesh is ambient; replicas are simulated host-side as
independent parameter copies (the real deployment maps each replica to
one edge pipeline).

Like the plain trainer, the inner loop is zero-sync: params/opt-state are
donated into the jit (each replica starts a round from a fresh on-device
copy of the global params so donation can never invalidate the buffer the
pseudo-gradient needs), per-step losses accumulate on device, and the
host fetches everything with a single ``jax.device_get`` per sync round.
An ``EnergyMonitor`` opts back into per-step sync (it needs real
per-step wall-clock).
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flops as F
from repro.core.energy.monitor import EnergyMonitor
from repro.core.faultinject import FaultInjector, FaultPlan
from repro.data.pipeline import make_batch_fn
from repro.obs.health import HealthMonitor
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer
from repro.models import params as PM
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.compress import CompressConfig, compress_grads, wire_bytes
from repro.train.step import make_train_step
from repro.train.trainer import TrainerConfig

PyTree = Any


@dataclass(frozen=True)
class LocalSGDConfig:
    replicas: int = 4
    inner_steps: int = 16            # K: inner steps per sync round
    outer_lr: float = 0.7            # DiLoCo outer Nesterov defaults
    outer_momentum: float = 0.9
    nesterov: bool = True
    compress: Optional[CompressConfig] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every_rounds: int = 0
    checkpoint_replication: int = 1  # §5 neighbour shard copies
    resume: bool = False             # restore newest complete ckpt first
    # ---- bounded-staleness async outer loop -------------------------
    async_mode: bool = False         # quorum-gated outer updates
    quorum: Optional[int] = None     # Q: updates apply at Q reports
                                     # (None -> all replicas)
    staleness_bound: int = 0         # S: max global-versions lag before
                                     # a delta is dropped + resynced
    nominal_step_s: float = 0.1      # modelled inner-step seconds when
                                     # no placement prices the devices


@dataclass
class LocalSGDResult:
    losses: List[float] = field(default_factory=list)     # replica-0, per step
    round_losses: List[float] = field(default_factory=list)  # fleet mean
    final_loss: float = float("nan")
    rounds: int = 0
    resumed_from_round: int = 0              # 0 when starting fresh
    steps_per_s: float = 0.0
    sync_wire_bytes_per_round: int = 0
    comm_time_s_per_round: float = 0.0       # modelled, if topology given
    comm_time_s_per_step: float = 0.0        # amortized over K inner steps
    energy_wh: float = 0.0
    replica_regions: List[str] = field(default_factory=list)  # per replica,
                                             # when a placement maps them
    sync_wan_bytes_per_round: float = 0.0    # modelled WAN share
    # ---- async / fault-injection accounting -------------------------
    mode: str = "sync"
    outer_updates: int = 0                   # == rounds in sync mode
    per_replica_k: List[int] = field(default_factory=list)
    inner_steps_total: int = 0               # steps actually run
    contributed_steps: int = 0               # steps whose deltas merged
    dropped_stale: int = 0                   # deltas past the S bound
    late_merged: int = 0                     # deltas folded with s >= 1
    resyncs: int = 0
    crashes: int = 0
    virtual_time_s: float = 0.0              # modelled fleet wall-clock
    virtual_tokens_per_s: float = 0.0        # contributed tokens / vclock
    fault_counts: Dict[str, int] = field(default_factory=dict)
    # ---- health-driven response accounting (PR 9) --------------------
    health_excluded_updates: int = 0         # outer updates that went
                                             # ahead without a detected
                                             # straggler (quorum shrunk)
    health_summary: Optional[Dict[str, Any]] = None


def _outer_update(global_params: PyTree, mean_delta: PyTree,
                  momentum: PyTree, ls: LocalSGDConfig
                  ) -> Tuple[PyTree, PyTree]:
    mu = ls.outer_momentum

    def one(p, d, m):
        d = d.astype(jnp.float32)
        m_new = mu * m + d
        upd = d + mu * m_new if ls.nesterov else m_new
        new_p = p.astype(jnp.float32) - ls.outer_lr * upd
        return new_p.astype(p.dtype), m_new

    flat_p, tdef = jax.tree.flatten(global_params)
    flat_d = jax.tree.leaves(mean_delta)
    flat_m = jax.tree.leaves(momentum)
    out = [one(p, d, m) for p, d, m in zip(flat_p, flat_d, flat_m)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


# ------------------------------------------------------------------ helpers

def _replica_speeds(placement, R: int) -> Optional[List[float]]:
    """Effective FLOP/s per replica, gated by its slowest stage device
    (the pipeline bound); None without a placement."""
    if placement is None:
        return None
    return [min(sp.device.effective_flops for sp in pipe)
            for pipe in placement.pipelines]


def per_replica_inner_steps(ls: LocalSGDConfig, placement) -> List[int]:
    """Per-replica K derived from the placement's region groups: every
    replica in a region shares that region's K, scaled by the region's
    slowest replica relative to the fastest region — slow regions run
    proportionally fewer inner steps so rounds finish together instead
    of the fleet idling on the slowest radio link.  Without a placement
    every replica runs the global K."""
    R = ls.replicas
    speeds = _replica_speeds(placement, R)
    if speeds is None:
        return [ls.inner_steps] * R
    region_speed: Dict[str, float] = {}
    groups = placement.region_groups()
    for reg, reps in groups.items():
        region_speed[reg] = min(speeds[r] for r in reps)
    fastest = max(region_speed.values())
    ks = [0] * R
    for reg, reps in groups.items():
        k = max(1, round(ls.inner_steps * region_speed[reg] / fastest))
        for r in reps:
            ks[r] = k
    return ks


def _replica_step_times(ls: LocalSGDConfig, placement,
                        step_flops: float) -> List[float]:
    """Modelled seconds per inner step, per replica (virtual clock)."""
    speeds = _replica_speeds(placement, ls.replicas)
    if speeds is None:
        return [ls.nominal_step_s] * ls.replicas
    return [step_flops / s for s in speeds]


def _price_sync_comm(ls: LocalSGDConfig, placement, topology,
                     sync_algorithm: str, global_params
                     ) -> Tuple[float, float, List[str]]:
    """(modelled outer-sync seconds per round, WAN bytes per round,
    replica->region map) over the placement/topology; zeros without."""
    if topology is None and placement is None:
        return 0.0, 0.0, []
    from repro.core.net import sync_cost
    R = ls.replicas
    n_elems = sum(x.size for x in jax.tree.leaves(global_params))
    if placement is not None:
        # each stage slot syncs its layer shard over that slot's
        # replica group (disjoint links — concurrent across slots,
        # the slowest slot gates); the region-grouped placement is
        # what makes the hierarchical collective pay intra-region
        # rates for most of the volume
        topo = placement.topology
        L = placement.num_layers
        t_round = 0.0
        wan = 0.0
        for i, group in enumerate(placement.dp_groups()):
            shard = int(n_elems * placement.layer_counts[i] / L)
            c = sync_cost(topo, group, shard, algorithm=sync_algorithm,
                          compress=ls.compress, dtype_bytes=4)
            t_round = max(t_round, c.time_s)
            wan += c.wan_bytes
        regions = [""] * R
        for reg, reps in placement.region_groups().items():
            for r in reps:
                regions[r] = reg
        return t_round, wan, regions
    group = topology.devices[:R]
    c = sync_cost(topology, group, n_elems, algorithm=sync_algorithm,
                  compress=ls.compress, dtype_bytes=4)
    return c.time_s, c.wan_bytes, []


def _restore_outer_state(ls: LocalSGDConfig, global_params: PyTree,
                         momentum: PyTree) -> Tuple[PyTree, PyTree, int]:
    """Elastic resume: the DiLoCo state (global params + outer Nesterov
    momentum) restores from any layout the previous fleet wrote —
    layer-sliced under different stage boundaries included — so churn
    between runs loses nothing but the inner-optimizer moments (which
    DiLoCo re-warms locally)."""
    if not (ls.resume and ls.checkpoint_dir):
        return global_params, momentum, 0
    from repro.checkpoint import ckpt
    found = ckpt.latest_complete_step(ls.checkpoint_dir)
    if found is None:
        return global_params, momentum, 0
    state = ckpt.restore(ls.checkpoint_dir,
                         {"params": global_params, "outer_m": momentum},
                         step=found)
    print(f"[local_sgd] resumed from round {found} ({ls.checkpoint_dir})")
    return state["params"], state["outer_m"], found


def _write_checkpoint(ls: LocalSGDConfig, placement, global_params: PyTree,
                      momentum: PyTree, round_no: int, tr) -> None:
    from repro.checkpoint import ckpt
    with tr.span("checkpoint", "local_sgd",
                 metric="local_sgd/checkpoint_s", round=round_no):
        state = {"params": global_params, "outer_m": momentum}
        if placement is not None:
            # stage slots shard the outer state over the spec's
            # replica/region groups (each slot's nodes hold its
            # layer range; replication adds §5 neighbour copies)
            ckpt.save_for_placement(
                ls.checkpoint_dir, round_no, state, placement,
                replication=ls.checkpoint_replication)
        else:
            ckpt.save(ls.checkpoint_dir, round_no, state)
        ckpt.prune(ls.checkpoint_dir)


def train_local_sgd(cfg: ModelConfig, tc: TrainerConfig, ls: LocalSGDConfig,
                    opt_cfg: Optional[adamw.OptConfig] = None, *,
                    topology=None, placement=None,
                    sync_algorithm: str = "hierarchical",
                    monitor: Optional[EnergyMonitor] = None,
                    metrics: Optional[MetricsRegistry] = None,
                    fault_plan: Optional[FaultPlan] = None,
                    health: Optional[HealthMonitor] = None
                    ) -> LocalSGDResult:
    """Run ``max(1, tc.steps // K)`` whole sync rounds of K inner steps
    per replica (``tc.steps`` rounded down to whole rounds; at least
    one round always runs).

    ``topology`` (a :class:`repro.core.net.Topology` covering at least
    ``ls.replicas`` devices) makes the result carry the *modelled*
    wide-area sync time per round under ``sync_algorithm``; training
    itself runs on the ambient JAX devices either way.

    ``placement`` (a :class:`repro.core.placement.PlacementSpec` with
    ``ls.replicas`` pipelines) maps each replica onto its placement
    region group instead: the pseudo-gradient sync is priced per stage
    slot over that slot's replica nodes — layer-proportional shards,
    concurrent across slots — so a region-grouped placement pays
    intra-region rates first and crosses the WAN O(regions) times.

    ``fault_plan`` (a seeded :class:`repro.core.faultinject.FaultPlan`)
    injects stragglers, crash/rejoin churn and link jitter into the
    modelled virtual clock deterministically — the same plan replays
    bit-identically.  In the synchronous mode faults only slow the
    virtual clock (every round still waits for everyone — that *is* the
    failure mode ``async_mode`` exists to fix); in async mode they also
    decide which deltas arrive late, get staleness-weighted, or are
    dropped at the bound.

    ``health`` (a :class:`repro.obs.HealthMonitor`) closes the loop the
    plan cannot: the monitor sees only *observed* durations and losses
    (what the tracer measures — never the plan), and in async mode the
    quorum barrier shrinks past replicas the monitor has flagged as
    stragglers, so the fleet stops waiting for a slow device the moment
    it is *detected* slow rather than because any oracle said so.
    ``benchmarks/bench_health.py`` gates how much of the oracle
    (plan-aware quorum) advantage this detection recovers.
    """
    if ls.replicas < 1 or ls.inner_steps < 1:
        raise ValueError(
            f"replicas={ls.replicas} and inner_steps={ls.inner_steps} "
            "must both be >= 1")
    if placement is not None:
        if topology is not None:
            raise ValueError("pass either topology= or placement=, not "
                             "both (the placement carries its topology)")
        if placement.data_parallel != ls.replicas:
            raise ValueError(
                f"placement has {placement.data_parallel} replica "
                f"pipelines but LocalSGDConfig.replicas={ls.replicas}")
    if topology is not None and len(topology.devices) < ls.replicas:
        raise ValueError(
            f"topology has {len(topology.devices)} devices but "
            f"{ls.replicas} replicas need to sync over it")
    Q = ls.quorum if ls.quorum is not None else ls.replicas
    if not 1 <= Q <= ls.replicas:
        raise ValueError(f"quorum={Q} must be in 1..{ls.replicas}")
    if ls.staleness_bound < 0:
        raise ValueError(f"staleness_bound={ls.staleness_bound} must be "
                         ">= 0")
    if ls.async_mode:
        if monitor is not None:
            raise ValueError(
                "EnergyMonitor needs real per-step wall-clock, which the "
                "async engine's virtual clock replaces; price energy "
                "from the placement instead")
        return _train_async(cfg, tc, ls, opt_cfg, topology=topology,
                            placement=placement,
                            sync_algorithm=sync_algorithm, metrics=metrics,
                            fault_plan=fault_plan, quorum=Q,
                            health=health)
    opt_cfg = opt_cfg or adamw.OptConfig(
        learning_rate=3e-4, warmup_steps=max(10, tc.steps // 20),
        decay_steps=tc.steps)
    rng = jax.random.PRNGKey(tc.seed)
    global_params = PM.init_params(cfg, rng)
    momentum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            global_params)
    global_params, momentum, start_round = _restore_outer_state(
        ls, global_params, momentum)

    from repro.train.trainer import effective_donate, make_jit_train_step
    step_fn = make_jit_train_step(cfg, tc, opt_cfg)
    donating = effective_donate(tc)
    outer_fn = jax.jit(lambda g, d, m: _outer_update(g, d, m, ls))

    R = ls.replicas
    locals_: List[PyTree] = [global_params] * R
    opt_states = [adamw.init_opt_state(global_params, opt_cfg)
                  for _ in range(R)]
    errors: List[Optional[PyTree]] = [None] * R
    streams = [make_batch_fn(cfg, tc.batch, tc.seq_len, tc.seed + 1000 * r)
               for r in range(R)]

    step_flops = F.train_flops(cfg, tc.batch, tc.seq_len,
                               remat=tc.remat != "none")
    res = LocalSGDResult()
    rounds = max(1, tc.steps // ls.inner_steps)
    tr = get_tracer()
    inj = FaultInjector(fault_plan, registry=metrics) \
        if fault_plan is not None else None
    # per-replica pseudo-gradient wire bytes (constant across rounds:
    # the compressed-delta layout depends only on the param tree)
    wire_b = wire_bytes(global_params,
                        ls.compress or CompressConfig(method="none"))
    comm_round_s, wan_round, replica_regions = _price_sync_comm(
        ls, placement, topology, sync_algorithm, global_params)
    step_times = _replica_step_times(ls, placement, step_flops)
    vclock = 0.0
    t0 = time.time()
    t_prev = t0
    for rnd in range(rounds):
        round_span = tr.span("round", "local_sgd",
                             metric="local_sgd/round_s",
                             round=start_round + rnd)
        round_span.__enter__()
        round_loss_dev = jnp.float32(0.0)    # accumulated on device
        r0_losses: List[jax.Array] = []      # replica-0 device scalars
        deltas: Optional[PyTree] = None
        round_dur = 0.0                      # virtual: slowest replica
        for r in range(R):
            rep_span = tr.span("replica", "local_sgd", replica=r)
            rep_span.__enter__()
            # with donation the jit consumes its input buffers; every
            # replica therefore starts from a fresh on-device copy so the
            # shared global_params stay valid for the pseudo-gradient
            p = jax.tree.map(lambda x: x.copy(), locals_[r]) if donating \
                else locals_[r]
            s = opt_states[r]
            for k in range(ls.inner_steps):
                with tr.span("inner_step", "local_sgd",
                             metric="local_sgd/inner_step_s"):
                    batch = jax.device_put(next(streams[r]))
                    p, s, metrics_d = step_fn(p, s, batch)
                if r == 0:
                    r0_losses.append(metrics_d["loss"])
                if monitor is not None:
                    # energy accounting needs true per-step wall-clock,
                    # which only exists at a sync point
                    jax.block_until_ready(metrics_d["loss"])
                    t_now = time.time()
                    monitor.record_step(flops=step_flops,
                                        duration_s=t_now - t_prev)
                    t_prev = t_now
            round_loss_dev = round_loss_dev + metrics_d["loss"]
            locals_[r], opt_states[r] = p, s

            with tr.span("pseudograd", "local_sgd", replica=r,
                         wire_bytes=wire_b):
                delta = jax.tree.map(
                    lambda g, l: g.astype(jnp.float32)
                    - l.astype(jnp.float32),
                    global_params, p)
                if ls.compress is not None and ls.compress.method != "none":
                    delta, errors[r] = compress_grads(delta, errors[r],
                                                      ls.compress)
                deltas = delta if deltas is None else jax.tree.map(
                    lambda a, b: a + b, deltas, delta)
            rep_span.__exit__(None, None, None)
            # virtual clock: compute gated by the replica's straggler
            # factor; a crash in sync mode stalls the whole round until
            # the device rejoins and redoes its work (the trajectory is
            # unchanged — that stall is exactly what async mode removes)
            dur_r = ls.inner_steps * step_times[r]
            jit = 0.0
            if inj is not None:
                slow = inj.plan.slowdown(r)
                dur_r *= slow
                if slow > 1.0 and rnd == 0:
                    inj.emit("straggle", r, ts_s=vclock,
                             slowdown=round(slow, 3))
                jit = inj.plan.jitter_s(r, rnd)
                if jit > 0.0:
                    inj.emit("link_flap", r, ts_s=vclock,
                             jitter_s=round(jit, 3), round=rnd)
                    dur_r += jit
                if inj.plan.crashes(r, rnd):
                    wait = inj.plan.rejoin_after(r, rnd)
                    inj.emit("crash", r, ts_s=vclock, round=rnd,
                             rejoin_rounds=wait)
                    res.crashes += 1
                    dur_r *= 1 + wait
            if health is not None:
                # feed the monitor what the spans measure: the compute
                # side of the round and the replica's sync/link time —
                # sync mode still waits for everyone (that is its
                # defining failure mode), but detection makes the
                # launcher summary / orchestrator see the straggler
                health.observe_step(r, dur_r - jit, ts_s=vclock)
                health.observe_link(r, comm_round_s + jit, ts_s=vclock)
            round_dur = max(round_dur, dur_r)

        with tr.span("outer_sync", "local_sgd",
                     metric="local_sgd/outer_sync_s",
                     wire_bytes_per_replica=wire_b, replicas=R):
            mean_delta = jax.tree.map(lambda d: d / R, deltas)
            global_params, momentum = outer_fn(global_params, mean_delta,
                                               momentum)
        vclock += round_dur + comm_round_s
        if metrics is not None:
            # fleet bytes shipped this round: every replica uploads its
            # (compressed) pseudo-gradient
            metrics.counter("local_sgd/pseudograd_bytes").inc(wire_b * R)
            metrics.counter("local_sgd/rounds").inc(1)
        # every replica restarts the next round from the new global
        # params; inner optimizer state persists (DiLoCo)
        locals_ = [global_params] * R
        if ls.checkpoint_dir and ls.checkpoint_every_rounds \
                and (rnd + 1) % ls.checkpoint_every_rounds == 0:
            _write_checkpoint(ls, placement, global_params, momentum,
                              start_round + rnd + 1, tr)
        # ONE host sync per round: replica-0 per-step losses + fleet mean
        with tr.span("metrics_drain", "local_sgd"):
            fetched = jax.device_get({"r0": r0_losses,
                                      "round": round_loss_dev})
        res.losses.extend(float(x) for x in fetched["r0"])
        round_loss = float(fetched["round"])
        res.round_losses.append(round_loss / R)
        if health is not None:
            health.observe_loss(round_loss / R, ts_s=vclock)
        if metrics is not None:
            for x in fetched["r0"]:
                metrics.histogram("local_sgd/loss", lo=1e-4, hi=1e4) \
                    .observe(float(x))
            metrics.histogram("local_sgd/round_loss", lo=1e-4, hi=1e4) \
                .observe(round_loss / R)
        round_span.__exit__(None, None, None)
        if tc.log_every and rnd % max(1, tc.log_every
                                      // ls.inner_steps) == 0:
            print(f"round {rnd:4d}  mean loss {round_loss / R:.4f}")

    wall = time.time() - t0
    res.rounds = rounds
    res.outer_updates = rounds
    res.resumed_from_round = start_round
    res.final_loss = res.round_losses[-1]
    res.steps_per_s = rounds * ls.inner_steps * R / wall
    res.sync_wire_bytes_per_round = wire_b
    res.per_replica_k = [ls.inner_steps] * R
    res.inner_steps_total = rounds * ls.inner_steps * R
    res.contributed_steps = res.inner_steps_total
    res.virtual_time_s = vclock
    if vclock > 0:
        res.virtual_tokens_per_s = (res.contributed_steps * tc.batch
                                    * tc.seq_len / vclock)
    if inj is not None:
        res.fault_counts = dict(inj.counts)
    if health is not None:
        res.health_summary = health.summary()
    if monitor is not None:
        res.energy_wh = monitor.total_wh
    if topology is not None or placement is not None:
        res.comm_time_s_per_round = comm_round_s
        res.sync_wan_bytes_per_round = wan_round
        res.replica_regions = replica_regions
        res.comm_time_s_per_step = comm_round_s / ls.inner_steps
    return res


# ------------------------------------------------- bounded-staleness async

@dataclass
class _Replica:
    """Host-side async replica state (one edge pipeline)."""
    params: PyTree = None            # local params while running
    opt_state: PyTree = None
    error: Optional[PyTree] = None   # compressor error feedback
    start_params: PyTree = None      # global snapshot the round began from
    start_version: int = 0           # global version of that snapshot
    round_idx: int = 0               # personal round counter (plan keys)
    idle: bool = False               # reported, waiting for next update
    start_t: float = 0.0             # virtual time the round began (what
                                     # overdue detection measures against)


def _train_async(cfg: ModelConfig, tc: TrainerConfig, ls: LocalSGDConfig,
                 opt_cfg: Optional[adamw.OptConfig], *, topology, placement,
                 sync_algorithm: str, metrics: Optional[MetricsRegistry],
                 fault_plan: Optional[FaultPlan], quorum: int,
                 health: Optional[HealthMonitor] = None
                 ) -> LocalSGDResult:
    """Event-driven bounded-staleness async outer loop.

    Replicas run on a modelled virtual clock; the outer update applies
    the moment ``quorum`` replicas have reported since the last update.
    Reported replicas idle until the update, then restart from the new
    global params; still-running replicas keep going and their deltas
    arrive *stale* — folded into the next update with weight
    ``1/(1+staleness)`` up to ``staleness_bound``, dropped (and the
    replica re-synced from global) past it.  A crashed replica's work is
    lost; it rejoins ``rejoin_after`` rounds later and re-syncs.
    Deterministic given (seed, plan): event ties break on replica id and
    every fault draw is keyed, so identical configs replay identical
    trajectories bit-for-bit.

    With ``health``, the quorum barrier additionally shrinks past
    replicas the monitor currently flags as stragglers and has no report
    from: an update applies once every *unflagged* outstanding replica
    (up to the configured quorum) has reported.  Detection is fed purely
    from observed per-report durations plus overdue checks on periodic
    health ticks — the plan never leaks into the decision — so the fleet
    waits on a straggler exactly until the monitor has seen enough
    evidence, then stops.  The plan keeps driving the sim underneath.
    """
    opt_cfg = opt_cfg or adamw.OptConfig(
        learning_rate=3e-4, warmup_steps=max(10, tc.steps // 20),
        decay_steps=tc.steps)
    global_params = PM.init_params(cfg, jax.random.PRNGKey(tc.seed))
    momentum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            global_params)
    global_params, momentum, start_round = _restore_outer_state(
        ls, global_params, momentum)

    from repro.train.trainer import effective_donate, make_jit_train_step
    step_fn = make_jit_train_step(cfg, tc, opt_cfg)
    donating = effective_donate(tc)
    outer_fn = jax.jit(lambda g, d, m: _outer_update(g, d, m, ls))

    R = ls.replicas
    S = ls.staleness_bound
    ks = per_replica_inner_steps(ls, placement)
    step_flops = F.train_flops(cfg, tc.batch, tc.seq_len,
                               remat=tc.remat != "none")
    step_times = _replica_step_times(ls, placement, step_flops)
    comm_round_s, wan_round, replica_regions = _price_sync_comm(
        ls, placement, topology, sync_algorithm, global_params)
    wire_b = wire_bytes(global_params,
                        ls.compress or CompressConfig(method="none"))
    tr = get_tracer()
    inj = FaultInjector(fault_plan, registry=metrics)
    plan = inj.plan

    reps = [_Replica(start_params=global_params,
                     opt_state=adamw.init_opt_state(global_params, opt_cfg))
            for _ in range(R)]
    streams = [make_batch_fn(cfg, tc.batch, tc.seq_len, tc.seed + 1000 * r)
               for r in range(R)]

    res = LocalSGDResult(mode="async", per_replica_k=list(ks))
    rounds = max(1, tc.steps // ls.inner_steps)
    version = 0
    # pending outer-update reports: replica -> (delta, weight, last_loss)
    reports: Dict[int, Tuple[PyTree, float, jax.Array]] = {}
    events: List[Tuple[float, int, str]] = []   # (t, replica, kind)

    def _round_dur(r: int) -> float:
        dur = ks[r] * step_times[r] * plan.slowdown(r)
        return dur + plan.jitter_s(r, reps[r].round_idx)

    def _start_round(r: int, t: float) -> None:
        """Begin replica r's next personal round at virtual time t."""
        rep = reps[r]
        rep.idle = False
        rep.start_t = t
        rep.start_params = global_params
        rep.start_version = version
        slow = plan.slowdown(r)
        if slow > 1.0 and rep.round_idx == 0:
            inj.emit("straggle", r, ts_s=t, slowdown=round(slow, 3))
        if plan.crashes(r, rep.round_idx):
            wait = plan.rejoin_after(r, rep.round_idx)
            inj.emit("crash", r, ts_s=t, round=rep.round_idx,
                     rejoin_rounds=wait)
            res.crashes += 1
            rep.round_idx += 1
            heapq.heappush(
                events, (t + wait * ks[r] * step_times[r] * slow, r,
                         "rejoin"))
            return
        jit = plan.jitter_s(r, rep.round_idx)
        if jit > 0.0:
            inj.emit("link_flap", r, ts_s=t, jitter_s=round(jit, 3),
                     round=rep.round_idx)
        dur = _round_dur(r)
        rep.round_idx += 1
        heapq.heappush(events, (t + dur, r, "report"))

    def _run_inner(r: int) -> Tuple[PyTree, jax.Array, List[jax.Array]]:
        """Host-execute replica r's K_r inner steps; returns (delta,
        last-step loss, per-step losses)."""
        rep = reps[r]
        p = jax.tree.map(lambda x: x.copy(), rep.start_params) \
            if donating else rep.start_params
        s = rep.opt_state
        losses: List[jax.Array] = []
        for _ in range(ks[r]):
            with tr.span("inner_step", "local_sgd",
                         metric="local_sgd/inner_step_s"):
                batch = jax.device_put(next(streams[r]))
                p, s, metrics_d = step_fn(p, s, batch)
            losses.append(metrics_d["loss"])
        rep.params, rep.opt_state = p, s
        with tr.span("pseudograd", "local_sgd", replica=r,
                     wire_bytes=wire_b):
            delta = jax.tree.map(
                lambda g, l: g.astype(jnp.float32) - l.astype(jnp.float32),
                rep.start_params, p)
            if ls.compress is not None and ls.compress.method != "none":
                delta, rep.error = compress_grads(delta, rep.error,
                                                  ls.compress)
        return delta, metrics_d["loss"], losses

    def _apply_update(t: float) -> float:
        """Weighted outer update from the buffered reports; returns the
        update's virtual completion time."""
        nonlocal global_params, momentum, version
        order = sorted(reports)
        weights = [reports[r][1] for r in order]
        uniform = all(w == 1.0 for w in weights)
        acc = None
        for r in order:
            d, w, _ = reports[r]
            term = d if uniform else jax.tree.map(lambda x: x * w, d)
            acc = term if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, term)
        wsum = float(len(order)) if uniform else sum(weights)
        mean_delta = jax.tree.map(lambda d: d / wsum, acc)
        with tr.span("outer_sync", "local_sgd",
                     metric="local_sgd/outer_sync_s",
                     wire_bytes_per_replica=wire_b, reports=len(order),
                     version=version):
            global_params, momentum = outer_fn(global_params, mean_delta,
                                               momentum)
        version += 1
        # fleet-mean loss of the contributing replicas' last inner steps
        loss_dev = jnp.float32(0.0)
        for r in order:
            loss_dev = loss_dev + reports[r][2]
        round_loss = float(jax.device_get(loss_dev)) / len(order)
        res.round_losses.append(round_loss)
        if health is not None:
            health.observe_loss(round_loss, ts_s=t)
        res.contributed_steps += sum(ks[r] for r in order)
        if metrics is not None:
            metrics.counter("local_sgd/pseudograd_bytes").inc(
                wire_b * len(order))
            metrics.counter("local_sgd/rounds").inc(1)
            metrics.histogram("local_sgd/round_loss", lo=1e-4,
                              hi=1e4).observe(round_loss)
        tr.complete("outer_update", ts_s=t, dur_s=comm_round_s,
                    cat="local_sgd", track="local_sgd/outer",
                    version=version, reports=len(order),
                    round_loss=round(round_loss, 6))
        reports.clear()
        return t + comm_round_s

    # ---- health-driven barrier (PR 9): never wait on a DETECTED
    # straggler.  The effective quorum shrinks by the number of flagged
    # replicas still outstanding; detection comes from observed report
    # durations plus overdue checks on periodic ticks — the fault plan
    # is never consulted for this decision.
    tick_s = 0.5 * min(ks[i] * step_times[i] for i in range(R))
    tick_pending = False

    def _overdue_scan(t: float) -> None:
        for i in range(R):
            if not reps[i].idle and i not in reports:
                health.check_overdue(i, t - reps[i].start_t, ts_s=t)

    def _quorum_eff() -> int:
        if health is None:
            return quorum
        outstanding_flagged = sum(
            1 for i in range(R)
            if health.is_straggler(i) and not reps[i].idle
            and i not in reports)
        return max(1, min(quorum, R - outstanding_flagged))

    def _maybe_update(t: float) -> Optional[float]:
        """Apply the outer update if the (health-shrunk) quorum is met;
        returns the update completion time, else None."""
        q_eff = _quorum_eff()
        if not reports or len(reports) < q_eff:
            return None
        if q_eff < quorum:
            res.health_excluded_updates += 1
        return _apply_update(t)

    vclock = 0.0
    t0 = time.time()
    for r in range(R):
        _start_round(r, 0.0)
    while version < rounds and events:
        t, r, kind = heapq.heappop(events)
        vclock = max(vclock, t)
        if kind == "health_tick":
            tick_pending = False
            if health is not None and reports:
                _overdue_scan(t)
                t_up = _maybe_update(t)
                if t_up is not None:
                    vclock = max(vclock, t_up)
                    if version >= rounds:
                        break
                    if ls.checkpoint_dir and ls.checkpoint_every_rounds \
                            and version % ls.checkpoint_every_rounds == 0:
                        _write_checkpoint(ls, placement, global_params,
                                          momentum, start_round + version,
                                          tr)
                    for i in range(R):
                        if reps[i].idle:
                            _start_round(i, t_up)
                elif not tick_pending:
                    tick_pending = True
                    heapq.heappush(events, (t + tick_s, -1, "health_tick"))
            continue
        rep = reps[r]
        if kind == "rejoin":
            # the crashed device is back but its local state is gone:
            # re-sync from the current global params and start fresh
            inj.emit("rejoin", r, ts_s=t)
            inj.emit("resync", r, ts_s=t, version=version)
            res.resyncs += 1
            _start_round(r, t)
            continue
        delta, last_loss, losses = _run_inner(r)
        res.inner_steps_total += ks[r]
        if r == 0:
            fetched = jax.device_get(losses)
            res.losses.extend(float(x) for x in fetched)
            if metrics is not None:
                for x in fetched:
                    metrics.histogram("local_sgd/loss", lo=1e-4,
                                      hi=1e4).observe(float(x))
        stale = version - rep.start_version
        tr.complete("async_round", ts_s=t - _round_dur_last(rep, ks, r,
                                                            step_times,
                                                            plan),
                    dur_s=_round_dur_last(rep, ks, r, step_times, plan),
                    cat="local_sgd", track=f"replica:{r}",
                    staleness=stale, k=ks[r])
        if health is not None:
            # what the spans measured for this report: compute time and
            # link time, separately (the async_round / outer_sync split)
            jit = plan.jitter_s(r, rep.round_idx - 1)
            health.observe_step(r, ks[r] * step_times[r] * plan.slowdown(r),
                                ts_s=t)
            health.observe_link(r, comm_round_s + jit, ts_s=t)
            _overdue_scan(t)
        if stale > S:
            # past the hard bound: the delta would drag the global
            # params toward a stale point — drop it and re-sync the
            # replica from the current global (it lost K_r steps of
            # work, which is exactly the price the bound caps)
            inj.emit("drop_stale", r, ts_s=t, staleness=stale, bound=S)
            inj.emit("resync", r, ts_s=t, version=version)
            res.dropped_stale += 1
            res.resyncs += 1
            _start_round(r, t)
        else:
            if stale > 0:
                res.late_merged += 1
            reports[r] = (delta, 1.0 / (1.0 + stale), last_loss)
            rep.idle = True
            t_up = _maybe_update(t)
            if t_up is not None:
                vclock = max(vclock, t_up)
                if version >= rounds:
                    break
                if ls.checkpoint_dir and ls.checkpoint_every_rounds \
                        and version % ls.checkpoint_every_rounds == 0:
                    _write_checkpoint(ls, placement, global_params,
                                      momentum, start_round + version, tr)
                for i in range(R):
                    if reps[i].idle:
                        _start_round(i, t_up)
            elif health is not None and not tick_pending:
                # quorum not met: schedule an overdue check so a
                # straggler can be detected (and the barrier shrunk)
                # before its report ever arrives
                tick_pending = True
                heapq.heappush(events, (t + tick_s, -1, "health_tick"))

    wall = time.time() - t0
    res.rounds = version
    res.outer_updates = version
    res.resumed_from_round = start_round
    res.final_loss = res.round_losses[-1] if res.round_losses \
        else float("nan")
    res.steps_per_s = res.inner_steps_total / wall if wall > 0 else 0.0
    res.sync_wire_bytes_per_round = wire_b
    res.virtual_time_s = vclock
    if vclock > 0:
        res.virtual_tokens_per_s = (res.contributed_steps * tc.batch
                                    * tc.seq_len / vclock)
    res.fault_counts = dict(inj.counts)
    if health is not None:
        res.health_summary = health.summary()
    if topology is not None or placement is not None:
        res.comm_time_s_per_round = comm_round_s
        res.sync_wan_bytes_per_round = wan_round
        res.replica_regions = replica_regions
        res.comm_time_s_per_step = comm_round_s / ls.inner_steps
    return res


def _round_dur_last(rep: _Replica, ks, r: int, step_times, plan) -> float:
    """Duration of the round that just reported (round_idx was already
    advanced when it was scheduled)."""
    idx = rep.round_idx - 1
    dur = ks[r] * step_times[r] * plan.slowdown(r)
    return dur + plan.jitter_s(r, idx)
