"""Local-update (DiLoCo-style) low-communication training.

Edge fleets cannot afford a gradient allreduce every step over wide-area
links; the viable regime (DiLoCo, FedOpt, post-local-SGD) is *local
update*: each replica runs K inner optimizer steps on its own data
shard, then the fleet synchronizes once on the **pseudo-gradient**

    delta_r = global_params - local_params_r          (after K steps)

with an outer Nesterov-momentum SGD applied to the averaged delta:

    m   <- mu * m + mean_r(delta_r)
    upd <- mean_r(delta_r) + mu * m      (Nesterov;  upd <- m  otherwise)
    global <- global - outer_lr * upd

Sync frequency — and therefore wide-area wire time — drops by K×, and
the pseudo-gradients additionally pass through the repro's gradient
compressors (int8 / top-k with per-replica error feedback), composing
with the collective cost models in :mod:`repro.core.net`.

With ``inner_steps=1``, ``outer_momentum=0``, ``outer_lr=1`` and one
replica the outer loop is the identity and the trajectory reduces
exactly to the plain inner-optimizer trainer — the correctness anchor
the tests pin down.

Inner steps run the same jit'd train step as :mod:`repro.train.trainer`
on whatever mesh is ambient; replicas are simulated host-side as
independent parameter copies (the real deployment maps each replica to
one edge pipeline).

Like the plain trainer, the inner loop is zero-sync: params/opt-state are
donated into the jit (each replica starts a round from a fresh on-device
copy of the global params so donation can never invalidate the buffer the
pseudo-gradient needs), per-step losses accumulate on device, and the
host fetches everything with a single ``jax.device_get`` per sync round.
An ``EnergyMonitor`` opts back into per-step sync (it needs real
per-step wall-clock).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flops as F
from repro.core.energy.monitor import EnergyMonitor
from repro.data.pipeline import make_batch_fn
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer
from repro.models import params as PM
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.compress import CompressConfig, compress_grads, wire_bytes
from repro.train.step import make_train_step
from repro.train.trainer import TrainerConfig

PyTree = Any


@dataclass(frozen=True)
class LocalSGDConfig:
    replicas: int = 4
    inner_steps: int = 16            # K: inner steps per sync round
    outer_lr: float = 0.7            # DiLoCo outer Nesterov defaults
    outer_momentum: float = 0.9
    nesterov: bool = True
    compress: Optional[CompressConfig] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every_rounds: int = 0
    checkpoint_replication: int = 1  # §5 neighbour shard copies
    resume: bool = False             # restore newest complete ckpt first


@dataclass
class LocalSGDResult:
    losses: List[float] = field(default_factory=list)     # replica-0, per step
    round_losses: List[float] = field(default_factory=list)  # fleet mean
    final_loss: float = float("nan")
    rounds: int = 0
    resumed_from_round: int = 0              # 0 when starting fresh
    steps_per_s: float = 0.0
    sync_wire_bytes_per_round: int = 0
    comm_time_s_per_round: float = 0.0       # modelled, if topology given
    comm_time_s_per_step: float = 0.0        # amortized over K inner steps
    energy_wh: float = 0.0
    replica_regions: List[str] = field(default_factory=list)  # per replica,
                                             # when a placement maps them
    sync_wan_bytes_per_round: float = 0.0    # modelled WAN share


def _outer_update(global_params: PyTree, mean_delta: PyTree,
                  momentum: PyTree, ls: LocalSGDConfig
                  ) -> Tuple[PyTree, PyTree]:
    mu = ls.outer_momentum

    def one(p, d, m):
        d = d.astype(jnp.float32)
        m_new = mu * m + d
        upd = d + mu * m_new if ls.nesterov else m_new
        new_p = p.astype(jnp.float32) - ls.outer_lr * upd
        return new_p.astype(p.dtype), m_new

    flat_p, tdef = jax.tree.flatten(global_params)
    flat_d = jax.tree.leaves(mean_delta)
    flat_m = jax.tree.leaves(momentum)
    out = [one(p, d, m) for p, d, m in zip(flat_p, flat_d, flat_m)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def train_local_sgd(cfg: ModelConfig, tc: TrainerConfig, ls: LocalSGDConfig,
                    opt_cfg: Optional[adamw.OptConfig] = None, *,
                    topology=None, placement=None,
                    sync_algorithm: str = "hierarchical",
                    monitor: Optional[EnergyMonitor] = None,
                    metrics: Optional[MetricsRegistry] = None
                    ) -> LocalSGDResult:
    """Run ``max(1, tc.steps // K)`` whole sync rounds of K inner steps
    per replica (``tc.steps`` rounded down to whole rounds; at least
    one round always runs).

    ``topology`` (a :class:`repro.core.net.Topology` covering at least
    ``ls.replicas`` devices) makes the result carry the *modelled*
    wide-area sync time per round under ``sync_algorithm``; training
    itself runs on the ambient JAX devices either way.

    ``placement`` (a :class:`repro.core.placement.PlacementSpec` with
    ``ls.replicas`` pipelines) maps each replica onto its placement
    region group instead: the pseudo-gradient sync is priced per stage
    slot over that slot's replica nodes — layer-proportional shards,
    concurrent across slots — so a region-grouped placement pays
    intra-region rates first and crosses the WAN O(regions) times.
    """
    if ls.replicas < 1 or ls.inner_steps < 1:
        raise ValueError(
            f"replicas={ls.replicas} and inner_steps={ls.inner_steps} "
            "must both be >= 1")
    if placement is not None:
        if topology is not None:
            raise ValueError("pass either topology= or placement=, not "
                             "both (the placement carries its topology)")
        if placement.data_parallel != ls.replicas:
            raise ValueError(
                f"placement has {placement.data_parallel} replica "
                f"pipelines but LocalSGDConfig.replicas={ls.replicas}")
    if topology is not None and len(topology.devices) < ls.replicas:
        raise ValueError(
            f"topology has {len(topology.devices)} devices but "
            f"{ls.replicas} replicas need to sync over it")
    opt_cfg = opt_cfg or adamw.OptConfig(
        learning_rate=3e-4, warmup_steps=max(10, tc.steps // 20),
        decay_steps=tc.steps)
    rng = jax.random.PRNGKey(tc.seed)
    global_params = PM.init_params(cfg, rng)
    momentum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            global_params)
    start_round = 0
    if ls.resume and ls.checkpoint_dir:
        # elastic resume: the DiLoCo state (global params + outer
        # Nesterov momentum) restores from any layout the previous
        # fleet wrote — layer-sliced under different stage boundaries
        # included — so churn between runs loses nothing but the
        # inner-optimizer moments (which DiLoCo re-warms locally)
        from repro.checkpoint import ckpt
        found = ckpt.latest_complete_step(ls.checkpoint_dir)
        if found is not None:
            state = ckpt.restore(
                ls.checkpoint_dir,
                {"params": global_params, "outer_m": momentum}, step=found)
            global_params, momentum = state["params"], state["outer_m"]
            start_round = found
            print(f"[local_sgd] resumed from round {found} "
                  f"({ls.checkpoint_dir})")

    from repro.train.trainer import effective_donate, make_jit_train_step
    step_fn = make_jit_train_step(cfg, tc, opt_cfg)
    donating = effective_donate(tc)
    outer_fn = jax.jit(lambda g, d, m: _outer_update(g, d, m, ls))

    R = ls.replicas
    locals_: List[PyTree] = [global_params] * R
    opt_states = [adamw.init_opt_state(global_params, opt_cfg)
                  for _ in range(R)]
    errors: List[Optional[PyTree]] = [None] * R
    streams = [make_batch_fn(cfg, tc.batch, tc.seq_len, tc.seed + 1000 * r)
               for r in range(R)]

    step_flops = F.train_flops(cfg, tc.batch, tc.seq_len,
                               remat=tc.remat != "none")
    res = LocalSGDResult()
    rounds = max(1, tc.steps // ls.inner_steps)
    tr = get_tracer()
    # per-replica pseudo-gradient wire bytes (constant across rounds:
    # the compressed-delta layout depends only on the param tree)
    wire_b = wire_bytes(global_params,
                        ls.compress or CompressConfig(method="none"))
    t0 = time.time()
    t_prev = t0
    for rnd in range(rounds):
        round_span = tr.span("round", "local_sgd",
                             metric="local_sgd/round_s",
                             round=start_round + rnd)
        round_span.__enter__()
        round_loss_dev = jnp.float32(0.0)    # accumulated on device
        r0_losses: List[jax.Array] = []      # replica-0 device scalars
        deltas: Optional[PyTree] = None
        for r in range(R):
            rep_span = tr.span("replica", "local_sgd", replica=r)
            rep_span.__enter__()
            # with donation the jit consumes its input buffers; every
            # replica therefore starts from a fresh on-device copy so the
            # shared global_params stay valid for the pseudo-gradient
            p = jax.tree.map(lambda x: x.copy(), locals_[r]) if donating \
                else locals_[r]
            s = opt_states[r]
            for k in range(ls.inner_steps):
                with tr.span("inner_step", "local_sgd",
                             metric="local_sgd/inner_step_s"):
                    batch = jax.device_put(next(streams[r]))
                    p, s, metrics_d = step_fn(p, s, batch)
                if r == 0:
                    r0_losses.append(metrics_d["loss"])
                if monitor is not None:
                    # energy accounting needs true per-step wall-clock,
                    # which only exists at a sync point
                    jax.block_until_ready(metrics_d["loss"])
                    t_now = time.time()
                    monitor.record_step(flops=step_flops,
                                        duration_s=t_now - t_prev)
                    t_prev = t_now
            round_loss_dev = round_loss_dev + metrics_d["loss"]
            locals_[r], opt_states[r] = p, s

            with tr.span("pseudograd", "local_sgd", replica=r,
                         wire_bytes=wire_b):
                delta = jax.tree.map(
                    lambda g, l: g.astype(jnp.float32)
                    - l.astype(jnp.float32),
                    global_params, p)
                if ls.compress is not None and ls.compress.method != "none":
                    delta, errors[r] = compress_grads(delta, errors[r],
                                                      ls.compress)
                deltas = delta if deltas is None else jax.tree.map(
                    lambda a, b: a + b, deltas, delta)
            rep_span.__exit__(None, None, None)

        with tr.span("outer_sync", "local_sgd",
                     metric="local_sgd/outer_sync_s",
                     wire_bytes_per_replica=wire_b, replicas=R):
            mean_delta = jax.tree.map(lambda d: d / R, deltas)
            global_params, momentum = outer_fn(global_params, mean_delta,
                                               momentum)
        if metrics is not None:
            # fleet bytes shipped this round: every replica uploads its
            # (compressed) pseudo-gradient
            metrics.counter("local_sgd/pseudograd_bytes").inc(wire_b * R)
            metrics.counter("local_sgd/rounds").inc(1)
        # every replica restarts the next round from the new global
        # params; inner optimizer state persists (DiLoCo)
        locals_ = [global_params] * R
        if ls.checkpoint_dir and ls.checkpoint_every_rounds \
                and (rnd + 1) % ls.checkpoint_every_rounds == 0:
            from repro.checkpoint import ckpt
            with tr.span("checkpoint", "local_sgd",
                         metric="local_sgd/checkpoint_s",
                         round=start_round + rnd + 1):
                state = {"params": global_params, "outer_m": momentum}
                if placement is not None:
                    # stage slots shard the outer state over the spec's
                    # replica/region groups (each slot's nodes hold its
                    # layer range; replication adds §5 neighbour copies)
                    ckpt.save_for_placement(
                        ls.checkpoint_dir, start_round + rnd + 1, state,
                        placement, replication=ls.checkpoint_replication)
                else:
                    ckpt.save(ls.checkpoint_dir, start_round + rnd + 1,
                              state)
                ckpt.prune(ls.checkpoint_dir)
        # ONE host sync per round: replica-0 per-step losses + fleet mean
        with tr.span("metrics_drain", "local_sgd"):
            fetched = jax.device_get({"r0": r0_losses,
                                      "round": round_loss_dev})
        res.losses.extend(float(x) for x in fetched["r0"])
        round_loss = float(fetched["round"])
        res.round_losses.append(round_loss / R)
        if metrics is not None:
            for x in fetched["r0"]:
                metrics.histogram("local_sgd/loss", lo=1e-4, hi=1e4) \
                    .observe(float(x))
            metrics.histogram("local_sgd/round_loss", lo=1e-4, hi=1e4) \
                .observe(round_loss / R)
        round_span.__exit__(None, None, None)
        if tc.log_every and rnd % max(1, tc.log_every
                                      // ls.inner_steps) == 0:
            print(f"round {rnd:4d}  mean loss {round_loss / R:.4f}")

    wall = time.time() - t0
    res.rounds = rounds
    res.resumed_from_round = start_round
    res.final_loss = res.round_losses[-1]
    res.steps_per_s = rounds * ls.inner_steps * R / wall
    res.sync_wire_bytes_per_round = wire_b
    if monitor is not None:
        res.energy_wh = monitor.total_wh
    if topology is not None or placement is not None:
        from repro.core.net import sync_cost
        n_elems = sum(x.size for x in jax.tree.leaves(global_params))
        if placement is not None:
            # each stage slot syncs its layer shard over that slot's
            # replica group (disjoint links — concurrent across slots,
            # the slowest slot gates); the region-grouped placement is
            # what makes the hierarchical collective pay intra-region
            # rates for most of the volume
            topo = placement.topology
            L = placement.num_layers
            t_round = 0.0
            wan = 0.0
            for i, group in enumerate(placement.dp_groups()):
                shard = int(n_elems * placement.layer_counts[i] / L)
                c = sync_cost(topo, group, shard,
                              algorithm=sync_algorithm,
                              compress=ls.compress, dtype_bytes=4)
                t_round = max(t_round, c.time_s)
                wan += c.wan_bytes
            res.comm_time_s_per_round = t_round
            res.sync_wan_bytes_per_round = wan
            regions = [""] * R
            for reg, reps in placement.region_groups().items():
                for r in reps:
                    regions[r] = reg
            res.replica_regions = regions
        else:
            group = topology.devices[:R]
            c = sync_cost(topology, group, n_elems,
                          algorithm=sync_algorithm, compress=ls.compress,
                          dtype_bytes=4)
            res.comm_time_s_per_round = c.time_s
            res.sync_wan_bytes_per_round = c.wan_bytes
        res.comm_time_s_per_step = res.comm_time_s_per_round \
            / ls.inner_steps
    return res
