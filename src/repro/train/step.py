"""jit-able train step: forward + backward + AdamW, one function per config.

The returned closure is pure (params, opt_state, batch) -> (params,
opt_state, metrics) and is what ``launch/dryrun.py`` lowers against the
production mesh and what the trainer loop jits for real execution.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.compress import CompressConfig, compress_grads

PyTree = Any


def _split_microbatches(batch: Dict[str, jax.Array], m: int
                        ) -> Dict[str, jax.Array]:
    """Reshape every batch leaf to (m, B/m, ...); positions (3,B,S) on dim 1."""
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim == 3:
            out[k] = v.reshape(v.shape[0], m, v.shape[1] // m, v.shape[2]) \
                      .swapaxes(0, 1)
        else:
            out[k] = v.reshape((m, v.shape[0] // m) + v.shape[1:])
    return out


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig, *,
                    remat: str = "none",
                    compress: Optional[CompressConfig] = None,
                    attn_impl: str = "chunked",
                    microbatches: int = 1) -> Callable:
    """Gradient-accumulation microbatching: activation memory scales with
    B/microbatches while the optimizer update stays per-global-batch —
    the standard big-model memory/throughput trade.

    ``remat`` defaults to "none" here AND in ``TrainerConfig`` (they used to
    disagree: "full" vs "none", so the trainer silently rematerialized
    nothing while dry-runs rematerialized everything).  Rematerialization is
    a memory/compute trade that only pays off at real model scale, so it is
    opt-in: the big-model launch paths (``launch/dryrun``, ``launch/train``)
    pass ``remat`` explicitly."""

    def loss_fn(p, mb):
        return M.forward_train(p, cfg, mb, remat=remat, attn_impl=attn_impl)

    def train_step(params: PyTree, opt_state: Dict[str, Any],
                   batch: Dict[str, jax.Array]
                   ) -> Tuple[PyTree, Dict[str, Any], Dict[str, jax.Array]]:
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def body(acc, mb):
                gsum, lsum = acc
                (loss, mets), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            # zeros_like inherits the param sharding: the accumulator (and
            # hence the per-microbatch grad reduction) stays FSDP-sharded
            # instead of forcing a replicated all-reduce
            g0 = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics: Dict[str, jax.Array] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if compress is not None and compress.method != "none":
            grads, _ = compress_grads(grads, None, compress)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, remat: str = "none",
                   attn_impl: str = "chunked") -> Callable:
    """jit'd eval step.  Takes the SAME ``remat``/``attn_impl`` knobs as
    ``make_train_step`` so evaluation runs the configuration being trained
    (it used to hardcode the forward defaults and silently diverge — e.g. a
    pallas-trained model would eval through the chunked path)."""
    @jax.jit
    def eval_step(params: PyTree, batch: Dict[str, jax.Array]):
        loss, metrics = M.forward_train(params, cfg, batch, remat=remat,
                                        attn_impl=attn_impl)
        return dict(metrics, loss=loss)
    return eval_step
