"""Shared fixtures.  NOTE: no --xla_force_host_platform_device_count here —
smoke tests and benches must see 1 CPU device; multi-device tests spawn
subprocesses with their own XLA_FLAGS."""

import dataclasses

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny(cfg, **kw):
    """Reduced fp32 variant for numerics-sensitive tests."""
    red = cfg.reduced(**kw)
    return dataclasses.replace(red, param_dtype="float32",
                               compute_dtype="float32")


def no_drop(cfg):
    """MoE variant with capacity high enough to avoid drops."""
    if not cfg.moe.enabled:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.num_experts)))
