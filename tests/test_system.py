"""End-to-end behaviour tests: training converges, decode==prefill,
greedy generation runs through the serve path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.opt import opt_config
from repro.models import model as M
from repro.models import params as P
from repro.serve.step import greedy_generate
from repro.train.trainer import TrainerConfig, train

from conftest import no_drop, tiny


def test_training_loss_decreases():
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=128,
                                         vocab_size=512)
    res = train(cfg, TrainerConfig(steps=30, batch=8, seq_len=64,
                                   log_every=0))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.5, (first, last)
    assert np.isfinite(res.final_loss)
    assert res.compile_time_s > 0.0
    assert res.steady_steps_per_s > 0.0


def test_async_zero_sync_loop_matches_seed_loop():
    """The zero-sync loop (donation + async metrics + prefetch) is a pure
    scheduling change: the loss trajectory is identical to the seed-style
    per-step-sync loop."""
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=128,
                                         vocab_size=512)
    kw = dict(steps=8, batch=4, seq_len=32, log_every=0, seed=11)
    sync = train(cfg, TrainerConfig(donate=False, async_metrics=False,
                                    prefetch=False, **kw))
    fast = train(cfg, TrainerConfig(donate=True, async_metrics=True,
                                    prefetch=True, **kw))
    np.testing.assert_allclose(sync.losses, fast.losses, rtol=0, atol=0)


def test_eval_step_matches_train_configuration():
    """make_eval_step threads attn_impl/remat: its loss equals the raw
    forward with the same knobs (it used to hardcode the defaults)."""
    from repro.train.step import make_eval_step

    cfg = tiny(get_config("qwen2-7b"))
    params = P.init_params(cfg, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    for impl in ("naive", "chunked"):
        ev = make_eval_step(cfg, attn_impl=impl, remat="none")
        got = ev(params, batch)
        want, _ = M.forward_train(params, cfg, batch, attn_impl=impl)
        np.testing.assert_allclose(float(got["loss"]), float(want),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-130m",
                                  "jamba-v0.1-52b", "deepseek-v3-671b",
                                  "mixtral-8x7b", "whisper-medium"])
def test_decode_matches_full_forward(arch, rng):
    cfg = no_drop(tiny(get_config(arch)))
    cfg = dataclasses.replace(cfg, mtp_depth=0)
    params = P.init_params(cfg, rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(rng, (B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.float32)
        batch["frames"] = frames
        enc = M.encoder_forward(params, cfg, frames, {})
    full = M.forward_logits(params, cfg, batch)
    cache = M.init_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: M.decode_step(p, cfg, c, t, i, enc=enc))
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, toks[:, i:i + 1], jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_greedy_generate_runs():
    cfg = tiny(get_config("qwen2-7b"))
    params = P.init_params(cfg, jax.random.PRNGKey(3))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 5), 0,
                                cfg.vocab_size)
    out = greedy_generate(params, cfg, prompt, max_new=4)
    assert out.shape == (2, 9)
    assert np.all(np.asarray(out) >= 0)
    assert np.all(np.asarray(out) < cfg.vocab_size)


def test_chunked_attention_equals_naive_end_to_end(rng):
    cfg = no_drop(tiny(get_config("mixtral-8x7b")))
    params = P.init_params(cfg, rng)
    toks = jax.random.randint(rng, (2, 48), 0, cfg.vocab_size)
    a = M.forward_logits(params, cfg, {"tokens": toks}, attn_impl="naive")
    b = M.forward_logits(params, cfg, {"tokens": toks}, attn_impl="chunked")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
