"""Fleet health analytics (PR 9): streaming detectors calibrated on
seeded synthetic streams with known injection points, SLO burn-rate
monitors, the offline trace analyzer, the alert/SLO schema in
``repro.obs.validate``, and the closed loop through local-SGD /
orchestrator / serve engine."""

import json
import math

import jax
import numpy as np
import pytest

from repro.obs import (HealthMonitor, LinkDegradeDetector,
                       LossSpikeDetector, MetricsRegistry, SLOMonitor,
                       SLOSpec, StragglerDetector, Tracer, serve_slos,
                       set_tracer, train_slos)
from repro.obs.validate import (validate_chrome_trace,
                                validate_metrics_jsonl)


def _hm(**kw):
    return HealthMonitor(registry=MetricsRegistry(), **kw)


# --------------------------------------------------------------------------- #
# Histogram non-finite rejection (metrics.py hardening)
# --------------------------------------------------------------------------- #

def test_histogram_rejects_non_finite():
    reg = MetricsRegistry()
    h = reg.histogram("lat", lo=1e-3, hi=10.0)
    h.observe(0.5)
    h.observe(float("nan"))
    h.observe(float("inf"))
    h.observe(float("-inf"))
    h.observe(0.7)
    snap = h.snapshot()
    assert snap["count"] == 2
    assert snap["rejected"] == 3
    assert math.isfinite(snap["p99"])
    # rejected key only appears once something was actually dropped
    clean = reg.histogram("ok", lo=1e-3, hi=10.0)
    clean.observe(1.0)
    assert "rejected" not in clean.snapshot()


# --------------------------------------------------------------------------- #
# Detector calibration on synthetic streams with known injection points
# --------------------------------------------------------------------------- #

def test_straggler_detector_flags_injected_entity_with_bounded_latency():
    det = StragglerDetector()
    rng = np.random.default_rng(0)
    flagged_at = None
    for t in range(20):
        for r in range(6):
            dur = 0.2 * (1 + 0.05 * rng.standard_normal())
            if r == 4 and t >= 5:          # entity 4 turns 6x slow at t=5
                dur *= 6.0
            a = det.observe(str(r), dur)
            if a is not None and a.kind == "straggler" \
                    and flagged_at is None:
                assert a.entity == "4"
                flagged_at = t
    # the entity is judged on its windowed MEDIAN (one spike never
    # flags), so the slow regime must first outnumber the 5 healthy
    # observations in its history (5 + 1 rounds), plus the <=3-round
    # lag of the amortized refresh-every-4 median cache
    assert flagged_at is not None and flagged_at <= 5 + 6 + 3, \
        "injected straggler must be flagged once slow dominates"
    assert det.flagged == {"4"}            # zero false positives


def test_straggler_detector_clears_with_hysteresis():
    det = StragglerDetector()
    for t in range(12):
        for r in range(5):
            det.observe(str(r), 1.2 if r == 0 else 0.2)
    assert "0" in det.flagged
    cleared = None
    for t in range(36):        # a full entity window + refresh lag
        for r in range(5):
            a = det.observe(str(r), 0.2)   # entity 0 recovers
            if a is not None and a.kind == "straggler_cleared":
                cleared = a.entity
    assert cleared == "0" and det.flagged == set()


def test_straggler_overdue_flags_before_first_report():
    det = StragglerDetector()
    for t in range(6):
        for r in range(4):
            det.observe(str(r), 0.2)
    # entity 5 never reported once; its in-flight round alone crosses
    assert det.check_overdue("5", 2.0) is not None
    assert "5" in det.flagged
    # an in-flight round inside the normal envelope does not flag
    assert det.check_overdue("2", 0.25) is None


def test_link_detector_spikes_and_degraded_verdict():
    det = LinkDegradeDetector()
    spikes = []
    for t in range(16):
        jit = 1.5 if (t in (8, 12)) else 0.0    # two injected flaps
        a = det.observe("7", 0.05 + jit)
        if a is not None:
            spikes.append((t, a.detail["spikes"]))
    assert spikes == [(8, 1), (12, 2)]
    assert det.degraded() == {"7"}
    # spikes stayed OUT of the baseline: a healthy obs still reads clean
    assert det.observe("7", 0.06) is None


def test_loss_detector_spike_at_known_index_and_divergence():
    det = LossSpikeDetector()
    rng = np.random.default_rng(1)
    hits = []
    for t in range(60):
        v = 2.0 - 0.01 * t + 0.005 * float(rng.standard_normal())
        if t == 40:
            v += 1.0                       # injected spike
        a = det.observe(v)
        if a is not None and a.kind == "loss_spike":
            hits.append(t)
    assert hits == [40]
    # sustained rise trips the two-window divergence verdict
    det2 = LossSpikeDetector()
    alerts = []
    for t in range(80):
        a = det2.observe(1.0 if t < 40 else 1.0 + 0.05 * (t - 39))
        if a is not None:
            alerts.append(a.kind)
    assert det2.diverged and "divergence" in alerts


def test_loss_detector_non_finite_is_immediate_divergence():
    det = LossSpikeDetector()
    for t in range(10):
        det.observe(1.0)
    a = det.observe(float("nan"))
    assert a is not None and a.kind == "divergence" and det.diverged


# --------------------------------------------------------------------------- #
# SLO burn rates
# --------------------------------------------------------------------------- #

def test_slo_breach_and_recover_cycle():
    slo = SLOMonitor(serve_slos(ttft_p99_s=0.5),
                     registry=MetricsRegistry())
    transitions = []
    for t in range(64):
        r = slo.observe("serve_ttft", 0.1, t=float(t))
        transitions.append(r)
    assert not any(transitions), "healthy traffic must not breach"
    for t in range(64, 104):
        r = slo.observe("serve_ttft", 0.9, t=float(t))
        if r:
            transitions.append(r)
    assert "breach" in transitions and slo.burning("serve_ttft")
    for t in range(104, 304):
        r = slo.observe("serve_ttft", 0.1, t=float(t))
        if r:
            transitions.append(r)
    assert transitions[-1] == "recovered"
    assert not slo.burning("serve_ttft")
    assert [e["event"] for e in slo.events] == ["slo.breach",
                                                "slo.recovered"]


def test_slo_needs_enough_signal_before_paging():
    spec = SLOSpec("x", "latency", 0.1, fast_window=8, slow_window=32)
    slo = SLOMonitor([spec], registry=MetricsRegistry())
    for t in range(7):                       # < fast_window observations
        assert slo.observe("x", 9.9, t=float(t)) is None
    assert not slo.burning("x")
    assert slo.observe("x", 9.9, t=8.0) == "breach"


def test_slo_budget_paces_spend_against_horizon():
    slo = SLOMonitor(train_slos(gco2e_budget=100.0, horizon_s=3600.0),
                     registry=MetricsRegistry())
    # spend half the budget in 1% of the horizon -> burn 50x
    slo.observe("train_gco2e", 50.0, t=0.0)
    slo.observe("train_gco2e", 0.0, t=36.0)
    assert slo.burn_rate("train_gco2e") == pytest.approx(50.0)
    v = {x["slo"]: x for x in slo.verdicts()}
    assert not v["train_gco2e"]["ok"]


def test_slo_ignores_unknown_names_and_non_finite():
    slo = SLOMonitor(serve_slos(), registry=MetricsRegistry())
    assert slo.observe("no_such_slo", 1.0) is None
    assert slo.observe("serve_ttft", float("nan")) is None
    assert slo.states["serve_ttft"].observations == 0


def test_throughput_slo_counts_low_values_as_bad():
    slo = SLOMonitor(train_slos(tokens_per_s_floor=100.0),
                     registry=MetricsRegistry())
    for t in range(16):
        slo.observe("train_tokens_per_s", 40.0, t=float(t))
    v = {x["slo"]: x for x in slo.verdicts()}
    assert v["train_tokens_per_s"]["bad_total"] == 16
    assert not v["train_tokens_per_s"]["ok"]


# --------------------------------------------------------------------------- #
# Alert/SLO schema: trace + JSONL round trips through the validator
# --------------------------------------------------------------------------- #

def test_alert_and_slo_events_validate_in_chrome_trace(tmp_path):
    tr = Tracer(enabled=True, process="test")
    hm = HealthMonitor(registry=MetricsRegistry(), tracer=tr)
    slo = SLOMonitor(serve_slos(ttft_p99_s=0.01),
                     registry=MetricsRegistry(), tracer=tr)
    for t in range(10):
        with tr.span("round", "train", round=t):
            for r in range(4):
                hm.observe_step(r, 1.5 if r == 1 else 0.2,
                                ts_s=float(t))
    for t in range(32):
        slo.observe("serve_ttft", 0.9, t=float(t))
    assert hm.stragglers() == {"1"} and slo.burning("serve_ttft")
    path = tmp_path / "trace.json"
    tr.save_chrome_trace(str(path))
    counts = validate_chrome_trace(str(path))
    assert counts["i"] >= 2                 # alert + slo instants

    bad = json.loads(path.read_text())
    for e in bad["traceEvents"]:
        if e.get("cat") == "alert":
            del e["args"]["entity"]
            break
    p2 = tmp_path / "bad.json"
    p2.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="entity"):
        validate_chrome_trace(str(p2))


def test_health_dump_jsonl_validates(tmp_path):
    hm = _hm()
    slo = SLOMonitor(serve_slos(), registry=MetricsRegistry())
    for t in range(10):
        for r in range(4):
            hm.observe_step(r, 1.5 if r == 0 else 0.2, ts_s=float(t))
    hm.observe_loss(float("inf"), ts_s=11.0)
    path = tmp_path / "health.jsonl"
    hm.dump_jsonl(str(path), slo=slo, meta={"run": "test"})
    counts = validate_metrics_jsonl(str(path))
    assert counts["alert"] >= 2 and counts["health_summary"] == 1
    assert counts["slo"] == len(slo.verdicts())
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    summary = next(r for r in recs if r["record"] == "health_summary")
    assert summary["stragglers"] == ["0"] and summary["diverged"]


# --------------------------------------------------------------------------- #
# Offline analyzer round trip
# --------------------------------------------------------------------------- #

@pytest.fixture
def round_trace(tmp_path):
    tr = Tracer(enabled=True, process="test")
    old = set_tracer(tr)
    try:
        import time
        for i in range(3):
            with tr.span("round", "train", round=i):
                with tr.span("inner_step", "train", region="europe"):
                    time.sleep(0.002)
                with tr.span("outer_sync", "train", region="europe"):
                    time.sleep(0.001)
        tr.instant("alert.straggler", "alert", track="health",
                   entity="2", detector="straggler", value=1.4,
                   threshold=0.4, severity=8.0)
    finally:
        set_tracer(old)
    path = tmp_path / "trace.json"
    tr.save_chrome_trace(str(path))
    return str(path)


def test_analyze_functions_on_generated_trace(round_trace):
    from repro.obs.analyze import (critical_path, load_events, rollup,
                                   top_spans)
    ev = load_events(round_trace)
    roll = {r["group"]: r for r in rollup(ev, by="name")}
    assert roll["inner_step"]["count"] == 3
    assert roll["inner_step"]["total_s"] >= 3 * 0.002
    by_arg = {r["group"]: r for r in rollup(ev, by="arg:region")}
    assert by_arg["europe"]["count"] == 6
    top = top_spans(ev, k=2)
    assert len(top) == 2 and top[0]["dur_s"] >= top[1]["dur_s"]
    crit = critical_path(ev, parent="round")
    assert len(crit) == 3
    r0 = crit[0]
    assert r0["wall_s"] > 0
    assert set(r0["phases"]) == {"inner_step", "outer_sync"}
    covered = sum(r0["phases"].values())
    assert covered <= r0["wall_s"] + 1e-6
    assert r0["uncovered_s"] >= 0


def test_analyze_cli_subcommands(round_trace, capsys, tmp_path):
    from repro.obs.analyze import main
    for argv in (["rollup", round_trace],
                 ["rollup", round_trace, "--by", "arg:region"],
                 ["top", round_trace, "-k", "2"],
                 ["critical", round_trace],
                 ["diff", round_trace, round_trace],
                 ["alerts", round_trace]):
        assert main(argv) == 0, argv
        assert capsys.readouterr().out.strip()
    # the alerts view reads --health-out JSONL artifacts too
    hm = _hm()
    for t in range(10):
        for r in range(4):
            hm.observe_step(r, 1.5 if r == 3 else 0.2, ts_s=float(t))
    rec = tmp_path / "health.jsonl"
    hm.dump_jsonl(str(rec))
    assert main(["alerts", str(rec)]) == 0
    assert "straggler" in capsys.readouterr().out


# --------------------------------------------------------------------------- #
# Closed loop: detections (not the plan) drive the responses
# --------------------------------------------------------------------------- #

def test_local_sgd_async_health_shrinks_quorum_past_detected():
    from conftest import tiny
    from repro.configs import get_config
    from repro.core.faultinject import FaultPlan
    from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
    from repro.train.trainer import TrainerConfig

    cfg = tiny(get_config("opt-125m"), num_layers=2, d_model=32,
               vocab_size=64)
    tc = TrainerConfig(steps=12, batch=2, seq_len=16, log_every=0)
    R = 4
    plan = FaultPlan(seed=3, straggler_frac=0.3)
    truth = {str(r) for r in range(R) if plan.is_straggler(r)}
    assert truth, "seed must realize at least one straggler"
    hm = _hm()
    res = train_local_sgd(
        cfg, tc, LocalSGDConfig(replicas=R, inner_steps=2,
                                nominal_step_s=0.1, async_mode=True,
                                quorum=R, staleness_bound=2),
        fault_plan=plan, health=hm)
    assert hm.stragglers() == truth
    assert res.health_excluded_updates >= 1
    assert res.health_summary["stragglers"] == sorted(truth)
    # at full quorum with no monitor, every update waits for the slow one
    res_plain = train_local_sgd(
        cfg, tc, LocalSGDConfig(replicas=R, inner_steps=2,
                                nominal_step_s=0.1, async_mode=True,
                                quorum=R, staleness_bound=2),
        fault_plan=plan)
    assert res.virtual_time_s < res_plain.virtual_time_s


def test_orchestrator_evicts_detected_stragglers():
    from repro.configs.opt import opt_config
    from repro.core.faultinject import FaultPlan
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    cfg = opt_config("opt-125m")
    # seed 7 realizes exactly one straggler (device 7) and the search
    # places it in the active set — a healthy majority to compare to
    plan = FaultPlan(seed=7, straggler_frac=0.25, link_flap_prob=0.05)
    fleet = make_fleet({"laptop-m2pro": 6, "smartphone-sd888": 2},
                       regions=("europe", "north_america"), seed=2)
    truth = {d.device_id for d in fleet if plan.is_straggler(d.device_id)}
    assert truth == {7}
    hm = _hm()
    r = Orchestrator(cfg, fleet,
                     SimConfig(total_steps=60, seed=5,
                               checkpoint_interval=20, fault_plan=plan),
                     health=hm).run()
    assert hm.stragglers() == {"7"}
    assert r.health_evictions >= 1
    assert r.health_summary["alerts_total"] >= 1
    # baseline without the monitor keeps the straggler in the fleet
    fleet2 = make_fleet({"laptop-m2pro": 6, "smartphone-sd888": 2},
                        regions=("europe", "north_america"), seed=2)
    r2 = Orchestrator(cfg, fleet2,
                      SimConfig(total_steps=60, seed=5,
                                checkpoint_interval=20,
                                fault_plan=plan)).run()
    assert r2.health_evictions == 0


def test_serve_engine_defers_admission_while_ttft_burns():
    import dataclasses

    from repro.configs import get_config
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    from conftest import tiny

    cfg = tiny(get_config("opt-125m"))
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    # impossible target + tiny windows: the SLO burns almost immediately
    slo = SLOMonitor([SLOSpec("serve_ttft", "latency", 1e-7,
                              objective=0.9, fast_window=4,
                              slow_window=16)],
                     registry=MetricsRegistry())
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=4, block_size=4, num_blocks=40,
        max_blocks_per_seq=6), slo=slo)
    reqs = [Request(uid=f"r{i}", prompt=[1 + i % 7, 2, 3], max_new=2)
            for i in range(12)]
    out = eng.run(reqs)
    assert set(out) == {r.uid for r in reqs}, \
        "brownout defers admissions but still drains the queue"
    deferred = eng.metrics.counter("serve/admission_deferred").value
    assert deferred > 0 and slo.burning("serve_ttft")
