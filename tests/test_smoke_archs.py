"""Per-architecture smoke tests (deliverable f).

For EACH of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model <= 512, <= 4 experts) and run one forward/train step on
CPU, asserting output shapes and absence of NaNs.  Decode smoke included
for every family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.models import params as P
from repro.optim import adamw
from repro.train.step import make_train_step

ARCHS = list_archs(assigned_only=True)


def _batch(cfg, rng, B=2, S=32):
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.arch_type == "vlm":
        Sv = 8
        batch["vision_embeds"] = jax.random.normal(
            rng, (B, Sv, cfg.d_model), jnp.bfloat16)
        lbl = np.full((B, S + Sv), -1, np.int32)
        lbl[:, Sv:] = np.asarray(toks)
        batch["labels"] = jnp.asarray(lbl)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S + Sv, dtype=jnp.int32)[None, None], (3, B, S + Sv))
    elif cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 or cfg.attn_layer_period > 0
    assert cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 4
    cfg.validate()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = P.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: M.forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_optimizer_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = P.init_params(cfg, rng)
    opt_cfg = adamw.OptConfig(learning_rate=1e-3, warmup_steps=0)
    opt = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
    batch = _batch(cfg, rng)
    new_params, new_opt, metrics = step(params, opt, batch)
    # shapes preserved, step advanced, params actually moved, all finite
    assert int(new_opt["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert np.all(np.isfinite(np.asarray(b, np.float32)))
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, f"{arch}: optimizer step did not change params"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    B, T = 2, 16
    params = P.init_params(cfg, rng)
    cache = M.init_cache(cfg, B, T)
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(rng, (B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.bfloat16)
        enc = M.encoder_forward(params, cfg, frames, {})
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    step = jax.jit(lambda p, c, t, i: M.decode_step(p, cfg, c, t, i, enc=enc))
    logits, cache = step(params, cache, tok, jnp.int32(0))
    logits, cache = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_match_published(arch):
    """Full configs: exact parameter counts in the published ballpark."""
    expected_b = {
        "qwen2-vl-2b": (1.2, 1.8),       # LM backbone of the 2B model
        "mamba2-130m": (0.12, 0.14),
        "jamba-v0.1-52b": (50, 53),
        "deepseek-v3-671b": (660, 685),
        "whisper-medium": (0.7, 0.9),
        "llama3-405b": (400, 412),
        "qwen2-7b": (7.0, 7.8),
        "qwen1.5-32b": (30, 36),
        "granite-3-2b": (2.3, 2.7),
        "mixtral-8x7b": (45, 48),
    }
    lo, hi = expected_b[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"
